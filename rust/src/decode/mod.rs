//! Autoregressive decode engine: KV-cached incremental generation plus a
//! long-lived slot-based continuous-batching scheduler (the ROADMAP serving
//! milestone beyond the prefill-only loop in `crate::serve`).
//!
//! # Layout
//!
//! * [`kvpool`] — the process-wide paged block pool: fixed-size,
//!   ref-counted K/V blocks (each spanning `block` positions × all layers)
//!   recycled through per-shape free lists.
//! * [`kv`] — per-sequence KV caches as **block tables** over the pool
//!   (plus the RoPE tables for llama-style models).  Slots release blocks
//!   on reuse; only positions `< len` are ever read.  Blocks adopted from
//!   the prefix tree are shared read-only with copy-on-write on first
//!   write.
//! * [`prefix`] — the prefix-sharing cache: a tree keyed on block-sized
//!   token runs mapping prompt prefixes to chains of immutable shared
//!   blocks, with LRU eviction under a block-capacity bound.  Admission
//!   matches incoming prompts against it and skips prefill for the matched
//!   prefix entirely.
//! * `runtime::native::decode_step` — the incremental step kernel: one token
//!   at position `cache.len` through the llama/opt graph against the cache,
//!   via either the dense weights or a compression plan's `(Wu, Wv)`
//!   low-rank factors.  `runtime::native::decode_batch` is its batched
//!   sibling and the serving hot path: many sequences and/or multi-token
//!   prompt chunks advance through ONE set of per-layer GEMMs (chunked
//!   prefill, batched-across-slots decode).  Both are dispatched through
//!   `Session::{decode_step, lowrank_decode_step, decode_batch,
//!   lowrank_decode_batch}`, which validate the artifact ABI exactly like
//!   the prefill entry points.
//! * [`sampler`] — greedy argmax and temperature softmax sampling, seeded
//!   per request so generations are independent of slot assignment,
//!   scheduling order, and thread count.
//! * [`scheduler`] — the continuous-batching loop: [`run_engine`] pulls
//!   work from a [`RequestSource`] (a fixed benchmark workload or the
//!   network server's admission queue), advances the batch through a
//!   bounded number of batched kernel calls per iteration (the across-slot
//!   decode advance, one chunk of every prefilling prompt — see
//!   [`DecodeConfig::prefill_chunk`]), and streams every generated token
//!   through a [`DecodeEvent`] sink; [`run_decode`] is the classic
//!   run-to-completion wrapper over a [`WorkloadSource`], and
//!   [`run_decode_speculative`] the same wrapper with a drafter engine
//!   proposing [`DecodeConfig::speculate_k`] tokens per slot per iteration
//!   for the target to verify in one batched call.
//!   [`run_engine_swappable`] is the live-reload variant: it serves from
//!   an owned [`EngineSlot`] and A/B-swaps to a replacement posted to its
//!   [`SwapMailbox`] once in-flight sequences drain (see `crate::artifact`
//!   for the on-disk artifact format it pairs with).
//!
//! # Determinism
//!
//! The step kernels reuse the exact per-row kernels and loop structures of
//! the full forward pass, so KV-cached step logits **bit-match** a full
//! forward over the same prefix for every thread count — and the batched
//! kernel's projections are row-independent (each output row is one
//! fixed-order accumulation; see `linalg::matmul`), so its logits also
//! bit-match the token-at-a-time reference for every chunk size and batch
//! composition.  The verify-mode contract extends this per position:
//! `runtime::native::decode_batch_modes` with `LogitsMode::All` returns,
//! for run position `j`, the bit-exact row a last-position call ending at
//! `j` would return — which is why speculative verification (accept a
//! draft only where it equals the target's own greedy sample) cannot
//! change generated output, only how many tokens commit per iteration.
//! The parity gate in `rust/tests/decode_parity.rs` enforces all of it
//! for the dense and the low-rank engines.  Scheduling only chooses
//! *when* a sequence advances, never *what* it computes, so generated
//! tokens are reproducible under any slot count / thread count / prefill
//! chunk size / arrival pattern / speculation depth — including tokens
//! streamed over TCP by `crate::server`, which bit-match the offline path
//! (`rust/tests/server_loopback.rs`).

pub mod kv;
pub mod kvpool;
pub mod prefix;
pub mod sampler;
pub mod scheduler;

pub use kv::KvCache;
pub use kvpool::DEFAULT_KV_BLOCK;
pub use prefix::PrefixTree;
pub use sampler::{argmax, Sampler};
pub use scheduler::{run_decode, run_decode_speculative, run_engine,
                    run_engine_swappable, sampler_seed, synth_requests,
                    synth_requests_shared_prefix, CompletedRequest,
                    DecodeConfig, DecodeEvent, DecodeRequest, DecodeStats,
                    EngineCounters, EngineSlot, RequestSource, SourcePoll,
                    SwapMailbox, WorkloadSource};
