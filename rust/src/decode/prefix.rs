//! Prefix-sharing cache: a tree keyed on block-sized token-id runs that
//! maps prompt prefixes to chains of immutable, shared, ref-counted KV
//! blocks.
//!
//! Fleet traffic overwhelmingly repeats prompt prefixes (system prompts,
//! few-shot preambles).  When a completed prompt's prefill blocks are
//! [`insert`](PrefixTree::insert)ed here, a later prompt that
//! [`lookup`](PrefixTree::lookup)s with the same leading tokens adopts the
//! matching block chain read-only and **skips prefill for the whole
//! matched prefix** — its KV cache starts at the divergence point.  The
//! tree holds plain [`BlockRef`]s, so sharing is ref-counting: a chain can
//! back any number of active slots at once, and eviction only drops the
//! tree's own reference (slots mid-generation keep their blocks alive).
//!
//! # Why a hit is bit-identical to a miss
//!
//! Blocks store the exact post-RoPE K and V rows prefill computed, keyed
//! by the exact token ids that produced them, and RoPE positions are
//! absolute — so the rows are a pure function of the token prefix.
//! Attention on a cache hit therefore reads the *same f32 values* a cold
//! prefill would recompute, and logits/tokens cannot differ by a bit
//! (`rust/tests/prefix_cache.rs` gates this over threads × chunk sizes ×
//! speculation depths).
//!
//! # Match policy
//!
//! Matches advance one full block (`block` tokens) at a time and are
//! capped at `prompt_len - 1` rounded **down** to a block boundary: the
//! final prompt position is always recomputed, because its forward pass is
//! what produces the first generated token's logits.  Partial trailing
//! blocks are likewise never inserted — a block enters the tree only when
//! the prompt covered all of its positions, so tree blocks are immutable
//! by construction (and [`KvCache`](super::KvCache)'s copy-on-write guard
//! makes that structural).
//!
//! # Capacity + LRU eviction
//!
//! The tree holds at most `cap_blocks` blocks.  Inserting past the bound
//! evicts least-recently-used **leaves** first (a chain shrinks from its
//! tail, so surviving entries always form valid prefixes).  Eviction is
//! deterministic: nodes live in `BTreeMap`s and ties break on the
//! first-in-order path.

use std::collections::BTreeMap;

use super::kv::KvCache;
use super::kvpool::{self, BlockRef};

/// One tree node: the KV block for the token run keyed by the parent map,
/// plus children for every continuation seen so far.
struct Node {
    blk: BlockRef,
    last_used: u64,
    children: BTreeMap<Vec<i32>, Node>,
}

/// Prefix tree over block-sized token runs (see the module docs).
pub struct PrefixTree {
    /// positions per block; every participating cache must match
    block: usize,
    /// capacity bound, in blocks
    cap_blocks: usize,
    /// logical clock driving LRU (bumped once per lookup/insert)
    clock: u64,
    /// blocks currently held by the tree
    held: usize,
    /// total blocks evicted since construction
    evictions: u64,
    children: BTreeMap<Vec<i32>, Node>,
}

impl PrefixTree {
    /// Empty tree for `block`-position blocks holding at most `cap_blocks`
    /// blocks.
    pub fn new(block: usize, cap_blocks: usize) -> PrefixTree {
        assert!(block > 0, "prefix tree needs a positive block size");
        PrefixTree {
            block,
            cap_blocks,
            clock: 0,
            held: 0,
            evictions: 0,
            children: BTreeMap::new(),
        }
    }

    /// Positions per block this tree was built for.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Match `prompt` against the cached prefixes: returns the chain of
    /// shared blocks for the longest cached prefix (block-aligned, capped
    /// at `prompt_len - 1` so the final prompt position is always
    /// recomputed) and the matched token count.  Touched nodes are bumped
    /// to most-recently-used.
    pub fn lookup(&mut self, prompt: &[i32]) -> (Vec<BlockRef>, usize) {
        self.clock += 1;
        let clock = self.clock;
        let block = self.block;
        let limit = (prompt.len().saturating_sub(1) / block) * block;
        let mut refs = Vec::new();
        let mut matched = 0usize;
        let mut cur = &mut self.children;
        while matched < limit {
            match cur.get_mut(&prompt[matched..matched + block]) {
                Some(node) => {
                    node.last_used = clock;
                    refs.push(node.blk.clone());
                    matched += block;
                    cur = &mut node.children;
                }
                None => break,
            }
        }
        (refs, matched)
    }

    /// Record a completed prompt's prefill blocks: every block fully
    /// covered by the prompt is inserted (new chains) or ref-bumped
    /// (already cached), then the tree evicts down to its capacity bound.
    /// Returns the number of newly held blocks.  The partial trailing
    /// block (if `prompt_len % block != 0`) never enters the tree.
    pub fn insert(&mut self, prompt: &[i32], cache: &KvCache) -> usize {
        assert_eq!(cache.block, self.block,
                   "cache block size {} != tree block size {}", cache.block,
                   self.block);
        let n_full = prompt.len() / self.block;
        assert!(cache.len >= n_full * self.block,
                "cache holds fewer positions than the prompt's full blocks");
        self.clock += 1;
        let clock = self.clock;
        let block = self.block;
        let mut added = 0usize;
        let mut cur = &mut self.children;
        for i in 0..n_full {
            let key = &prompt[i * block..(i + 1) * block];
            if !cur.contains_key(key) {
                added += 1;
                cur.insert(key.to_vec(), Node {
                    blk: cache.block_ref(i),
                    last_used: 0,
                    children: BTreeMap::new(),
                });
            }
            let node = cur.get_mut(key).expect("present or just inserted");
            node.last_used = clock;
            cur = &mut node.children;
        }
        self.held += added;
        self.evict_to_cap();
        added
    }

    /// Evict LRU leaves until the block count is back under the capacity
    /// bound; returns how many blocks were dropped.  Only the tree's own
    /// references are released — blocks adopted by active slots stay
    /// alive through their tables.
    fn evict_to_cap(&mut self) -> usize {
        let mut dropped = 0usize;
        while self.held > self.cap_blocks {
            let Some(path) = lru_leaf_path(&self.children) else {
                break; // held > 0 implies a leaf exists; defensive only
            };
            let blk = remove_path(&mut self.children, &path);
            kvpool::release(blk);
            self.held -= 1;
            self.evictions += 1;
            dropped += 1;
        }
        dropped
    }

    /// Blocks currently held by the tree.
    pub fn held_blocks(&self) -> usize {
        self.held
    }

    /// Total blocks evicted since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Number of distinct cached chains (tree leaves).
    pub fn chains(&self) -> usize {
        fn leaves(children: &BTreeMap<Vec<i32>, Node>) -> usize {
            children.values()
                .map(|n| {
                    if n.children.is_empty() { 1 } else { leaves(&n.children) }
                })
                .sum()
        }
        leaves(&self.children)
    }

    /// f32 bytes of KV storage reachable through the tree (each held block
    /// counted once; sharing with slots is not double-counted here).
    pub fn shared_bytes(&self) -> usize {
        fn bytes(children: &BTreeMap<Vec<i32>, Node>) -> usize {
            children.values()
                .map(|n| n.blk.bytes() + bytes(&n.children))
                .sum()
        }
        bytes(&self.children)
    }
}

impl Drop for PrefixTree {
    /// Release every held block back to the pool.
    fn drop(&mut self) {
        fn drain(children: &mut BTreeMap<Vec<i32>, Node>) {
            while let Some((_, mut n)) = children.pop_first() {
                drain(&mut n.children);
                kvpool::release(n.blk);
            }
        }
        drain(&mut self.children);
        self.held = 0;
    }
}

/// Path (sequence of map keys) to the least-recently-used leaf, ties
/// broken on the first path in `BTreeMap` order — deterministic.
fn lru_leaf_path(children: &BTreeMap<Vec<i32>, Node>)
                 -> Option<(Vec<Vec<i32>>, u64)> {
    let mut best: Option<(Vec<Vec<i32>>, u64)> = None;
    for (key, node) in children {
        let cand = if node.children.is_empty() {
            (vec![key.clone()], node.last_used)
        } else {
            let (mut path, used) = lru_leaf_path(&node.children)
                .expect("non-empty children have a leaf");
            path.insert(0, key.clone());
            (path, used)
        };
        let better = match &best {
            None => true,
            Some((_, bu)) => cand.1 < *bu,
        };
        if better {
            best = Some(cand);
        }
    }
    best
}

/// Remove the leaf at `path` and return its block.
fn remove_path(children: &mut BTreeMap<Vec<i32>, Node>, path: &[Vec<i32>])
               -> BlockRef {
    if path.len() == 1 {
        let node = children.remove(&path[0]).expect("leaf path valid");
        debug_assert!(node.children.is_empty(), "evicting a non-leaf");
        node.blk
    } else {
        let node = children.get_mut(&path[0]).expect("interior path valid");
        remove_path(&mut node.children, &path[1..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ConfigMeta, Manifest};

    fn tiny() -> ConfigMeta {
        Manifest::builtin().config("tiny").clone()
    }

    /// A cache with `len` positions "prefilled" (storage acquired and the
    /// cursor advanced; attention bits don't matter for tree mechanics).
    fn filled(cfg: &ConfigMeta, block: usize, len: usize) -> KvCache {
        let mut c = KvCache::with_block(cfg, block);
        c.ensure_len(len);
        c.len = len;
        c
    }

    #[test]
    fn lookup_matches_block_aligned_and_caps_last_position() {
        let cfg = tiny();
        let mut t = PrefixTree::new(4, 64);
        let prompt: Vec<i32> = (1..=10).collect();
        let c = filled(&cfg, 4, 10);
        // 10 tokens at block 4 → 2 full blocks enter the tree
        assert_eq!(t.insert(&prompt, &c), 2);
        assert_eq!(t.held_blocks(), 2);
        assert_eq!(t.chains(), 1);

        // identical prompt: both full blocks match (8 ≤ 10 - 1)
        let (refs, m) = t.lookup(&prompt);
        assert_eq!((refs.len(), m), (2, 8));
        // block-exact prompt of 8 tokens: the match is capped at 7 → one
        // block, so the final position is left for recompute
        let (refs, m) = t.lookup(&prompt[..8]);
        assert_eq!((refs.len(), m), (1, 4));
        // divergence inside the second block: only the first matches
        let mut div = prompt.clone();
        div[6] = 99;
        let (refs, m) = t.lookup(&div);
        assert_eq!((refs.len(), m), (1, 4));
        // divergence in the first block: no match
        div[1] = 98;
        let (refs, m) = t.lookup(&div);
        assert_eq!((refs.len(), m), (0, 0));
        // too-short prompts can never match (limit is 0)
        let (refs, m) = t.lookup(&prompt[..4]);
        assert_eq!((refs.len(), m), (0, 0));
    }

    #[test]
    fn insert_dedupes_shared_prefixes() {
        let cfg = tiny();
        let mut t = PrefixTree::new(4, 64);
        let a: Vec<i32> = (1..=12).collect();
        let mut b = a.clone();
        b[9] = 77; // diverges in the third block
        let ca = filled(&cfg, 4, 12);
        let cb = filled(&cfg, 4, 12);
        assert_eq!(t.insert(&a, &ca), 3);
        // shared first two blocks dedupe; only b's third block is new
        assert_eq!(t.insert(&b, &cb), 1);
        assert_eq!(t.held_blocks(), 4);
        assert_eq!(t.chains(), 2);
        // a's chain still matches end-to-end through the shared nodes
        let (_, m) = t.lookup(&a);
        assert_eq!(m, 8);
    }

    #[test]
    fn eviction_is_lru_and_leaf_first() {
        let cfg = tiny();
        let mut t = PrefixTree::new(4, 2);
        let a: Vec<i32> = (1..=9).collect(); // 2 full blocks
        let b: Vec<i32> = (101..=109).collect();
        let ca = filled(&cfg, 4, 9);
        let cb = filled(&cfg, 4, 9);
        t.insert(&a, &ca);
        assert_eq!(t.held_blocks(), 2);
        // touch a so b's insert evicts from a's tail anyway (capacity 2
        // can't hold both chains); the leaf goes first, then a's root
        t.lookup(&a);
        t.insert(&b, &cb);
        assert_eq!(t.held_blocks(), 2);
        assert_eq!(t.evictions(), 2);
        // a was evicted tail-first and is gone; b survives intact
        let (_, ma) = t.lookup(&a);
        let (_, mb) = t.lookup(&b);
        assert_eq!(ma, 0);
        assert_eq!(mb, 8);
    }

    #[test]
    fn eviction_respects_recency() {
        let cfg = tiny();
        // capacity 2: two single-block chains + one more forces the LRU out
        let mut t = PrefixTree::new(4, 2);
        let a: Vec<i32> = (1..=5).collect(); // 1 full block each
        let b: Vec<i32> = (11..=15).collect();
        let c: Vec<i32> = (21..=25).collect();
        let cache = filled(&cfg, 4, 5);
        t.insert(&a, &cache);
        t.insert(&b, &cache);
        t.lookup(&a); // a is now more recent than b
        t.insert(&c, &cache);
        assert_eq!(t.held_blocks(), 2);
        let (_, ma) = t.lookup(&a);
        let (_, mb) = t.lookup(&b);
        let (_, mc) = t.lookup(&c);
        assert_eq!((ma, mb, mc), (4, 0, 4)); // b was the LRU casualty
    }

    #[test]
    fn shared_bytes_counts_held_blocks_once() {
        let cfg = tiny();
        let mut t = PrefixTree::new(4, 64);
        assert_eq!(t.shared_bytes(), 0);
        let a: Vec<i32> = (1..=9).collect();
        let c = filled(&cfg, 4, 9);
        t.insert(&a, &c);
        let per_block =
            kvpool::KvBlock::bytes_for(cfg.n_layers, 4, cfg.d_model);
        assert_eq!(t.shared_bytes(), 2 * per_block);
        // re-inserting the same prompt adds nothing
        t.insert(&a, &c);
        assert_eq!(t.shared_bytes(), 2 * per_block);
    }
}
