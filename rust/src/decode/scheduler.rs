//! Continuous-batching scheduler: slot-based admission into an executing
//! decode batch, driven by an **external request source** with **per-token
//! emission callbacks**.
//!
//! A request's lifecycle is prefill-then-decode: on admission into a free
//! slot its prompt is ingested in **chunks** of
//! [`DecodeConfig::prefill_chunk`] tokens per scheduler iteration (each
//! chunk one batched [`crate::runtime::native::decode_batch`] call, filling
//! the slot's KV arena as it goes; the first new token is sampled from the
//! final chunk's logits), and on every subsequent iteration each occupied
//! slot advances by one generated token.  When a sequence hits its
//! generation budget (or its KV arena fills) the slot retires, its arena is
//! rewound into the free pool, and the next pending request is admitted —
//! the batch never drains to empty while work is queued.
//!
//! With [`DecodeConfig::prefix_cache_blocks`] > 0 admission first matches
//! the prompt against the prefix-sharing cache (`super::prefix`): the
//! matched block-aligned prefix is adopted into the slot's block table as
//! shared read-only blocks and prefill starts past it, so repeated prompts
//! skip most of their prefill — bit-identically, because the adopted
//! blocks hold the exact f32 rows a cold prefill would recompute.
//! Completed prefills are published back to the cache; the drafter's
//! mirrored arenas never share blocks with it.
//!
//! The core loop is [`run_engine`]: a **long-lived** scheduler that pulls
//! work from a [`RequestSource`] and reports progress through a sink
//! callback ([`DecodeEvent`]: one event per generated token, one per
//! completion).  Two sources exist:
//!
//! * [`WorkloadSource`] — a fixed request list with virtual-clock arrivals
//!   (request `i` becomes eligible at iteration `i * arrival_steps`; `0`
//!   saturates the queue).  [`run_decode`] wraps it to reproduce the
//!   classic run-to-completion benchmark API.
//! * the network server's queue-backed source (`crate::server`), where the
//!   scheduler runs for the life of the process, idles cheaply when no
//!   requests are queued, and drains gracefully when the queue closes.
//!
//! # Batched execution
//!
//! Each iteration issues a bounded number of batched kernel calls: the
//! decode advance across every decoding slot (the slots' hidden states
//! share a single activation matrix per layer — one GEMM across the batch
//! instead of per-slot single-row products), and one ingest of the current
//! prompt chunk of every prefilling slot.  Chunked prefill bounds the work
//! any single iteration performs, so a long prompt no longer stalls the
//! whole batch for its entire prefill: ongoing decode steps interleave with
//! its chunks, one per iteration.  Row-level parallelism inside the GEMMs
//! comes from the persistent `exec` pool.
//!
//! # Speculative self-decode
//!
//! With [`DecodeConfig::speculate_k`] > 0 and a drafter engine (built from
//! the *same* plan artifact — typically the high-compression low-rank
//! factors, while the target stays dense), each decoding slot proposes up
//! to K tokens per iteration instead of one: the drafter catches up on any
//! tokens it has not yet ingested and emits K greedy draft tokens (one
//! batched drafter call for the catch-up + first draft, then K−1 batched
//! single-token drafter calls), and the target then scores the whole
//! `[pending, draft_1 .. draft_K]` run in ONE batched verify call that
//! returns logits at **all** K+1 positions
//! (`Session::decode_batch_modes`, `LogitsMode::All`).  The slot accepts
//! the longest prefix of drafts that match the target's own greedy
//! samples, plus the target's token at the first mismatch (or the free
//! bonus token when everything matched) — so every verify round commits
//! between 1 and K+1 tokens.  Rejected positions are rolled back with
//! [`KvCache::truncate`], the dual of `reset()`: both the target's and
//! the drafter's cursors rewind past them, and the stale rows are simply
//! overwritten by the next run.
//!
//! Speculation is gated per slot on greedy sampling
//! (`Sampler::is_greedy`): greedy consumes no rng, so verification through
//! the slot's own sampler is bit-identical to plain decode, while a
//! temperature slot would consume a *different number* of rng draws under
//! speculation.  Temperature slots (and slots out of budget or KV
//! headroom) simply run with K = 0, which degenerates to the plain
//! one-token batched step — same code path, run length 1.  During prefill,
//! each prompt chunk is mirrored into the drafter's cache in the same
//! iteration (one extra batched drafter call, no logits), so the drafter
//! is warm the moment decoding starts; the first generated token is still
//! sampled from the TARGET's prompt logits.
//!
//! # Live plan hot-swap
//!
//! [`run_engine_swappable`] serves from an owned [`EngineSlot`] (params +
//! target engine + optional drafter) and installs replacements posted to a
//! [`SwapMailbox`] — the live half of the artifact story
//! (`crate::artifact` is the on-disk half).  A pending swap pauses
//! admissions while in-flight sequences drain on the old state; at the
//! drain point the new slot takes over with cleared arena pools and a
//! fresh prefix cache, so post-swap generations are bit-identical to a
//! fresh process started on the swapped-in artifact.  The classic
//! [`run_engine`] path borrows its engine and never swaps.
//!
//! # Determinism
//!
//! Generated tokens are bit-reproducible for any slot count / thread count
//! / chunk size / arrival pattern / speculation depth K: the batched
//! kernel is row-independent (a sequence's logits cannot depend on which
//! other sequences share the GEMM — see `decode_batch`'s bit-identity
//! contract), and every sequence samples from its own seeded `Sampler` —
//! explicitly via `DecodeRequest::seed`, or derived from the scheduler
//! seed and request id by [`sampler_seed`].  Scheduling chooses *when* a
//! sequence advances, never *what* it computes; speculative verification
//! accepts a token only when it equals what the target itself would have
//! sampled at that position, so speculation changes only how many
//! positions commit per iteration, which is what lets network generations
//! bit-match the offline path (`rust/tests/server_loopback.rs`) and
//! speculative runs bit-match plain decode
//! (`rust/tests/decode_parity.rs`).
//!
//! Latency accounting: a request's latency spans eligibility → completion
//! (queue wait included, so admission pressure is visible in p95/p99);
//! TTFT spans eligibility → first generated token; queue wait is reported
//! separately as eligibility → slot admission.  Prefill and decode phases
//! are separate kernel calls per iteration and are clocked separately
//! ([`EngineCounters::prefill_secs`] vs the decode-section clock behind
//! [`EngineCounters::decode_tok_per_sec`]), so the serving benches report
//! split prefill/decode token rates.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::kv::KvCache;
use super::kvpool;
use super::prefix::PrefixTree;
use super::sampler::{argmax, Sampler};
use crate::model::{ConfigMeta, ParamStore};
use crate::runtime::native::LogitsMode;
use crate::runtime::session::Session;
use crate::serve::{peak_rss_bytes, Engine};
use crate::tensor::{Mat, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::LatencySummary;

/// One generation request.
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    /// caller-assigned id, unique within one engine run
    pub id: usize,
    /// prompt token ids (non-empty, <= the model's seq_len)
    pub prompt: Vec<i32>,
    /// generation budget for this request
    pub max_new_tokens: usize,
    /// per-request sampling temperature (None = the scheduler default).
    /// The network front-end threads client-supplied values through these
    /// overrides so a server generation bit-matches an offline
    /// [`run_decode`] carrying the same explicit settings.
    pub temperature: Option<f32>,
    /// per-request sampler seed (None = derived via [`sampler_seed`])
    pub seed: Option<u64>,
}

impl DecodeRequest {
    /// Request with default sampling (scheduler temperature, derived seed).
    pub fn new(id: usize, prompt: Vec<i32>, max_new_tokens: usize)
               -> DecodeRequest {
        DecodeRequest { id, prompt, max_new_tokens, temperature: None,
                        seed: None }
    }
}

/// Default per-request sampler seed: scheduler seed mixed with the request
/// id, so generations are independent of slot assignment and scheduling.
pub fn sampler_seed(base: u64, id: usize) -> u64 {
    base ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Synthetic request stream for the benchmarks: random prompts (compute
/// cost is content-independent, as in the prefill load generator).
pub fn synth_requests(cfg: &ConfigMeta, n: usize, prompt_len: usize,
                      max_new_tokens: usize, seed: u64) -> Vec<DecodeRequest> {
    let mut rng = Rng::new(seed);
    let plen = prompt_len.clamp(1, cfg.seq_len);
    (0..n)
        .map(|id| DecodeRequest::new(
            id,
            (0..plen).map(|_| rng.range(1, cfg.vocab) as i32).collect(),
            max_new_tokens,
        ))
        .collect()
}

/// Synthetic fleet traffic with a REPEATED prompt prefix: every request's
/// prompt opens with the same `prefix_len` random tokens (a shared system
/// prompt / few-shot header) followed by `suffix_len` per-request random
/// tokens — the workload the prefix cache is built for.  The combined
/// length is clamped to `seq_len` (suffix first, then prefix), and every
/// prompt keeps at least one token.
pub fn synth_requests_shared_prefix(cfg: &ConfigMeta, n: usize,
                                    prefix_len: usize, suffix_len: usize,
                                    max_new_tokens: usize, seed: u64)
                                    -> Vec<DecodeRequest> {
    let mut rng = Rng::new(seed);
    let plen = (prefix_len + suffix_len).clamp(1, cfg.seq_len);
    let shared = prefix_len.min(plen);
    let prefix: Vec<i32> =
        (0..shared).map(|_| rng.range(1, cfg.vocab) as i32).collect();
    (0..n)
        .map(|id| {
            let mut prompt = prefix.clone();
            while prompt.len() < plen {
                prompt.push(rng.range(1, cfg.vocab) as i32);
            }
            DecodeRequest::new(id, prompt, max_new_tokens)
        })
        .collect()
}

/// Scheduler shape + per-request defaults for one engine run.
#[derive(Clone, Debug)]
pub struct DecodeConfig {
    /// concurrent sequences in the executing batch
    pub max_slots: usize,
    /// default generation budget (requests carry their own, already set by
    /// `synth_requests`; this caps the CLI/bench default)
    pub max_new_tokens: usize,
    /// default sampling temperature: 0 = greedy argmax; > 0 = softmax
    /// sampling at this temperature (requests may override per-request)
    pub temperature: f32,
    /// base sampler seed, mixed per request by [`sampler_seed`]
    pub seed: u64,
    /// arrival gap in scheduler iterations for [`WorkloadSource`]
    /// (deterministic schedule: request `i` becomes eligible at iteration
    /// `i * arrival_steps`); 0 saturates the queue
    pub arrival_steps: f64,
    /// prompt tokens a prefilling slot ingests per scheduler iteration
    /// (each chunk is one batched kernel call); 0 = the whole remaining
    /// prompt in a single iteration.  Smaller chunks bound per-iteration
    /// work so ongoing decode steps interleave with a long prompt's
    /// prefill; generated tokens are identical for every chunk size.
    pub prefill_chunk: usize,
    /// speculative draft depth K: tokens the drafter engine proposes per
    /// slot per iteration, all verified in one batched target call; 0
    /// disables speculation.  Takes effect only when the engine run is
    /// given a drafter, and only on greedy slots (see the module docs —
    /// generated tokens are bit-identical to plain decode for every K).
    pub speculate_k: usize,
    /// positions per paged KV block (every slot's cache and the prefix
    /// tree share this granularity); 0 selects
    /// [`super::kvpool::DEFAULT_KV_BLOCK`].  Block size never changes
    /// what a sequence computes — only how its K/V rows are stored.
    pub kv_block: usize,
    /// capacity of the prefix-sharing cache in KV blocks; 0 disables it.
    /// When enabled, admission matches each prompt against previously
    /// prefilled prompts and skips prefill for the matched block-aligned
    /// prefix (the slot's block table starts with shared read-only
    /// blocks), and completed prefills are inserted back under LRU
    /// eviction.  Generated tokens are bit-identical either way — a hit
    /// reuses the exact f32 rows a cold prefill would recompute.
    pub prefix_cache_blocks: usize,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig { max_slots: 4, max_new_tokens: 32, temperature: 0.0,
                       seed: 1, arrival_steps: 0.0, prefill_chunk: 0,
                       speculate_k: 0, kv_block: 0, prefix_cache_blocks: 0 }
    }
}

/// One finished request.
#[derive(Clone, Debug)]
pub struct CompletedRequest {
    /// the request's caller-assigned id
    pub id: usize,
    /// prompt length, tokens
    pub prompt_len: usize,
    /// generated tokens (the prompt is not echoed)
    pub tokens: Vec<i32>,
    /// eligibility → completion, ms (includes queue wait)
    pub latency_ms: f64,
    /// eligibility → first generated token, ms
    pub ttft_ms: f64,
    /// eligibility → slot admission, ms (pure queue wait)
    pub queue_ms: f64,
    /// slot admission → prompt fully ingested, ms (the prefill phase of
    /// this request's lifecycle; chunked prefill spreads it over several
    /// scheduler iterations)
    pub prefill_ms: f64,
    /// prompt ingested → completion, ms (the decode phase)
    pub decode_ms: f64,
    /// the KV arena filled before the generation budget was reached — the
    /// request got fewer tokens than it asked for because the prompt left
    /// less headroom than `max_new_tokens` (previously this truncation was
    /// silent)
    pub truncated: bool,
    /// prompt tokens served from the prefix cache (prefill skipped for
    /// them); 0 when prefix caching is disabled or the prompt missed
    pub cached_prompt_tokens: usize,
}

/// Per-token / per-completion emissions from [`run_engine`], delivered on
/// the driver thread in slot order after each iteration — never from pool
/// workers, so sinks need no synchronization of their own.
#[derive(Debug)]
pub enum DecodeEvent {
    /// the `index`-th generated token of request `id`
    Token {
        /// the request's caller-assigned id
        id: usize,
        /// 0-based position in this request's generation
        index: usize,
        /// the sampled token id
        token: i32,
        /// gap since this request's previous emission (the first token's
        /// gap is its TTFT), seconds
        gap_secs: f64,
    },
    /// request finished (budget reached or KV arena full)
    Done(CompletedRequest),
    /// one iteration's speculative verify summary: `proposed` drafter
    /// tokens entered verification, `accepted` of them matched the
    /// target's own greedy samples.  Emitted only on iterations that
    /// drafted (the server's metrics registry aggregates these into the
    /// wire acceptance rate); sinks that only care about tokens can
    /// ignore it.
    Draft {
        /// drafter tokens verified this iteration
        proposed: usize,
        /// drafter tokens the target accepted
        accepted: usize,
    },
    /// a malformed request failed admission validation (empty prompt,
    /// prompt longer than `seq_len`, zero-token budget).  Only THIS
    /// request fails — the engine loop keeps serving every other slot
    /// (previously one bad request tore down the whole run).  The offline
    /// wrapper still rejects its workload up front with a hard `Err`, and
    /// the network front-end screens at admission; this is the last line
    /// of defense for sources that let one through.
    Rejected {
        /// the request's caller-assigned id
        id: usize,
        /// human-readable validation failure
        reason: String,
    },
}

/// What a [`RequestSource`] hands the scheduler when asked for work.
pub enum SourcePoll {
    /// next request plus the instant it became eligible (latency baseline)
    Ready(DecodeRequest, Instant),
    /// nothing eligible right now, but the stream is still open
    Pending,
    /// the stream has ended: drain in-flight slots and return
    Drained,
}

/// External request feed for the long-lived scheduler loop.
pub trait RequestSource {
    /// Called once per scheduler iteration, before admission — virtual-
    /// clock sources stamp newly-eligible arrivals here so queue wait is
    /// charged even while every slot is busy.
    fn tick(&mut self, _iter: usize) {}

    /// Next request for a free slot.
    fn poll(&mut self, iter: usize) -> SourcePoll;

    /// The batch is empty and `poll` returned `Pending`: block until work
    /// may be available and return the iteration to resume at.  Virtual
    /// clocks fast-forward (discrete-event style) instead of busy-spinning;
    /// live sources wait on a condvar with a bounded timeout.
    fn idle_wait(&mut self, iter: usize) -> usize;
}

/// Fixed request list with virtual-clock arrivals — the offline benchmark
/// workload expressed as a [`RequestSource`].
pub struct WorkloadSource<'a> {
    requests: &'a [DecodeRequest],
    arrival_steps: f64,
    next: usize,
    arrivals: Vec<Option<Instant>>,
    /// lowest index whose arrival is still unstamped — arrivals are
    /// monotone in the request index, so each `tick` resumes here instead
    /// of rescanning every request (the old loop was
    /// O(requests × iterations) over a run)
    first_unstamped: usize,
}

impl<'a> WorkloadSource<'a> {
    /// Source over a fixed request list with the given arrival gap.
    pub fn new(requests: &'a [DecodeRequest], arrival_steps: f64)
               -> WorkloadSource<'a> {
        WorkloadSource {
            requests,
            arrival_steps,
            next: 0,
            arrivals: vec![None; requests.len()],
            first_unstamped: 0,
        }
    }
}

impl RequestSource for WorkloadSource<'_> {
    fn tick(&mut self, iter: usize) {
        // request `i` is due at iteration `i * arrival_steps` — monotone
        // in `i`, so the first not-yet-due index ends the scan and the
        // next tick resumes from it
        if self.first_unstamped >= self.arrivals.len() {
            return;
        }
        let now = Instant::now();
        while self.first_unstamped < self.arrivals.len()
            && (self.first_unstamped as f64) * self.arrival_steps
                <= iter as f64
        {
            self.arrivals[self.first_unstamped] = Some(now);
            self.first_unstamped += 1;
        }
    }

    fn poll(&mut self, _iter: usize) -> SourcePoll {
        if self.next >= self.requests.len() {
            return SourcePoll::Drained;
        }
        match self.arrivals[self.next] {
            Some(at) => {
                let r = self.requests[self.next].clone();
                self.next += 1;
                SourcePoll::Ready(r, at)
            }
            None => SourcePoll::Pending,
        }
    }

    fn idle_wait(&mut self, iter: usize) -> usize {
        // batch fully drained before the next arrival: fast-forward the
        // virtual clock to it instead of spinning through empty iterations
        let due = ((self.next as f64) * self.arrival_steps).ceil() as usize;
        due.max(iter + 1)
    }
}

/// Aggregate counters from one [`run_engine`] run.  Percentiles are the
/// sink's business: a long-lived server summarizes from its metrics
/// registry, [`run_decode`] from the completions it collects.
#[derive(Clone, Debug, Default)]
pub struct EngineCounters {
    /// scheduler iterations executed
    pub iterations: usize,
    /// requests that ran to completion
    pub requests_completed: usize,
    /// prompt tokens ingested through the chunked-prefill path
    pub prefill_tokens: usize,
    /// tokens generated across all requests
    pub decode_tokens: usize,
    /// whole-run wall time, seconds
    pub wall_seconds: f64,
    /// wall time spent inside the batched decode-step sections (every
    /// iteration's decode call + sampling; prefill runs as a separate
    /// kernel call and is never charged here)
    pub decode_only_secs: f64,
    /// tokens generated during those decode-step sections
    pub decode_only_tokens: usize,
    /// wall time spent inside the batched prefill-chunk kernel calls
    /// (the denominator of [`EngineCounters::prefill_tok_per_sec`])
    pub prefill_secs: f64,
    /// drafter tokens proposed into speculative verification (0 when
    /// speculation is disabled)
    pub drafted_tokens: usize,
    /// drafted tokens the target accepted — matched the target's own
    /// greedy sample at that position (rejected = drafted − accepted)
    pub accepted_draft_tokens: usize,
    /// prompt tokens served from the prefix cache across all admissions
    /// (prefill skipped for them; 0 when prefix caching is disabled)
    pub prefix_hit_tokens: usize,
    /// prompt tokens that missed the prefix cache and went through
    /// prefill (with caching disabled every prompt token counts here)
    pub prefix_miss_tokens: usize,
    /// prefix-tree blocks evicted under the capacity bound
    pub prefix_evictions: usize,
    /// live plan swaps installed by [`run_engine_swappable`] (always 0 on
    /// the borrowed [`run_engine`] path)
    pub plan_swaps: usize,
    /// requests rejected at admission validation ([`DecodeEvent::Rejected`])
    pub requests_rejected: usize,
}

impl EngineCounters {
    /// Steady-state decode throughput — the ONE definition every surface
    /// reports (`DecodeStats::decode_tok_per_sec`, the network server's
    /// session table, `benches/server_throughput.rs`): tokens generated
    /// over the wall time of the batched decode-step sections alone.
    /// Prefill runs as its own kernel call per iteration, so this stays
    /// meaningful for any chunk size — mixed iterations charge only their
    /// decode section here (the pre-PR-4 definition counted whole
    /// prefill-free iterations, which chunked prefill can starve).
    /// Returns 0.0 when no decode section ever ran: the old fallback
    /// divided `decode_tokens` by whole-run wall time, which includes
    /// queue idling, so a prefill-only run with long idle gaps reported a
    /// misleading near-zero rate instead of an unambiguous zero (read it
    /// together with [`EngineCounters::requests_completed`]).
    pub fn decode_tok_per_sec(&self) -> f64 {
        if self.decode_only_secs > 0.0 {
            self.decode_only_tokens as f64 / self.decode_only_secs
        } else {
            0.0
        }
    }

    /// Fraction of drafted tokens the target accepted (0.0 when nothing
    /// was drafted).  High acceptance is the paper's fidelity claim made
    /// operational: the closer the ZS-SVD drafter tracks the dense
    /// target's greedy choices, the more tokens each verify call commits.
    pub fn draft_acceptance_rate(&self) -> f64 {
        if self.drafted_tokens > 0 {
            self.accepted_draft_tokens as f64 / self.drafted_tokens as f64
        } else {
            0.0
        }
    }

    /// Prefill-phase throughput: prompt tokens ingested over the wall time
    /// of the batched prefill-chunk calls alone (decode iterations and
    /// queue idling excluded), so the chunked-prefill win is measurable
    /// separately from the steady-state decode rate.
    pub fn prefill_tok_per_sec(&self) -> f64 {
        if self.prefill_secs > 0.0 {
            self.prefill_tokens as f64 / self.prefill_secs
        } else {
            0.0
        }
    }
}

/// Aggregate result of one [`run_decode`] benchmark run.
#[derive(Clone, Debug)]
pub struct DecodeStats {
    /// engine label (`dense` / `lowrank-r<tag>`)
    pub engine: String,
    /// requests completed
    pub requests: usize,
    /// prompt tokens ingested
    pub prefill_tokens: usize,
    /// tokens generated
    pub decode_tokens: usize,
    /// whole-run wall time, seconds
    pub wall_seconds: f64,
    /// steady-state decode throughput: tokens generated over the wall
    /// time of the batched decode-step sections alone (prefill is a
    /// separate per-iteration kernel call and is never charged).  Most
    /// meaningful under saturating arrivals (`arrival_steps == 0`, the
    /// benchmarks' setting).
    pub decode_tok_per_sec: f64,
    /// prefill-phase throughput: prompt tokens over the wall time of the
    /// batched prefill-chunk calls alone
    /// ([`EngineCounters::prefill_tok_per_sec`])
    pub prefill_tok_per_sec: f64,
    /// prefill + decode tokens over the full wall clock
    pub total_tok_per_sec: f64,
    /// end-to-end latency summary (eligibility → completion), ms
    pub latency: LatencySummary,
    /// time-to-first-token summary, ms
    pub ttft: LatencySummary,
    /// K/V arena bytes one slot holds (f32)
    pub kv_bytes_per_slot: usize,
    /// peak RSS of the process (VmHWM), bytes
    pub peak_mem_bytes: usize,
    /// drafter tokens proposed into speculative verification (0 when
    /// speculation was off)
    pub drafted_tokens: usize,
    /// drafted tokens the target accepted
    pub accepted_draft_tokens: usize,
    /// accepted / drafted ([`EngineCounters::draft_acceptance_rate`])
    pub draft_acceptance: f64,
}

/// Per-slot in-flight sequence state.
struct Active {
    req: DecodeRequest,
    cache: KvCache,
    sampler: Sampler,
    /// drafter KV arena — present only when this slot speculates (drafter
    /// configured + greedy sampling).  Mirrors the prompt during prefill
    /// and afterwards holds a prefix of the generated tokens; its cursor
    /// may lag the target's by up to one committed token after an
    /// all-accepted verify round (the next catch-up run replays it)
    draft_cache: Option<KvCache>,
    /// prompt tokens already ingested; prefill is complete once this
    /// reaches the prompt length
    prefill_pos: usize,
    last_token: i32,
    tokens: Vec<i32>,
    /// tokens already delivered to the sink
    emitted: usize,
    /// generation budget for this request
    limit: usize,
    /// eligibility instant (latency baseline; includes queue wait)
    arrival: Instant,
    /// slot-admission instant (arrival → admitted = queue wait)
    admitted: Instant,
    /// prompt-fully-ingested instant (admitted → this = prefill phase;
    /// this → completion = decode phase)
    prefill_done_at: Option<Instant>,
    first_token_at: Option<Instant>,
    /// previous emission instant (token-gap baseline; starts at arrival)
    last_emit: Instant,
    done: bool,
    /// the KV arena filled before `limit` tokens were generated
    truncated: bool,
    /// prompt tokens adopted from the prefix cache at admission (prefill
    /// started at this position instead of 0)
    cached_prompt_tokens: usize,
}

impl Active {
    /// Still ingesting its prompt (not yet generating).
    fn prefilling(&self) -> bool {
        self.prefill_pos < self.req.prompt.len()
    }

    /// Bookkeeping after a sampled token: record it, stamp TTFT, and
    /// retire the slot once the budget or the KV arena is exhausted —
    /// flagging the latter as a truncation (the request got fewer tokens
    /// than it asked for).
    fn push_token(&mut self, tok: i32) {
        self.tokens.push(tok);
        self.last_token = tok;
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        if self.tokens.len() >= self.limit {
            self.done = true;
        } else if self.cache.len >= self.cache.max_len {
            self.done = true;
            self.truncated = true;
        }
    }
}

/// One batched engine advance over several sequences' token runs: each
/// sequence with `want_logits[s]` set gets back the next-token logits
/// after its last run token (interior prefill chunks request none and skip
/// the vocab projection).
fn step_engine_batch(sess: &Session, params: &ParamStore, engine: &Engine,
                     seqs: &mut [(&mut KvCache, &[i32])],
                     want_logits: &[bool])
                     -> Result<Vec<Option<Tensor>>> {
    match engine {
        Engine::Dense => sess.decode_batch(params, seqs, want_logits),
        Engine::Lowrank { tag, factors } => {
            sess.lowrank_decode_batch(tag, params, factors, seqs, want_logits)
        }
    }
}

/// [`step_engine_batch`] with per-sequence [`LogitsMode`] — the verify
/// half of speculation asks for all run positions' logits, the drafter
/// calls for last-row logits (or none, for prefill mirroring).
fn step_engine_batch_modes(sess: &Session, params: &ParamStore,
                           engine: &Engine,
                           seqs: &mut [(&mut KvCache, &[i32])],
                           modes: &[LogitsMode])
                           -> Result<Vec<Option<Mat>>> {
    match engine {
        Engine::Dense => sess.decode_batch_modes(params, seqs, modes),
        Engine::Lowrank { tag, factors } => {
            sess.lowrank_decode_batch_modes(tag, params, factors, seqs, modes)
        }
    }
}

// ---------------------------------------------------------------------------
// hot-swappable serving state
// ---------------------------------------------------------------------------

/// A complete, self-contained serving state: the trained parameters plus
/// the target engine and an optional speculative drafter.
///
/// [`run_engine_swappable`] owns one of these and serves from it; a live
/// replacement posted through its [`SwapMailbox`] is installed at the next
/// drain point (no sequences in flight).  `crate::artifact` packs slots
/// into content-addressed on-disk artifacts and loads them back with full
/// verification, which is how a server hot-swaps to a new compression plan
/// without restarting.
pub struct EngineSlot {
    /// the trained parameter store the engines read from
    pub params: ParamStore,
    /// the target engine (dense weights or low-rank factors)
    pub engine: Engine,
    /// optional low-rank drafter for speculative self-decode
    pub drafter: Option<Engine>,
}

impl EngineSlot {
    /// Human-readable label: the target engine's, plus the drafter's when
    /// one is attached (`dense (drafter lowrank-r40)`).
    pub fn label(&self) -> String {
        match &self.drafter {
            Some(d) => format!("{} (drafter {})", self.engine.label(),
                               d.label()),
            None => self.engine.label(),
        }
    }
}

/// Completion cell a swap requester blocks on: `Ok(new engine label)` once
/// the engine installed the slot, `Err(reason)` if the engine exited first.
type SwapCell = Arc<(Mutex<Option<Result<String, String>>>, Condvar)>;

fn swap_signal(cell: &SwapCell, result: Result<String, String>) {
    let (lock, cv) = &**cell;
    *lock.lock().unwrap_or_else(|e| e.into_inner()) = Some(result);
    cv.notify_all();
}

/// One posted swap: the replacement state plus its completion cell.
struct PendingSwap {
    slot: EngineSlot,
    done: SwapCell,
}

/// Rendezvous between the engine thread and reload requesters.
///
/// A reload posts a fully-built (already loaded and verified)
/// [`EngineSlot`] via [`request`](SwapMailbox::request) and blocks; the
/// engine loop notices the pending swap, stops admitting new work so its
/// in-flight sequences drain on the old state, installs the new slot at
/// the drain point, and completes the request with the new engine's label.
/// At most one swap can be pending at a time (a second concurrent request
/// fails fast), and the engine fails a pending request when it exits, so a
/// requester can never hang on a dead engine.
pub struct SwapMailbox {
    state: Mutex<MailboxState>,
}

#[derive(Default)]
struct MailboxState {
    pending: Option<PendingSwap>,
    closed: bool,
}

impl SwapMailbox {
    /// Empty mailbox: no swap pending, engine presumed live.
    pub fn new() -> SwapMailbox {
        SwapMailbox { state: Mutex::new(MailboxState::default()) }
    }

    /// Post `slot` and block until the engine installs it (returning the
    /// new engine's label) or exits.  Fails immediately when another swap
    /// is already in flight or the engine has already exited.
    pub fn request(&self, slot: EngineSlot) -> Result<String> {
        let done: SwapCell = Arc::new((Mutex::new(None), Condvar::new()));
        {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            anyhow::ensure!(!st.closed,
                            "engine is not running (server shutting down?)");
            anyhow::ensure!(st.pending.is_none(),
                            "another reload is already in flight");
            st.pending = Some(PendingSwap { slot, done: Arc::clone(&done) });
        }
        let (lock, cv) = &*done;
        let mut got = lock.lock().unwrap_or_else(|e| e.into_inner());
        while got.is_none() {
            got = cv.wait(got).unwrap_or_else(|e| e.into_inner());
        }
        match got.take().expect("loop exits only on Some") {
            Ok(label) => Ok(label),
            Err(msg) => Err(anyhow::anyhow!("{msg}")),
        }
    }

    /// A swap is posted and waiting for the engine's next drain point.
    pub fn pending(&self) -> bool {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
            .pending.is_some()
    }

    fn take(&self) -> Option<PendingSwap> {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).pending.take()
    }

    /// Engine exit: fail any pending request and refuse future ones.
    fn close(&self) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.closed = true;
        if let Some(p) = st.pending.take() {
            swap_signal(&p.done,
                        Err("engine exited before the swap was applied"
                            .to_string()));
        }
    }
}

impl Default for SwapMailbox {
    fn default() -> Self {
        SwapMailbox::new()
    }
}

/// What the engine loop serves from: the borrowed pieces of the classic
/// [`run_engine`] signature, or an owned [`EngineSlot`] a swap can replace.
enum Binding<'a> {
    Borrowed {
        params: &'a ParamStore,
        engine: &'a Engine,
        drafter: Option<&'a Engine>,
    },
    Owned(EngineSlot),
}

impl Binding<'_> {
    fn params(&self) -> &ParamStore {
        match self {
            Binding::Borrowed { params, .. } => params,
            Binding::Owned(s) => &s.params,
        }
    }

    fn engine(&self) -> &Engine {
        match self {
            Binding::Borrowed { engine, .. } => engine,
            Binding::Owned(s) => &s.engine,
        }
    }

    fn drafter(&self) -> Option<&Engine> {
        match self {
            Binding::Borrowed { drafter, .. } => *drafter,
            Binding::Owned(s) => s.drafter.as_ref(),
        }
    }
}

/// Run the long-lived continuous-batching scheduler until `source` drains:
/// admit from `source` into free slots, advance occupied slots through the
/// batched step/prefill kernels (one GEMM set across the batch per
/// iteration, row-parallel on the persistent `exec` pool), and deliver
/// every generated token and completion to `sink` in slot order.
///
/// `drafter` enables speculative self-decode when paired with
/// [`DecodeConfig::speculate_k`] > 0: greedy slots propose up to K tokens
/// per iteration through the drafter engine and `engine` (the target)
/// verifies them in one batched all-positions call — generated tokens are
/// bit-identical to running without a drafter (see the module docs).
/// `None` runs plain decode regardless of `speculate_k`.
///
/// Engine errors (a failing step kernel) abort the run; request validation
/// is layered — the offline wrapper checks its whole workload up front
/// (callers get a hard `Err` before any compute) and the network front-end
/// screens at admission, but a malformed request that still reaches the
/// scheduler fails ALONE with a [`DecodeEvent::Rejected`] emission instead
/// of tearing down the engine loop and every other in-flight generation
/// with it.
pub fn run_engine(sess: &Session, params: &ParamStore, engine: &Engine,
                  drafter: Option<&Engine>, cfg: &DecodeConfig,
                  source: &mut dyn RequestSource,
                  sink: &mut dyn FnMut(DecodeEvent))
                  -> Result<EngineCounters> {
    run_engine_inner(sess, Binding::Borrowed { params, engine, drafter },
                     cfg, source, sink, None)
}

/// [`run_engine`] over an owned, hot-swappable [`EngineSlot`].
///
/// While a swap posted to `mailbox` is pending, the loop stops admitting
/// new requests (they stay queued at the source) and in-flight sequences
/// finish on the old state.  Once every slot has drained, the new slot is
/// installed, the pooled KV arenas are dropped (their rows were computed
/// under the old weights) and the prefix cache is rebuilt empty — no state
/// derived from the old plan survives into post-swap generations, which is
/// what makes a swapped-in artifact produce output **bit-identical** to a
/// fresh process started on it (`rust/tests/server_loopback.rs`).  The
/// mailbox is closed on exit, failing any still-pending request instead of
/// leaving its requester blocked.
pub fn run_engine_swappable(sess: &Session, slot: EngineSlot,
                            cfg: &DecodeConfig,
                            source: &mut dyn RequestSource,
                            sink: &mut dyn FnMut(DecodeEvent),
                            mailbox: &SwapMailbox)
                            -> Result<EngineCounters> {
    let r = run_engine_inner(sess, Binding::Owned(slot), cfg, source, sink,
                             Some(mailbox));
    mailbox.close();
    r
}

fn run_engine_inner(sess: &Session, mut binding: Binding<'_>,
                    cfg: &DecodeConfig, source: &mut dyn RequestSource,
                    sink: &mut dyn FnMut(DecodeEvent),
                    mailbox: Option<&SwapMailbox>)
                    -> Result<EngineCounters> {
    anyhow::ensure!(cfg.max_slots >= 1, "decode needs at least one slot");

    let start = Instant::now();
    let mut slots: Vec<Option<Active>> = Vec::new();
    for _ in 0..cfg.max_slots {
        slots.push(None);
    }
    // rewound arenas from retired slots, reused by later admissions
    let mut arena_pool: Vec<KvCache> = Vec::new();
    // same, for the drafter arenas of speculating slots
    let mut draft_pool: Vec<KvCache> = Vec::new();
    // the prefix-sharing cache: prompts of completed prefills keyed by
    // block-sized token runs, holding shared refs into the paged pool.
    // Drops (and releases every held block) when the run returns.
    let block = if cfg.kv_block == 0 { kvpool::DEFAULT_KV_BLOCK }
                else { cfg.kv_block };
    let mut tree = (cfg.prefix_cache_blocks > 0)
        .then(|| PrefixTree::new(block, cfg.prefix_cache_blocks));
    let mut c = EngineCounters::default();
    let mut iter = 0usize;
    let mut drained = false;

    loop {
        // a posted swap installs at the drain point: admissions pause (new
        // requests stay queued at the source) while in-flight sequences
        // finish on the old state, then the new slot takes over with fresh
        // pools and an empty prefix cache
        let mut swap_wait = false;
        if let Some(m) = mailbox {
            if m.pending() && !drained {
                if slots.iter().any(Option::is_some) {
                    swap_wait = true;
                } else if let Some(PendingSwap { slot, done }) = m.take() {
                    let t_swap = Instant::now();
                    let label = slot.label();
                    binding = Binding::Owned(slot);
                    // nothing computed under the old weights may survive:
                    // pooled arenas and the prefix tree hold old-plan KV
                    // rows, so they are dropped, not recycled
                    arena_pool.clear();
                    draft_pool.clear();
                    if tree.is_some() {
                        tree = Some(PrefixTree::new(
                            block, cfg.prefix_cache_blocks));
                    }
                    c.plan_swaps += 1;
                    crate::obs::counter_add("artifact.swaps", 1);
                    if crate::obs::enabled() {
                        crate::obs::emit_span(
                            "plan_swap", "sched", crate::obs::us_of(t_swap),
                            t_swap.elapsed().as_micros() as u64,
                            crate::obs::PID_ENGINE, crate::obs::tid(),
                            vec![("engine", Json::str(&label))]);
                    }
                    swap_signal(&done, Ok(label));
                }
            }
        }

        // speculation needs both the knob and a drafter engine — re-derived
        // every iteration because a swap can attach or detach the drafter
        let spec_k = if binding.drafter().is_some() { cfg.speculate_k }
                     else { 0 };

        source.tick(iter);

        // admit pending requests into free slots, in source order
        if !drained && !swap_wait {
            'admit: for slot in slots.iter_mut() {
                if slot.is_some() {
                    continue;
                }
                // a rejected request re-polls for the same slot, so one
                // bad request can never leave a slot idle while valid
                // work queues behind it
                loop {
                    match source.poll(iter) {
                        SourcePoll::Ready(req, arrival) => {
                            let reason = if req.prompt.is_empty() {
                                Some("empty prompt".to_string())
                            } else if req.prompt.len() > sess.cfg.seq_len {
                                Some(format!(
                                    "prompt {} exceeds seq_len {}",
                                    req.prompt.len(), sess.cfg.seq_len))
                            } else if req.max_new_tokens < 1 {
                                Some("max_new_tokens must be >= 1 \
                                      (a zero-token generation is a caller \
                                      error)".to_string())
                            } else {
                                None
                            };
                            if let Some(reason) = reason {
                                c.requests_rejected += 1;
                                sink(DecodeEvent::Rejected {
                                    id: req.id,
                                    reason,
                                });
                                continue;
                            }
                            let mut cache = match arena_pool.pop() {
                                Some(mut cached) => {
                                    cached.reset();
                                    cached
                                }
                                None => KvCache::with_block(&sess.cfg,
                                                            cfg.kv_block),
                            };
                            // prefix-cache lookup: adopt the matched
                            // block-aligned prefix (shared read-only
                            // blocks — prefill skips straight past them)
                            // and charge the hit/miss split.  The lookup's
                            // returned refs are released after adoption
                            // clones its own; the tree still holds the
                            // blocks either way.
                            let mut cached_prompt_tokens = 0usize;
                            if let Some(tree) = tree.as_mut() {
                                let (blocks, matched) =
                                    tree.lookup(&req.prompt);
                                if matched > 0 {
                                    cache.adopt_prefix(&blocks, matched);
                                    cached_prompt_tokens = matched;
                                }
                                for b in blocks {
                                    kvpool::release(b);
                                }
                            }
                            c.prefix_hit_tokens += cached_prompt_tokens;
                            c.prefix_miss_tokens +=
                                req.prompt.len() - cached_prompt_tokens;
                            if cached_prompt_tokens > 0 {
                                crate::obs::counter_add(
                                    "prefix.hit_tokens",
                                    cached_prompt_tokens as u64);
                            }
                            crate::obs::counter_add(
                                "prefix.miss_tokens",
                                (req.prompt.len() - cached_prompt_tokens)
                                    as u64);
                            let sampler = Sampler::new(
                                req.temperature.unwrap_or(cfg.temperature),
                                req.seed.unwrap_or_else(
                                    || sampler_seed(cfg.seed, req.id)),
                            );
                            // only greedy slots speculate: temperature
                            // sampling consumes rng per draw, so verifying
                            // K positions would change the random stream
                            // (module docs)
                            let draft_cache = (spec_k > 0
                                               && sampler.is_greedy())
                                .then(|| match draft_pool.pop() {
                                    Some(mut cached) => {
                                        cached.reset();
                                        cached
                                    }
                                    None => KvCache::with_block(&sess.cfg,
                                                                cfg.kv_block),
                                });
                            let now = Instant::now();
                            let limit = req.max_new_tokens;
                            // generation can never exceed the KV capacity,
                            // so a huge client-supplied budget must not
                            // drive a huge pre-allocation
                            let cap = limit.min(sess.cfg.seq_len);
                            *slot = Some(Active {
                                cache,
                                sampler,
                                draft_cache,
                                prefill_pos: cached_prompt_tokens,
                                last_token: 0,
                                tokens: Vec::with_capacity(cap),
                                emitted: 0,
                                limit,
                                arrival,
                                admitted: now,
                                prefill_done_at: None,
                                first_token_at: None,
                                last_emit: arrival,
                                done: false,
                                truncated: false,
                                cached_prompt_tokens,
                                req,
                            });
                            break;
                        }
                        SourcePoll::Pending => break 'admit,
                        SourcePoll::Drained => {
                            drained = true;
                            break 'admit;
                        }
                    }
                }
            }
        }

        if !slots.iter().any(Option::is_some) {
            if drained {
                break;
            }
            iter = source.idle_wait(iter);
            continue;
        }

        // advance the batch with a bounded number of batched kernel calls:
        // the decode advance across every decoding slot (draft + verify
        // when speculating, a single one-token step otherwise — the slots'
        // hidden states share one activation matrix per layer either way),
        // then one prompt-chunk ingest across every prefilling slot.  Long
        // prompts make bounded, chunk-sized progress alongside the
        // decoding slots.
        let had_prefill = slots
            .iter()
            .any(|s| s.as_ref().is_some_and(Active::prefilling));

        // --- batched decode advance across decoding slots ---
        {
            // collect the decoding slots once; every phase below (draft,
            // verify, accept) walks this in slot order
            let mut act: Vec<&mut Active> = slots
                .iter_mut()
                .filter_map(Option::as_mut)
                .filter(|a| !a.prefilling())
                .collect();
            if !act.is_empty() {
                let t_step = Instant::now();
                // per-slot draft depth: the configured K, capped by the
                // remaining budget (a round commits up to k+1 tokens, all
                // of which must fit) and by the KV headroom the k+1-token
                // verify run needs.  0 = the plain one-token step.
                let keff: Vec<usize> = act
                    .iter()
                    .map(|a| {
                        if a.draft_cache.is_none() {
                            return 0;
                        }
                        let budget = a.limit - a.tokens.len();
                        let headroom = a.cache.max_len - a.cache.len;
                        spec_k.min(budget - 1).min(headroom - 1)
                    })
                    .collect();

                // drafter proposals per slot (empty when keff == 0)
                let mut drafts: Vec<Vec<i32>> =
                    act.iter().map(|_| Vec::new()).collect();
                let max_k = keff.iter().copied().max().unwrap_or(0);
                let t_draft = Instant::now();
                if max_k > 0 {
                    let draft_engine = binding.drafter().expect("spec_k > 0");
                    // catch-up + first draft: one ragged batched call
                    // feeding each drafting slot whatever its drafter has
                    // not ingested yet (always at least the pending
                    // generated token); the last row's argmax is draft 1.
                    // A prefix-cache hit shortens the TARGET's prefill but
                    // the drafter's mirrored cache replays the full prompt
                    // (drafter arenas never share blocks with the tree),
                    // so the run may start with a prompt remainder — hence
                    // the owned runs instead of `&tokens[seen..]` slices.
                    let catchups: Vec<Vec<i32>> = act
                        .iter()
                        .enumerate()
                        .map(|(di, a)| {
                            if keff[di] == 0 {
                                return Vec::new();
                            }
                            let draft = a.draft_cache.as_ref()
                                .expect("keff > 0 implies a draft cache");
                            let plen = a.req.prompt.len();
                            if draft.len < plen {
                                let mut run =
                                    a.req.prompt[draft.len..].to_vec();
                                run.extend_from_slice(&a.tokens);
                                run
                            } else {
                                a.tokens[draft.len - plen..].to_vec()
                            }
                        })
                        .collect();
                    let logits = {
                        let mut seqs: Vec<(&mut KvCache, &[i32])> =
                            Vec::new();
                        for (di, a) in act.iter_mut().enumerate() {
                            if keff[di] == 0 {
                                continue;
                            }
                            let draft = a.draft_cache
                                .as_mut()
                                .expect("keff > 0 implies a draft cache");
                            seqs.push((draft, &catchups[di][..]));
                        }
                        let modes = vec![LogitsMode::Last; seqs.len()];
                        step_engine_batch_modes(sess, binding.params(),
                                                draft_engine, &mut seqs,
                                                &modes)?
                    };
                    let mut w = 0usize;
                    for di in 0..act.len() {
                        if keff[di] == 0 {
                            continue;
                        }
                        let l = logits[w].as_ref()
                            .expect("draft logits requested");
                        // the drafter proposes greedily (speculating slots
                        // are greedy, and argmax consumes no rng)
                        drafts[di].push(argmax(l.row(0)) as i32);
                        w += 1;
                    }
                    // drafts 2..K: single-token drafter steps, batched
                    // across the slots still drafting
                    for step in 1..max_k {
                        let feed: Vec<i32> = (0..act.len())
                            .filter(|&di| keff[di] > step)
                            .map(|di| drafts[di][step - 1])
                            .collect();
                        if feed.is_empty() {
                            break;
                        }
                        let logits = {
                            let mut seqs: Vec<(&mut KvCache, &[i32])> =
                                Vec::new();
                            let mut f = 0usize;
                            for (di, a) in act.iter_mut().enumerate() {
                                if keff[di] <= step {
                                    continue;
                                }
                                let draft =
                                    a.draft_cache.as_mut().expect("drafting");
                                seqs.push((draft,
                                           std::slice::from_ref(&feed[f])));
                                f += 1;
                            }
                            let modes = vec![LogitsMode::Last; seqs.len()];
                            step_engine_batch_modes(sess, binding.params(),
                                                    draft_engine, &mut seqs,
                                                    &modes)?
                        };
                        let mut f = 0usize;
                        for di in 0..act.len() {
                            if keff[di] <= step {
                                continue;
                            }
                            let l = logits[f].as_ref()
                                .expect("draft logits requested");
                            drafts[di].push(argmax(l.row(0)) as i32);
                            f += 1;
                        }
                    }
                }

                if max_k > 0 {
                    crate::obs::counter_add("phase.draft_ns",
                                            t_draft.elapsed().as_nanos()
                                                as u64);
                }

                // verify: ONE batched target call scores every slot's
                // [pending, drafts..] run with logits at ALL positions.  A
                // draft-free run has length 1 — exactly the plain batched
                // one-token decode step
                let runs: Vec<Vec<i32>> = act
                    .iter()
                    .enumerate()
                    .map(|(di, a)| {
                        let mut r = Vec::with_capacity(1 + drafts[di].len());
                        r.push(a.last_token);
                        r.extend_from_slice(&drafts[di]);
                        r
                    })
                    .collect();
                let t_verify = Instant::now();
                let logits = {
                    let mut seqs: Vec<(&mut KvCache, &[i32])> =
                        Vec::with_capacity(act.len());
                    for (di, a) in act.iter_mut().enumerate() {
                        seqs.push((&mut a.cache, &runs[di][..]));
                    }
                    let modes = vec![LogitsMode::All; seqs.len()];
                    step_engine_batch_modes(sess, binding.params(),
                                            binding.engine(), &mut seqs,
                                            &modes)?
                };
                crate::obs::counter_add("phase.verify_ns",
                                        t_verify.elapsed().as_nanos() as u64);

                // accept, on the driver thread in slot order: verify row i
                // is the target's distribution after run position i, so
                // the slot's own sampler replays exactly the tokens plain
                // decode would produce — accept drafts while they match,
                // commit the target's token at the first mismatch, and
                // take the free bonus token when every draft matched
                let (mut proposed, mut accepted_drafts) = (0usize, 0usize);
                let mut committed = 0usize;
                for (di, a) in act.iter_mut().enumerate() {
                    let lm = logits[di].as_ref()
                        .expect("verify logits requested");
                    let k = drafts[di].len();
                    let len_before = a.cache.len - runs[di].len();
                    let m_before = a.tokens.len();
                    let mut acc: Vec<i32> = Vec::with_capacity(k + 1);
                    let mut matched = 0usize;
                    for i in 0..k {
                        let x = a.sampler.sample(lm.row(i)) as i32;
                        acc.push(x);
                        if x != drafts[di][i] {
                            break;
                        }
                        matched += 1;
                    }
                    if matched == k {
                        // all drafts matched (or none were made): the
                        // final row's sample rides along for free
                        acc.push(a.sampler.sample(lm.row(k)) as i32);
                    }
                    // rewind the target past rejected draft positions
                    // BEFORE recording tokens, so push_token's capacity
                    // check sees the real cursor
                    a.cache.truncate(len_before + acc.len());
                    if k > 0 {
                        // the drafter ingested the catch-up run plus
                        // drafts 1..K-1; keep the prefix consistent with
                        // the committed stream (a full accept rewinds
                        // nothing — the drafter just lags one token, which
                        // the next catch-up run replays)
                        let keep = a.req.prompt.len() + m_before
                            + (acc.len() - 1).min(k - 1);
                        if let Some(draft) = a.draft_cache.as_mut() {
                            draft.truncate(keep);
                        }
                    }
                    proposed += k;
                    accepted_drafts += matched;
                    committed += acc.len();
                    for x in acc {
                        a.push_token(x);
                    }
                }
                // the decode section is its own set of kernel calls, so
                // its clock is clean even when the same iteration also
                // prefills a chunk — charge it always (a prefill-free-
                // iterations-only clock would starve under small chunk
                // sizes and steady admissions).  Drafter calls are decode
                // work and are charged here too.
                let step_el = t_step.elapsed();
                c.decode_only_secs += step_el.as_secs_f64();
                c.decode_only_tokens += committed;
                c.drafted_tokens += proposed;
                c.accepted_draft_tokens += accepted_drafts;
                crate::obs::counter_add("phase.decode_ns",
                                        step_el.as_nanos() as u64);
                if crate::obs::enabled() {
                    // gated here (not just inside emit_span) so the args
                    // vec is never built on the disabled path
                    crate::obs::emit_span(
                        "decode_step", "sched", crate::obs::us_of(t_step),
                        step_el.as_micros() as u64, crate::obs::PID_ENGINE,
                        crate::obs::tid(),
                        vec![("slots", Json::num(act.len() as f64)),
                             ("committed", Json::num(committed as f64)),
                             ("drafted", Json::num(proposed as f64))]);
                }
                if proposed > 0 {
                    sink(DecodeEvent::Draft {
                        proposed,
                        accepted: accepted_drafts,
                    });
                }
            }
        }

        // --- chunked prefill across prefilling slots ---
        if had_prefill {
            let t_pre = Instant::now();
            // the chunk plan is computed ONCE and replayed below, so the
            // logits index can never drift from the slot it belongs to
            let (logits, takes) = {
                let mut seqs: Vec<(&mut KvCache, &[i32])> = Vec::new();
                let mut takes: Vec<usize> = Vec::new();
                let mut want: Vec<bool> = Vec::new();
                for s in slots.iter_mut() {
                    let Some(a) = s else { continue };
                    if !a.prefilling() {
                        continue;
                    }
                    let rem = a.req.prompt.len() - a.prefill_pos;
                    let take = match cfg.prefill_chunk {
                        0 => rem,
                        chunk => rem.min(chunk),
                    };
                    seqs.push((&mut a.cache,
                               &a.req.prompt[a.prefill_pos
                                   ..a.prefill_pos + take]));
                    takes.push(take);
                    // only a prompt-completing chunk feeds the sampler
                    want.push(take == rem);
                }
                (step_engine_batch(sess, binding.params(), binding.engine(),
                                   &mut seqs, &want)?,
                 takes)
            };
            // mirror prompt chunks into the drafter caches of the
            // speculating slots — one extra batched drafter call, no
            // logits requested (so no vocab GEMM).  The drafter walks the
            // FULL prompt on its own cursor: a prefix-cache hit starts the
            // target's prefill at the matched position, but drafter arenas
            // never share blocks with the tree, so the drafter replays
            // tokens 0.. itself (any remainder left when the target
            // finishes first is picked up by the decode-phase catch-up
            // run).  The FIRST generated token is still sampled from the
            // target's prompt logits below, preserving bit-identity.
            if let Some(draft_engine) = binding.drafter() {
                let mut seqs: Vec<(&mut KvCache, &[i32])> = Vec::new();
                for s in slots.iter_mut() {
                    let Some(a) = s else { continue };
                    if !a.prefilling() {
                        continue;
                    }
                    let Active { draft_cache, req, .. } = a;
                    let Some(draft) = draft_cache.as_mut() else { continue };
                    let at = draft.len;
                    let rem = req.prompt.len() - at;
                    if rem == 0 {
                        continue;
                    }
                    let take = match cfg.prefill_chunk {
                        0 => rem,
                        chunk => rem.min(chunk),
                    };
                    seqs.push((draft, &req.prompt[at..at + take]));
                }
                if !seqs.is_empty() {
                    let modes = vec![LogitsMode::None; seqs.len()];
                    step_engine_batch_modes(sess, binding.params(),
                                            draft_engine, &mut seqs,
                                            &modes)?;
                }
            }
            let pre_el = t_pre.elapsed();
            c.prefill_secs += pre_el.as_secs_f64();
            crate::obs::counter_add("phase.prefill_ns",
                                    pre_el.as_nanos() as u64);
            if crate::obs::enabled() {
                let toks: usize = takes.iter().sum();
                crate::obs::emit_span(
                    "prefill_chunk", "sched", crate::obs::us_of(t_pre),
                    pre_el.as_micros() as u64, crate::obs::PID_ENGINE,
                    crate::obs::tid(),
                    vec![("slots", Json::num(takes.len() as f64)),
                         ("tokens", Json::num(toks as f64))]);
            }
            let mut k = 0usize;
            for s in slots.iter_mut() {
                let Some(a) = s else { continue };
                if !a.prefilling() {
                    continue;
                }
                let take = takes[k];
                a.prefill_pos += take;
                c.prefill_tokens += take;
                if !a.prefilling() {
                    // prompt fully ingested: the final chunk's logits are
                    // the last prompt position's — sample the first token
                    a.prefill_done_at = Some(Instant::now());
                    // publish this prompt's full blocks to the prefix
                    // cache (ref-bumps blocks already present, shares the
                    // fresh ones; never the drafter's mirror).  Future
                    // writes into a now-shared block copy-on-write — the
                    // slot keeps decoding unperturbed.
                    if let Some(tree) = tree.as_mut() {
                        tree.insert(&a.req.prompt, &a.cache);
                        let ev = tree.evictions() as usize;
                        if ev > c.prefix_evictions {
                            crate::obs::counter_add(
                                "prefix.evictions",
                                (ev - c.prefix_evictions) as u64);
                            c.prefix_evictions = ev;
                        }
                    }
                    let l = logits[k].as_ref()
                        .expect("final-chunk logits requested");
                    let tok = a.sampler.sample(&l.data) as i32;
                    a.push_token(tok);
                }
                k += 1;
            }
        }

        // emit new tokens and retire finished sequences, in slot order;
        // retired arenas go back to the pool
        for slot in slots.iter_mut() {
            let Some(a) = slot.as_mut() else { continue };
            while a.emitted < a.tokens.len() {
                let now = Instant::now();
                let gap = now.duration_since(a.last_emit).as_secs_f64();
                a.last_emit = now;
                sink(DecodeEvent::Token {
                    id: a.req.id,
                    index: a.emitted,
                    token: a.tokens[a.emitted],
                    gap_secs: gap,
                });
                a.emitted += 1;
            }
            if !a.done {
                continue;
            }
            let mut a = slot.take().expect("checked occupied");
            let now = Instant::now();
            c.requests_completed += 1;
            c.decode_tokens += a.tokens.len();
            // phase split: admitted → prompt ingested → completion (a
            // completed request always generated at least one token, so
            // prefill_done_at is stamped; `now` is a defensive fallback)
            let prefill_done = a.prefill_done_at.unwrap_or(now);
            let queue_ms =
                a.admitted.duration_since(a.arrival).as_secs_f64() * 1e3;
            let prefill_ms =
                prefill_done.duration_since(a.admitted).as_secs_f64() * 1e3;
            let decode_ms =
                now.duration_since(prefill_done).as_secs_f64() * 1e3;
            if crate::obs::enabled() {
                // request-lifecycle track: tid = request id, so a trace
                // viewer renders one queue→prefill→decode row per request
                let id = a.req.id as u64;
                let us = crate::obs::us_of;
                crate::obs::emit_span(
                    "queue", "request", us(a.arrival),
                    (queue_ms * 1e3) as u64, crate::obs::PID_REQUESTS, id,
                    vec![]);
                crate::obs::emit_span(
                    "prefill", "request", us(a.admitted),
                    (prefill_ms * 1e3) as u64, crate::obs::PID_REQUESTS, id,
                    vec![("prompt_len",
                          Json::num(a.req.prompt.len() as f64))]);
                crate::obs::emit_span(
                    "decode", "request", us(prefill_done),
                    (decode_ms * 1e3) as u64, crate::obs::PID_REQUESTS, id,
                    vec![("tokens", Json::num(a.tokens.len() as f64)),
                         ("truncated", Json::Bool(a.truncated))]);
                crate::obs::counter_add("sched.requests_done", 1);
            }
            sink(DecodeEvent::Done(CompletedRequest {
                id: a.req.id,
                prompt_len: a.req.prompt.len(),
                tokens: std::mem::take(&mut a.tokens),
                latency_ms: now.duration_since(a.arrival).as_secs_f64() * 1e3,
                ttft_ms: a
                    .first_token_at
                    .map(|t| t.duration_since(a.arrival).as_secs_f64() * 1e3)
                    .unwrap_or(0.0),
                queue_ms,
                prefill_ms,
                decode_ms,
                truncated: a.truncated,
                cached_prompt_tokens: a.cached_prompt_tokens,
            }));
            if let Some(d) = a.draft_cache.take() {
                draft_pool.push(d);
            }
            arena_pool.push(a.cache);
        }

        // always-on occupancy gauges: the server's `metrics` wire snapshot
        // reads these whether or not tracing is enabled, so they bypass the
        // gated hooks (a handful of map writes per ~ms-scale iteration)
        let active = slots.iter().flatten().count();
        let kv_tokens: usize =
            slots.iter().flatten().map(|a| a.cache.len).sum();
        let kv_capacity: usize =
            slots.iter().flatten().map(|a| a.cache.max_len).sum();
        crate::obs::gauge_set("sched.slots_active", active as f64);
        crate::obs::gauge_set("sched.slots_max", cfg.max_slots as f64);
        crate::obs::gauge_set("sched.arena_pool", arena_pool.len() as f64);
        crate::obs::gauge_set("sched.draft_pool", draft_pool.len() as f64);
        crate::obs::gauge_set("sched.kv_tokens", kv_tokens as f64);
        crate::obs::gauge_set("sched.kv_capacity", kv_capacity as f64);
        let pool = kvpool::stats();
        crate::obs::gauge_set("kvpool.blocks_used", pool.live_blocks as f64);
        crate::obs::gauge_set("kvpool.blocks_free", pool.free_blocks as f64);
        if let Some(tree) = tree.as_ref() {
            crate::obs::gauge_set("prefix.chains", tree.chains() as f64);
            crate::obs::gauge_set("prefix.blocks",
                                  tree.held_blocks() as f64);
            crate::obs::gauge_set("prefix.shared_bytes",
                                  tree.shared_bytes() as f64);
            crate::obs::gauge_set("prefix.hit_tokens",
                                  c.prefix_hit_tokens as f64);
            crate::obs::gauge_set("prefix.miss_tokens",
                                  c.prefix_miss_tokens as f64);
            crate::obs::gauge_set("prefix.evictions",
                                  c.prefix_evictions as f64);
        }

        iter += 1;
    }

    c.iterations = iter;
    c.wall_seconds = start.elapsed().as_secs_f64();
    Ok(c)
}

/// Run the fixed-workload generation benchmark: [`run_engine`] over a
/// [`WorkloadSource`].  Returns aggregate stats plus every completed
/// request (sorted by id; generated tokens are deterministic for a given
/// engine + config).
pub fn run_decode(sess: &Session, params: &ParamStore, engine: &Engine,
                  requests: &[DecodeRequest], cfg: &DecodeConfig)
                  -> Result<(DecodeStats, Vec<CompletedRequest>)> {
    run_decode_inner(sess, params, engine, None, requests, cfg)
}

/// [`run_decode`] with speculative self-decode: `drafter` proposes
/// [`DecodeConfig::speculate_k`] tokens per slot per iteration and
/// `engine` (the target) verifies them in one batched call.  Generated
/// tokens are bit-identical to [`run_decode`] on the target alone — only
/// throughput and the draft counters change.  The stats row is labeled
/// `<target>+spec-k<K>` so bench tables keep one row per configuration.
pub fn run_decode_speculative(sess: &Session, params: &ParamStore,
                              engine: &Engine, drafter: &Engine,
                              requests: &[DecodeRequest], cfg: &DecodeConfig)
                              -> Result<(DecodeStats, Vec<CompletedRequest>)> {
    run_decode_inner(sess, params, engine, Some(drafter), requests, cfg)
}

fn run_decode_inner(sess: &Session, params: &ParamStore, engine: &Engine,
                    drafter: Option<&Engine>, requests: &[DecodeRequest],
                    cfg: &DecodeConfig)
                    -> Result<(DecodeStats, Vec<CompletedRequest>)> {
    anyhow::ensure!(!requests.is_empty(), "no decode requests");
    for r in requests {
        anyhow::ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
        anyhow::ensure!(r.prompt.len() <= sess.cfg.seq_len,
                        "request {}: prompt {} exceeds seq_len {}",
                        r.id, r.prompt.len(), sess.cfg.seq_len);
        anyhow::ensure!(r.max_new_tokens >= 1,
                        "request {}: max_new_tokens must be >= 1 \
                         (a zero-token generation is a caller error)",
                        r.id);
    }

    let mut source = WorkloadSource::new(requests, cfg.arrival_steps);
    let mut done: Vec<CompletedRequest> = Vec::with_capacity(requests.len());
    let counters = {
        let mut sink = |ev: DecodeEvent| {
            if let DecodeEvent::Done(c) = ev {
                done.push(c);
            }
        };
        run_engine(sess, params, engine, drafter, cfg, &mut source,
                   &mut sink)?
    };

    done.sort_by_key(|c| c.id);
    let lats: Vec<f64> = done.iter().map(|c| c.latency_ms).collect();
    let ttfts: Vec<f64> = done.iter().map(|c| c.ttft_ms).collect();
    let label = if drafter.is_some() && cfg.speculate_k > 0 {
        format!("{}+spec-k{}", engine.label(), cfg.speculate_k)
    } else {
        engine.label()
    };
    let stats = DecodeStats {
        engine: label,
        requests: done.len(),
        prefill_tokens: counters.prefill_tokens,
        decode_tokens: counters.decode_tokens,
        wall_seconds: counters.wall_seconds,
        decode_tok_per_sec: counters.decode_tok_per_sec(),
        prefill_tok_per_sec: counters.prefill_tok_per_sec(),
        total_tok_per_sec: (counters.prefill_tokens + counters.decode_tokens)
            as f64
            / counters.wall_seconds,
        latency: LatencySummary::from_samples(&lats),
        ttft: LatencySummary::from_samples(&ttfts),
        kv_bytes_per_slot: KvCache::arena_bytes_for(&sess.cfg),
        peak_mem_bytes: peak_rss_bytes(),
        drafted_tokens: counters.drafted_tokens,
        accepted_draft_tokens: counters.accepted_draft_tokens,
        draft_acceptance: counters.draft_acceptance_rate(),
    };
    Ok((stats, done))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_requests_shapes() {
        let cfg = crate::model::Manifest::builtin().config("tiny").clone();
        let reqs = synth_requests(&cfg, 5, 16, 8, 1);
        assert_eq!(reqs.len(), 5);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.prompt.len(), 16);
            assert_eq!(r.max_new_tokens, 8);
            assert!(r.temperature.is_none() && r.seed.is_none());
            assert!(r.prompt.iter().all(|&t| t >= 1 && (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn synth_prompt_len_clamped_to_seq() {
        let cfg = crate::model::Manifest::builtin().config("tiny").clone();
        let reqs = synth_requests(&cfg, 1, 10 * cfg.seq_len, 4, 2);
        assert_eq!(reqs[0].prompt.len(), cfg.seq_len);
        let reqs = synth_requests(&cfg, 1, 0, 4, 2);
        assert_eq!(reqs[0].prompt.len(), 1);
    }

    #[test]
    fn shared_prefix_requests_share_exactly_the_prefix() {
        let cfg = crate::model::Manifest::builtin().config("tiny").clone();
        let reqs = synth_requests_shared_prefix(&cfg, 4, 8, 5, 2, 9);
        assert_eq!(reqs.len(), 4);
        for r in &reqs {
            assert_eq!(r.prompt.len(), 13);
            assert_eq!(r.prompt[..8], reqs[0].prompt[..8]);
        }
        // per-request suffixes are independent draws
        assert_ne!(reqs[0].prompt[8..], reqs[1].prompt[8..]);
        // combined length clamps to seq_len; degenerate lengths keep one
        // token (the request stays valid)
        let long =
            synth_requests_shared_prefix(&cfg, 1, 10 * cfg.seq_len, 10, 2, 9);
        assert_eq!(long[0].prompt.len(), cfg.seq_len);
        let tiny = synth_requests_shared_prefix(&cfg, 1, 0, 0, 2, 9);
        assert_eq!(tiny[0].prompt.len(), 1);
    }

    #[test]
    fn workload_source_respects_virtual_clock() {
        let reqs: Vec<DecodeRequest> =
            (0..3).map(|i| DecodeRequest::new(i, vec![1], 2)).collect();
        let mut src = WorkloadSource::new(&reqs, 2.0);
        // iter 0: only request 0 is eligible
        src.tick(0);
        assert!(matches!(src.poll(0), SourcePoll::Ready(r, _) if r.id == 0));
        assert!(matches!(src.poll(0), SourcePoll::Pending));
        // fast-forward lands exactly on request 1's due iteration
        assert_eq!(src.idle_wait(0), 2);
        src.tick(2);
        assert!(matches!(src.poll(2), SourcePoll::Ready(r, _) if r.id == 1));
        src.tick(4);
        assert!(matches!(src.poll(4), SourcePoll::Ready(r, _) if r.id == 2));
        assert!(matches!(src.poll(4), SourcePoll::Drained));
    }

    fn dummy_slot() -> EngineSlot {
        EngineSlot {
            params: ParamStore::new_empty(Vec::new()),
            engine: Engine::Dense,
            drafter: None,
        }
    }

    #[test]
    fn engine_slot_labels() {
        let mut s = dummy_slot();
        assert_eq!(s.label(), "dense");
        s.drafter = Some(Engine::Lowrank {
            tag: "40".into(),
            factors: std::collections::BTreeMap::new(),
        });
        assert_eq!(s.label(), "dense (drafter lowrank-r40)");
    }

    #[test]
    fn swap_mailbox_rejects_double_post_and_post_after_close() {
        let m = SwapMailbox::new();
        assert!(!m.pending());
        // requester blocks on the cell, so drive the post/complete halves
        // from two threads: one posts, the "engine" takes + signals
        std::thread::scope(|s| {
            let h = s.spawn(|| m.request(dummy_slot()));
            // wait for the post to land, then a second post must fail fast
            while !m.pending() {
                std::thread::yield_now();
            }
            let second = m.request(dummy_slot());
            assert!(second.is_err(), "double post must fail");
            assert!(second.unwrap_err().to_string().contains("in flight"));
            let p = m.take().expect("posted swap");
            swap_signal(&p.done, Ok(p.slot.label()));
            let got = h.join().expect("requester thread");
            assert_eq!(got.expect("swap completed"), "dense");
        });
        // engine exit: pending and future requests fail instead of hanging
        std::thread::scope(|s| {
            let h = s.spawn(|| m.request(dummy_slot()));
            while !m.pending() {
                std::thread::yield_now();
            }
            m.close();
            let got = h.join().expect("requester thread");
            assert!(got.is_err(), "pending swap must fail on engine exit");
        });
        assert!(m.request(dummy_slot()).is_err(),
                "post after close must fail");
    }

    #[test]
    fn sampler_seed_mixes_ids() {
        assert_ne!(sampler_seed(1, 0), sampler_seed(1, 1));
        assert_eq!(sampler_seed(7, 3), sampler_seed(7, 3));
    }

    #[test]
    fn workload_tick_matches_full_rescan_for_fractional_gaps() {
        // the incremental (first-unstamped-index) scan must stamp exactly
        // the set the old every-request rescan did: request `i` is stamped
        // iff `i * arrival_steps <= iter`, for every arrival gap including
        // fractional ones (where consecutive requests share an iteration)
        let reqs: Vec<DecodeRequest> =
            (0..7).map(|i| DecodeRequest::new(i, vec![1], 1)).collect();
        for steps in [0.0, 0.4, 1.0, 1.5, 2.0, 3.7] {
            let mut src = WorkloadSource::new(&reqs, steps);
            for iter in 0..30usize {
                src.tick(iter);
                for i in 0..reqs.len() {
                    let due = (i as f64) * steps <= iter as f64;
                    assert_eq!(src.arrivals[i].is_some(), due,
                               "steps {steps} iter {iter} req {i}");
                }
            }
        }
    }

    #[test]
    fn workload_tick_survives_fast_forwarded_iterations() {
        // idle_wait can skip the virtual clock several iterations ahead;
        // a single tick at the landing iteration must stamp every request
        // that became due in the skipped range
        let reqs: Vec<DecodeRequest> =
            (0..5).map(|i| DecodeRequest::new(i, vec![1], 1)).collect();
        let mut src = WorkloadSource::new(&reqs, 2.0);
        src.tick(7); // requests 0..=3 due (0, 2, 4, 6)
        for i in 0..4 {
            assert!(src.arrivals[i].is_some(), "req {i}");
        }
        assert!(src.arrivals[4].is_none());
        src.tick(8);
        assert!(src.arrivals[4].is_some());
    }
}
