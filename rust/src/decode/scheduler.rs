//! Continuous-batching scheduler: slot-based admission into an executing
//! decode batch.
//!
//! A request's lifecycle is prefill-then-decode: on admission into a free
//! slot its whole prompt is driven through the incremental step kernel
//! (filling the slot's KV arena and sampling the first new token), and on
//! every subsequent scheduler iteration each occupied slot advances by one
//! generated token.  When a sequence hits its generation budget (or its KV
//! arena fills) the slot retires, its arena is rewound into the free pool,
//! and the next pending request is admitted — the batch never drains to
//! empty while work is queued, unlike the static prefill drain in
//! `crate::serve`.
//!
//! Slot steps are independent, so each iteration fans the occupied slots
//! out across the `exec` worker pool in contiguous bands.  Generated tokens
//! are bit-reproducible for any slot count / thread count / arrival
//! pattern: the step kernel is deterministic per sequence and every
//! sequence samples from its own request-seeded `Sampler`.
//!
//! Admission uses a virtual clock (scheduler iterations): request `i`
//! becomes eligible at iteration `i * arrival_steps`, with `0` meaning all
//! requests arrive up front (a saturating queue).  Latency is wall-clock
//! from eligibility to completion, so queue wait is visible in p95 exactly
//! as in the prefill serving loop.

use std::time::Instant;

use anyhow::Result;

use super::kv::KvCache;
use super::sampler::Sampler;
use crate::exec;
use crate::model::{ConfigMeta, ParamStore};
use crate::runtime::session::Session;
use crate::serve::{peak_rss_bytes, Engine};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats::summarize;

/// One generation request.
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Synthetic request stream for the benchmarks: random prompts (compute
/// cost is content-independent, as in the prefill load generator).
pub fn synth_requests(cfg: &ConfigMeta, n: usize, prompt_len: usize,
                      max_new_tokens: usize, seed: u64) -> Vec<DecodeRequest> {
    let mut rng = Rng::new(seed);
    let plen = prompt_len.clamp(1, cfg.seq_len);
    (0..n)
        .map(|id| DecodeRequest {
            id,
            prompt: (0..plen).map(|_| rng.range(1, cfg.vocab) as i32).collect(),
            max_new_tokens,
        })
        .collect()
}

#[derive(Clone, Debug)]
pub struct DecodeConfig {
    /// concurrent sequences in the executing batch
    pub max_slots: usize,
    /// default generation budget (requests carry their own, already set by
    /// `synth_requests`; this caps the CLI/bench default)
    pub max_new_tokens: usize,
    /// 0 = greedy argmax; > 0 = softmax sampling at this temperature
    pub temperature: f32,
    pub seed: u64,
    /// arrival gap in scheduler iterations (deterministic schedule:
    /// request `i` becomes eligible at iteration `i * arrival_steps`);
    /// 0 saturates the queue
    pub arrival_steps: f64,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig { max_slots: 4, max_new_tokens: 32, temperature: 0.0,
                       seed: 1, arrival_steps: 0.0 }
    }
}

/// One finished request, in request-id order.
#[derive(Clone, Debug)]
pub struct CompletedRequest {
    pub id: usize,
    pub prompt_len: usize,
    /// generated tokens (the prompt is not echoed)
    pub tokens: Vec<i32>,
    /// eligibility → completion, ms (includes queue wait)
    pub latency_ms: f64,
    /// eligibility → first generated token, ms
    pub ttft_ms: f64,
}

#[derive(Clone, Debug)]
pub struct DecodeStats {
    pub engine: String,
    pub requests: usize,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub wall_seconds: f64,
    /// steady-state decode throughput: tokens generated during
    /// prefill-free scheduler iterations over those iterations' wall time
    /// (falls back to decode_tokens / wall when every iteration carried a
    /// prefill).  Most meaningful under saturating arrivals
    /// (`arrival_steps == 0`, the benchmarks' setting); with staggered
    /// arrivals admissions land in most iterations and the prefill-free
    /// sample shrinks toward the drain tail.
    pub decode_tok_per_sec: f64,
    /// prefill + decode tokens over the full wall clock
    pub total_tok_per_sec: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p50_ttft_ms: f64,
    /// K/V arena bytes one slot holds (f32)
    pub kv_bytes_per_slot: usize,
    pub peak_mem_bytes: usize,
}

/// Per-slot in-flight sequence state.
struct Active {
    /// index into the request slice
    req: usize,
    cache: KvCache,
    sampler: Sampler,
    prefilled: bool,
    last_token: i32,
    tokens: Vec<i32>,
    /// generation budget for this request
    limit: usize,
    /// wall seconds at eligibility
    arrival: f64,
    ttft: Option<f64>,
    err: Option<anyhow::Error>,
    done: bool,
}

/// One engine step: `token` at position `cache.len` → next-token logits.
fn step_engine(sess: &Session, params: &ParamStore, engine: &Engine,
               cache: &mut KvCache, token: i32) -> Result<Tensor> {
    match engine {
        Engine::Dense => sess.decode_step(params, cache, token),
        Engine::Lowrank { tag, factors } => {
            sess.lowrank_decode_step(tag, params, factors, cache, token)
        }
    }
}

/// Advance one slot: full-prompt prefill on first touch, else one decode
/// step.  Errors are parked on the slot and surfaced by the driver loop.
fn advance(sess: &Session, params: &ParamStore, engine: &Engine,
           req: &DecodeRequest, a: &mut Active, start: &Instant) {
    let r = (|| -> Result<()> {
        let logits = if a.prefilled {
            step_engine(sess, params, engine, &mut a.cache, a.last_token)?
        } else {
            let mut last = None;
            for &t in &req.prompt {
                last = Some(step_engine(sess, params, engine, &mut a.cache, t)?);
            }
            a.prefilled = true;
            a.ttft = Some(start.elapsed().as_secs_f64());
            last.expect("admission rejects empty prompts")
        };
        let tok = a.sampler.sample(&logits.data) as i32;
        a.tokens.push(tok);
        a.last_token = tok;
        Ok(())
    })();
    if let Err(e) = r {
        a.err = Some(e);
    }
    if a.err.is_some() || a.tokens.len() >= a.limit || a.cache.len >= a.cache.max_len {
        a.done = true;
    }
}

/// Run the continuous-batching generation workload.  Returns aggregate
/// stats plus every completed request (sorted by id; generated tokens are
/// deterministic for a given engine + config).
pub fn run_decode(sess: &Session, params: &ParamStore, engine: &Engine,
                  requests: &[DecodeRequest], cfg: &DecodeConfig)
                  -> Result<(DecodeStats, Vec<CompletedRequest>)> {
    anyhow::ensure!(cfg.max_slots >= 1, "decode needs at least one slot");
    anyhow::ensure!(!requests.is_empty(), "no decode requests");
    for r in requests {
        anyhow::ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
        anyhow::ensure!(r.prompt.len() <= sess.cfg.seq_len,
                        "request {}: prompt {} exceeds seq_len {}",
                        r.id, r.prompt.len(), sess.cfg.seq_len);
    }

    let start = Instant::now();
    let mut slots: Vec<Option<Active>> = Vec::new();
    for _ in 0..cfg.max_slots {
        slots.push(None);
    }
    // rewound arenas from retired slots, reused by later admissions
    let mut arena_pool: Vec<KvCache> = Vec::new();
    let mut arrivals: Vec<Option<f64>> = vec![None; requests.len()];
    let mut next_admit = 0usize;
    let mut done: Vec<CompletedRequest> = Vec::with_capacity(requests.len());
    let mut iter = 0usize;
    let mut decode_only_secs = 0.0f64;
    let mut decode_only_tokens = 0usize;

    while next_admit < requests.len() || slots.iter().any(Option::is_some) {
        // eligibility on the virtual clock (latency includes queue wait)
        let now = start.elapsed().as_secs_f64();
        for (i, a) in arrivals.iter_mut().enumerate() {
            if a.is_none() && (i as f64) * cfg.arrival_steps <= iter as f64 {
                *a = Some(now);
            }
        }

        // admit pending requests into free slots, in arrival order
        for slot in slots.iter_mut() {
            if slot.is_some() || next_admit >= requests.len() {
                continue;
            }
            let Some(arrival) = arrivals[next_admit] else { break };
            let r = &requests[next_admit];
            let cache = match arena_pool.pop() {
                Some(mut c) => {
                    c.reset();
                    c
                }
                None => KvCache::new(&sess.cfg),
            };
            *slot = Some(Active {
                req: next_admit,
                cache,
                sampler: Sampler::new(
                    cfg.temperature,
                    cfg.seed ^ (r.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                ),
                prefilled: false,
                last_token: 0,
                tokens: Vec::with_capacity(r.max_new_tokens),
                limit: r.max_new_tokens.max(1),
                arrival,
                ttft: None,
                err: None,
                done: false,
            });
            next_admit += 1;
        }

        // advance every occupied slot by one engine step, band-parallel;
        // iterations with no prefill in them time the steady-state decode
        // phase (each active slot emits exactly one token per iteration)
        {
            let mut act: Vec<&mut Active> =
                slots.iter_mut().filter_map(|s| s.as_mut()).collect();
            if !act.is_empty() {
                let had_prefill = act.iter().any(|a| !a.prefilled);
                let stepped = act.len();
                let t_band = Instant::now();
                let band = act.len().div_ceil(exec::threads().min(act.len()));
                exec::par_chunks_mut(&mut act, band, |_, band| {
                    for a in band.iter_mut() {
                        advance(sess, params, engine, &requests[a.req], a,
                                &start);
                    }
                });
                if !had_prefill {
                    decode_only_secs += t_band.elapsed().as_secs_f64();
                    decode_only_tokens += stepped;
                }
            }
        }

        // retire finished sequences; their arenas go back to the pool
        let now = start.elapsed().as_secs_f64();
        for slot in slots.iter_mut() {
            if !slot.as_ref().map(|a| a.done).unwrap_or(false) {
                continue;
            }
            let mut a = slot.take().expect("checked occupied");
            if let Some(e) = a.err.take() {
                return Err(e);
            }
            done.push(CompletedRequest {
                id: requests[a.req].id,
                prompt_len: requests[a.req].prompt.len(),
                tokens: a.tokens,
                latency_ms: (now - a.arrival) * 1e3,
                ttft_ms: a.ttft.map(|t| (t - a.arrival) * 1e3).unwrap_or(0.0),
            });
            // admission rewinds pooled arenas; no reset needed here
            arena_pool.push(a.cache);
        }
        iter += 1;
        if next_admit < requests.len() && slots.iter().all(Option::is_none) {
            // batch fully drained before the next arrival: fast-forward the
            // virtual clock to it (discrete-event style) instead of
            // busy-spinning through empty iterations
            let next_due =
                ((next_admit as f64) * cfg.arrival_steps).ceil() as usize;
            iter = iter.max(next_due);
        }
    }

    done.sort_by_key(|c| c.id);
    let wall = start.elapsed().as_secs_f64();
    let prefill_tokens: usize = done.iter().map(|c| c.prompt_len).sum();
    let decode_tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    let lats: Vec<f64> = done.iter().map(|c| c.latency_ms).collect();
    let ttfts: Vec<f64> = done.iter().map(|c| c.ttft_ms).collect();
    let s = summarize(&lats);
    let st = summarize(&ttfts);
    let stats = DecodeStats {
        engine: engine.label(),
        requests: done.len(),
        prefill_tokens,
        decode_tokens,
        wall_seconds: wall,
        decode_tok_per_sec: if decode_only_secs > 0.0 {
            decode_only_tokens as f64 / decode_only_secs
        } else {
            decode_tokens as f64 / wall
        },
        total_tok_per_sec: (prefill_tokens + decode_tokens) as f64 / wall,
        p50_ms: s.median,
        p95_ms: s.p95,
        p50_ttft_ms: st.median,
        kv_bytes_per_slot: KvCache::arena_bytes_for(&sess.cfg),
        peak_mem_bytes: peak_rss_bytes(),
    };
    Ok((stats, done))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_requests_shapes() {
        let cfg = crate::model::Manifest::builtin().config("tiny").clone();
        let reqs = synth_requests(&cfg, 5, 16, 8, 1);
        assert_eq!(reqs.len(), 5);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.prompt.len(), 16);
            assert_eq!(r.max_new_tokens, 8);
            assert!(r.prompt.iter().all(|&t| t >= 1 && (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn synth_prompt_len_clamped_to_seq() {
        let cfg = crate::model::Manifest::builtin().config("tiny").clone();
        let reqs = synth_requests(&cfg, 1, 10 * cfg.seq_len, 4, 2);
        assert_eq!(reqs[0].prompt.len(), cfg.seq_len);
        let reqs = synth_requests(&cfg, 1, 0, 4, 2);
        assert_eq!(reqs[0].prompt.len(), 1);
    }
}
