//! Continuous-batching scheduler: slot-based admission into an executing
//! decode batch, driven by an **external request source** with **per-token
//! emission callbacks**.
//!
//! A request's lifecycle is prefill-then-decode: on admission into a free
//! slot its prompt is ingested in **chunks** of
//! [`DecodeConfig::prefill_chunk`] tokens per scheduler iteration (each
//! chunk one batched [`crate::runtime::native::decode_batch`] call, filling
//! the slot's KV arena as it goes; the first new token is sampled from the
//! final chunk's logits), and on every subsequent iteration each occupied
//! slot advances by one generated token.  When a sequence hits its
//! generation budget (or its KV arena fills) the slot retires, its arena is
//! rewound into the free pool, and the next pending request is admitted —
//! the batch never drains to empty while work is queued.
//!
//! The core loop is [`run_engine`]: a **long-lived** scheduler that pulls
//! work from a [`RequestSource`] and reports progress through a sink
//! callback ([`DecodeEvent`]: one event per generated token, one per
//! completion).  Two sources exist:
//!
//! * [`WorkloadSource`] — a fixed request list with virtual-clock arrivals
//!   (request `i` becomes eligible at iteration `i * arrival_steps`; `0`
//!   saturates the queue).  [`run_decode`] wraps it to reproduce the
//!   classic run-to-completion benchmark API.
//! * the network server's queue-backed source (`crate::server`), where the
//!   scheduler runs for the life of the process, idles cheaply when no
//!   requests are queued, and drains gracefully when the queue closes.
//!
//! # Batched execution
//!
//! Each iteration issues at most two batched kernel calls: one advancing
//! every decoding slot by one token (the slots' hidden states share a
//! single activation matrix per layer — one GEMM across the batch instead
//! of per-slot single-row products), and one ingesting the current prompt
//! chunk of every prefilling slot.  Chunked prefill bounds the work any
//! single iteration performs, so a long prompt no longer stalls the whole
//! batch for its entire prefill: ongoing decode steps interleave with its
//! chunks, one per iteration.  Row-level parallelism inside the GEMMs comes
//! from the persistent `exec` pool.
//!
//! # Determinism
//!
//! Generated tokens are bit-reproducible for any slot count / thread count
//! / chunk size / arrival pattern: the batched kernel is row-independent
//! (a sequence's logits cannot depend on which other sequences share the
//! GEMM — see `decode_batch`'s bit-identity contract), and every sequence
//! samples from its own seeded `Sampler` — explicitly via
//! `DecodeRequest::seed`, or derived from the scheduler seed and request id
//! by [`sampler_seed`].  Scheduling chooses *when* a sequence advances,
//! never *what* it computes, which is what lets network generations
//! bit-match the offline path (`rust/tests/server_loopback.rs`).
//!
//! Latency accounting: a request's latency spans eligibility → completion
//! (queue wait included, so admission pressure is visible in p95/p99);
//! TTFT spans eligibility → first generated token; queue wait is reported
//! separately as eligibility → slot admission.  Prefill and decode phases
//! are separate kernel calls per iteration and are clocked separately
//! ([`EngineCounters::prefill_secs`] vs the decode-section clock behind
//! [`EngineCounters::decode_tok_per_sec`]), so the serving benches report
//! split prefill/decode token rates.

use std::time::Instant;

use anyhow::Result;

use super::kv::KvCache;
use super::sampler::Sampler;
use crate::model::{ConfigMeta, ParamStore};
use crate::runtime::session::Session;
use crate::serve::{peak_rss_bytes, Engine};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats::LatencySummary;

/// One generation request.
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    /// caller-assigned id, unique within one engine run
    pub id: usize,
    /// prompt token ids (non-empty, <= the model's seq_len)
    pub prompt: Vec<i32>,
    /// generation budget for this request
    pub max_new_tokens: usize,
    /// per-request sampling temperature (None = the scheduler default).
    /// The network front-end threads client-supplied values through these
    /// overrides so a server generation bit-matches an offline
    /// [`run_decode`] carrying the same explicit settings.
    pub temperature: Option<f32>,
    /// per-request sampler seed (None = derived via [`sampler_seed`])
    pub seed: Option<u64>,
}

impl DecodeRequest {
    /// Request with default sampling (scheduler temperature, derived seed).
    pub fn new(id: usize, prompt: Vec<i32>, max_new_tokens: usize)
               -> DecodeRequest {
        DecodeRequest { id, prompt, max_new_tokens, temperature: None,
                        seed: None }
    }
}

/// Default per-request sampler seed: scheduler seed mixed with the request
/// id, so generations are independent of slot assignment and scheduling.
pub fn sampler_seed(base: u64, id: usize) -> u64 {
    base ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Synthetic request stream for the benchmarks: random prompts (compute
/// cost is content-independent, as in the prefill load generator).
pub fn synth_requests(cfg: &ConfigMeta, n: usize, prompt_len: usize,
                      max_new_tokens: usize, seed: u64) -> Vec<DecodeRequest> {
    let mut rng = Rng::new(seed);
    let plen = prompt_len.clamp(1, cfg.seq_len);
    (0..n)
        .map(|id| DecodeRequest::new(
            id,
            (0..plen).map(|_| rng.range(1, cfg.vocab) as i32).collect(),
            max_new_tokens,
        ))
        .collect()
}

/// Scheduler shape + per-request defaults for one engine run.
#[derive(Clone, Debug)]
pub struct DecodeConfig {
    /// concurrent sequences in the executing batch
    pub max_slots: usize,
    /// default generation budget (requests carry their own, already set by
    /// `synth_requests`; this caps the CLI/bench default)
    pub max_new_tokens: usize,
    /// default sampling temperature: 0 = greedy argmax; > 0 = softmax
    /// sampling at this temperature (requests may override per-request)
    pub temperature: f32,
    /// base sampler seed, mixed per request by [`sampler_seed`]
    pub seed: u64,
    /// arrival gap in scheduler iterations for [`WorkloadSource`]
    /// (deterministic schedule: request `i` becomes eligible at iteration
    /// `i * arrival_steps`); 0 saturates the queue
    pub arrival_steps: f64,
    /// prompt tokens a prefilling slot ingests per scheduler iteration
    /// (each chunk is one batched kernel call); 0 = the whole remaining
    /// prompt in a single iteration.  Smaller chunks bound per-iteration
    /// work so ongoing decode steps interleave with a long prompt's
    /// prefill; generated tokens are identical for every chunk size.
    pub prefill_chunk: usize,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig { max_slots: 4, max_new_tokens: 32, temperature: 0.0,
                       seed: 1, arrival_steps: 0.0, prefill_chunk: 0 }
    }
}

/// One finished request.
#[derive(Clone, Debug)]
pub struct CompletedRequest {
    /// the request's caller-assigned id
    pub id: usize,
    /// prompt length, tokens
    pub prompt_len: usize,
    /// generated tokens (the prompt is not echoed)
    pub tokens: Vec<i32>,
    /// eligibility → completion, ms (includes queue wait)
    pub latency_ms: f64,
    /// eligibility → first generated token, ms
    pub ttft_ms: f64,
    /// eligibility → slot admission, ms (pure queue wait)
    pub queue_ms: f64,
}

/// Per-token / per-completion emissions from [`run_engine`], delivered on
/// the driver thread in slot order after each iteration — never from pool
/// workers, so sinks need no synchronization of their own.
#[derive(Debug)]
pub enum DecodeEvent {
    /// the `index`-th generated token of request `id`
    Token {
        /// the request's caller-assigned id
        id: usize,
        /// 0-based position in this request's generation
        index: usize,
        /// the sampled token id
        token: i32,
        /// gap since this request's previous emission (the first token's
        /// gap is its TTFT), seconds
        gap_secs: f64,
    },
    /// request finished (budget reached or KV arena full)
    Done(CompletedRequest),
}

/// What a [`RequestSource`] hands the scheduler when asked for work.
pub enum SourcePoll {
    /// next request plus the instant it became eligible (latency baseline)
    Ready(DecodeRequest, Instant),
    /// nothing eligible right now, but the stream is still open
    Pending,
    /// the stream has ended: drain in-flight slots and return
    Drained,
}

/// External request feed for the long-lived scheduler loop.
pub trait RequestSource {
    /// Called once per scheduler iteration, before admission — virtual-
    /// clock sources stamp newly-eligible arrivals here so queue wait is
    /// charged even while every slot is busy.
    fn tick(&mut self, _iter: usize) {}

    /// Next request for a free slot.
    fn poll(&mut self, iter: usize) -> SourcePoll;

    /// The batch is empty and `poll` returned `Pending`: block until work
    /// may be available and return the iteration to resume at.  Virtual
    /// clocks fast-forward (discrete-event style) instead of busy-spinning;
    /// live sources wait on a condvar with a bounded timeout.
    fn idle_wait(&mut self, iter: usize) -> usize;
}

/// Fixed request list with virtual-clock arrivals — the offline benchmark
/// workload expressed as a [`RequestSource`].
pub struct WorkloadSource<'a> {
    requests: &'a [DecodeRequest],
    arrival_steps: f64,
    next: usize,
    arrivals: Vec<Option<Instant>>,
}

impl<'a> WorkloadSource<'a> {
    /// Source over a fixed request list with the given arrival gap.
    pub fn new(requests: &'a [DecodeRequest], arrival_steps: f64)
               -> WorkloadSource<'a> {
        WorkloadSource {
            requests,
            arrival_steps,
            next: 0,
            arrivals: vec![None; requests.len()],
        }
    }
}

impl RequestSource for WorkloadSource<'_> {
    fn tick(&mut self, iter: usize) {
        let now = Instant::now();
        for (i, a) in self.arrivals.iter_mut().enumerate() {
            if a.is_none() && (i as f64) * self.arrival_steps <= iter as f64 {
                *a = Some(now);
            }
        }
    }

    fn poll(&mut self, _iter: usize) -> SourcePoll {
        if self.next >= self.requests.len() {
            return SourcePoll::Drained;
        }
        match self.arrivals[self.next] {
            Some(at) => {
                let r = self.requests[self.next].clone();
                self.next += 1;
                SourcePoll::Ready(r, at)
            }
            None => SourcePoll::Pending,
        }
    }

    fn idle_wait(&mut self, iter: usize) -> usize {
        // batch fully drained before the next arrival: fast-forward the
        // virtual clock to it instead of spinning through empty iterations
        let due = ((self.next as f64) * self.arrival_steps).ceil() as usize;
        due.max(iter + 1)
    }
}

/// Aggregate counters from one [`run_engine`] run.  Percentiles are the
/// sink's business: a long-lived server summarizes from its metrics
/// registry, [`run_decode`] from the completions it collects.
#[derive(Clone, Debug, Default)]
pub struct EngineCounters {
    /// scheduler iterations executed
    pub iterations: usize,
    /// requests that ran to completion
    pub requests_completed: usize,
    /// prompt tokens ingested through the chunked-prefill path
    pub prefill_tokens: usize,
    /// tokens generated across all requests
    pub decode_tokens: usize,
    /// whole-run wall time, seconds
    pub wall_seconds: f64,
    /// wall time spent inside the batched decode-step sections (every
    /// iteration's decode call + sampling; prefill runs as a separate
    /// kernel call and is never charged here)
    pub decode_only_secs: f64,
    /// tokens generated during those decode-step sections
    pub decode_only_tokens: usize,
    /// wall time spent inside the batched prefill-chunk kernel calls
    /// (the denominator of [`EngineCounters::prefill_tok_per_sec`])
    pub prefill_secs: f64,
}

impl EngineCounters {
    /// Steady-state decode throughput — the ONE definition every surface
    /// reports (`DecodeStats::decode_tok_per_sec`, the network server's
    /// session table, `benches/server_throughput.rs`): tokens generated
    /// over the wall time of the batched decode-step sections alone.
    /// Prefill runs as its own kernel call per iteration, so this stays
    /// meaningful for any chunk size — mixed iterations charge only their
    /// decode section here (the pre-PR-4 definition counted whole
    /// prefill-free iterations, which chunked prefill can starve).  Falls
    /// back to the whole-run average when no decode section ever ran.
    pub fn decode_tok_per_sec(&self) -> f64 {
        if self.decode_only_secs > 0.0 {
            self.decode_only_tokens as f64 / self.decode_only_secs
        } else if self.wall_seconds > 0.0 {
            self.decode_tokens as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Prefill-phase throughput: prompt tokens ingested over the wall time
    /// of the batched prefill-chunk calls alone (decode iterations and
    /// queue idling excluded), so the chunked-prefill win is measurable
    /// separately from the steady-state decode rate.
    pub fn prefill_tok_per_sec(&self) -> f64 {
        if self.prefill_secs > 0.0 {
            self.prefill_tokens as f64 / self.prefill_secs
        } else {
            0.0
        }
    }
}

/// Aggregate result of one [`run_decode`] benchmark run.
#[derive(Clone, Debug)]
pub struct DecodeStats {
    /// engine label (`dense` / `lowrank-r<tag>`)
    pub engine: String,
    /// requests completed
    pub requests: usize,
    /// prompt tokens ingested
    pub prefill_tokens: usize,
    /// tokens generated
    pub decode_tokens: usize,
    /// whole-run wall time, seconds
    pub wall_seconds: f64,
    /// steady-state decode throughput: tokens generated over the wall
    /// time of the batched decode-step sections alone (prefill is a
    /// separate per-iteration kernel call and is never charged).  Most
    /// meaningful under saturating arrivals (`arrival_steps == 0`, the
    /// benchmarks' setting).
    pub decode_tok_per_sec: f64,
    /// prefill-phase throughput: prompt tokens over the wall time of the
    /// batched prefill-chunk calls alone
    /// ([`EngineCounters::prefill_tok_per_sec`])
    pub prefill_tok_per_sec: f64,
    /// prefill + decode tokens over the full wall clock
    pub total_tok_per_sec: f64,
    /// end-to-end latency summary (eligibility → completion), ms
    pub latency: LatencySummary,
    /// time-to-first-token summary, ms
    pub ttft: LatencySummary,
    /// K/V arena bytes one slot holds (f32)
    pub kv_bytes_per_slot: usize,
    /// peak RSS of the process (VmHWM), bytes
    pub peak_mem_bytes: usize,
}

/// Per-slot in-flight sequence state.
struct Active {
    req: DecodeRequest,
    cache: KvCache,
    sampler: Sampler,
    /// prompt tokens already ingested; prefill is complete once this
    /// reaches the prompt length
    prefill_pos: usize,
    last_token: i32,
    tokens: Vec<i32>,
    /// tokens already delivered to the sink
    emitted: usize,
    /// generation budget for this request
    limit: usize,
    /// eligibility instant (latency baseline; includes queue wait)
    arrival: Instant,
    /// slot-admission instant (arrival → admitted = queue wait)
    admitted: Instant,
    first_token_at: Option<Instant>,
    /// previous emission instant (token-gap baseline; starts at arrival)
    last_emit: Instant,
    done: bool,
}

impl Active {
    /// Still ingesting its prompt (not yet generating).
    fn prefilling(&self) -> bool {
        self.prefill_pos < self.req.prompt.len()
    }

    /// Bookkeeping after a sampled token: record it, stamp TTFT, and
    /// retire the slot once the budget or the KV arena is exhausted.
    fn push_token(&mut self, tok: i32) {
        self.tokens.push(tok);
        self.last_token = tok;
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        if self.tokens.len() >= self.limit || self.cache.len >= self.cache.max_len {
            self.done = true;
        }
    }
}

/// One batched engine advance over several sequences' token runs: each
/// sequence with `want_logits[s]` set gets back the next-token logits
/// after its last run token (interior prefill chunks request none and skip
/// the vocab projection).
fn step_engine_batch(sess: &Session, params: &ParamStore, engine: &Engine,
                     seqs: &mut [(&mut KvCache, &[i32])],
                     want_logits: &[bool])
                     -> Result<Vec<Option<Tensor>>> {
    match engine {
        Engine::Dense => sess.decode_batch(params, seqs, want_logits),
        Engine::Lowrank { tag, factors } => {
            sess.lowrank_decode_batch(tag, params, factors, seqs, want_logits)
        }
    }
}

/// Run the long-lived continuous-batching scheduler until `source` drains:
/// admit from `source` into free slots, advance occupied slots through the
/// batched step/prefill kernels (one GEMM set across the batch per
/// iteration, row-parallel on the persistent `exec` pool), and deliver
/// every generated token and completion to `sink` in slot order.
///
/// Engine errors (a failing step kernel) abort the run; request validation
/// belongs to the caller — the offline wrapper checks its whole workload up
/// front and the network front-end screens at admission.
pub fn run_engine(sess: &Session, params: &ParamStore, engine: &Engine,
                  cfg: &DecodeConfig, source: &mut dyn RequestSource,
                  sink: &mut dyn FnMut(DecodeEvent))
                  -> Result<EngineCounters> {
    anyhow::ensure!(cfg.max_slots >= 1, "decode needs at least one slot");

    let start = Instant::now();
    let mut slots: Vec<Option<Active>> = Vec::new();
    for _ in 0..cfg.max_slots {
        slots.push(None);
    }
    // rewound arenas from retired slots, reused by later admissions
    let mut arena_pool: Vec<KvCache> = Vec::new();
    let mut c = EngineCounters::default();
    let mut iter = 0usize;
    let mut drained = false;

    loop {
        source.tick(iter);

        // admit pending requests into free slots, in source order
        if !drained {
            for slot in slots.iter_mut() {
                if slot.is_some() {
                    continue;
                }
                match source.poll(iter) {
                    SourcePoll::Ready(req, arrival) => {
                        anyhow::ensure!(!req.prompt.is_empty(),
                                        "request {}: empty prompt", req.id);
                        anyhow::ensure!(
                            req.prompt.len() <= sess.cfg.seq_len,
                            "request {}: prompt {} exceeds seq_len {}",
                            req.id, req.prompt.len(), sess.cfg.seq_len);
                        let cache = match arena_pool.pop() {
                            Some(mut cached) => {
                                cached.reset();
                                cached
                            }
                            None => KvCache::new(&sess.cfg),
                        };
                        let sampler = Sampler::new(
                            req.temperature.unwrap_or(cfg.temperature),
                            req.seed
                                .unwrap_or_else(|| sampler_seed(cfg.seed, req.id)),
                        );
                        let now = Instant::now();
                        let limit = req.max_new_tokens.max(1);
                        // generation can never exceed the KV capacity, so a
                        // huge client-supplied budget must not drive a huge
                        // pre-allocation
                        let cap = limit.min(sess.cfg.seq_len);
                        *slot = Some(Active {
                            cache,
                            sampler,
                            prefill_pos: 0,
                            last_token: 0,
                            tokens: Vec::with_capacity(cap),
                            emitted: 0,
                            limit,
                            arrival,
                            admitted: now,
                            first_token_at: None,
                            last_emit: arrival,
                            done: false,
                            req,
                        });
                    }
                    SourcePoll::Pending => break,
                    SourcePoll::Drained => {
                        drained = true;
                        break;
                    }
                }
            }
        }

        if !slots.iter().any(Option::is_some) {
            if drained {
                break;
            }
            iter = source.idle_wait(iter);
            continue;
        }

        // advance the batch with at most two batched kernel calls: one
        // single-token step across every decoding slot (their hidden states
        // share one activation matrix per layer), then one prompt-chunk
        // ingest across every prefilling slot.  Decoding slots therefore
        // emit exactly one token per iteration while long prompts make
        // bounded, chunk-sized progress alongside them.
        let had_prefill = slots
            .iter()
            .any(|s| s.as_ref().is_some_and(Active::prefilling));

        // --- batched decode step across decoding slots ---
        let step_toks: Vec<i32> = slots
            .iter()
            .filter_map(|s| s.as_ref())
            .filter(|a| !a.prefilling())
            .map(|a| a.last_token)
            .collect();
        if !step_toks.is_empty() {
            let t_step = Instant::now();
            let logits = {
                let mut seqs: Vec<(&mut KvCache, &[i32])> =
                    Vec::with_capacity(step_toks.len());
                let mut k = 0usize;
                for s in slots.iter_mut() {
                    let Some(a) = s else { continue };
                    if a.prefilling() {
                        continue;
                    }
                    seqs.push((&mut a.cache,
                               std::slice::from_ref(&step_toks[k])));
                    k += 1;
                }
                // every decode step feeds its slot's sampler
                let want = vec![true; seqs.len()];
                step_engine_batch(sess, params, engine, &mut seqs, &want)?
            };
            let stepped = step_toks.len();
            // sampling stays on the driver thread, in slot order — cheap
            // next to the GEMMs, and per-sequence seeding keeps it
            // independent of batch composition anyway
            let mut k = 0usize;
            for s in slots.iter_mut() {
                let Some(a) = s else { continue };
                if a.prefilling() {
                    continue;
                }
                let l = logits[k].as_ref().expect("decode logits requested");
                let tok = a.sampler.sample(&l.data) as i32;
                k += 1;
                a.push_token(tok);
            }
            // the decode section is its own kernel call, so its clock is
            // clean even when the same iteration also prefills a chunk —
            // charge it always (a prefill-free-iterations-only clock would
            // starve under small chunk sizes and steady admissions)
            c.decode_only_secs += t_step.elapsed().as_secs_f64();
            c.decode_only_tokens += stepped;
        }

        // --- chunked prefill across prefilling slots ---
        if had_prefill {
            let t_pre = Instant::now();
            // the chunk plan is computed ONCE and replayed below, so the
            // logits index can never drift from the slot it belongs to
            let (logits, takes) = {
                let mut seqs: Vec<(&mut KvCache, &[i32])> = Vec::new();
                let mut takes: Vec<usize> = Vec::new();
                let mut want: Vec<bool> = Vec::new();
                for s in slots.iter_mut() {
                    let Some(a) = s else { continue };
                    if !a.prefilling() {
                        continue;
                    }
                    let rem = a.req.prompt.len() - a.prefill_pos;
                    let take = match cfg.prefill_chunk {
                        0 => rem,
                        chunk => rem.min(chunk),
                    };
                    seqs.push((&mut a.cache,
                               &a.req.prompt[a.prefill_pos
                                   ..a.prefill_pos + take]));
                    takes.push(take);
                    // only a prompt-completing chunk feeds the sampler
                    want.push(take == rem);
                }
                (step_engine_batch(sess, params, engine, &mut seqs, &want)?,
                 takes)
            };
            c.prefill_secs += t_pre.elapsed().as_secs_f64();
            let mut k = 0usize;
            for s in slots.iter_mut() {
                let Some(a) = s else { continue };
                if !a.prefilling() {
                    continue;
                }
                let take = takes[k];
                a.prefill_pos += take;
                c.prefill_tokens += take;
                if !a.prefilling() {
                    // prompt fully ingested: the final chunk's logits are
                    // the last prompt position's — sample the first token
                    let l = logits[k].as_ref()
                        .expect("final-chunk logits requested");
                    let tok = a.sampler.sample(&l.data) as i32;
                    a.push_token(tok);
                }
                k += 1;
            }
        }

        // emit new tokens and retire finished sequences, in slot order;
        // retired arenas go back to the pool
        for slot in slots.iter_mut() {
            let Some(a) = slot.as_mut() else { continue };
            while a.emitted < a.tokens.len() {
                let now = Instant::now();
                let gap = now.duration_since(a.last_emit).as_secs_f64();
                a.last_emit = now;
                sink(DecodeEvent::Token {
                    id: a.req.id,
                    index: a.emitted,
                    token: a.tokens[a.emitted],
                    gap_secs: gap,
                });
                a.emitted += 1;
            }
            if !a.done {
                continue;
            }
            let mut a = slot.take().expect("checked occupied");
            let now = Instant::now();
            c.requests_completed += 1;
            c.decode_tokens += a.tokens.len();
            sink(DecodeEvent::Done(CompletedRequest {
                id: a.req.id,
                prompt_len: a.req.prompt.len(),
                tokens: std::mem::take(&mut a.tokens),
                latency_ms: now.duration_since(a.arrival).as_secs_f64() * 1e3,
                ttft_ms: a
                    .first_token_at
                    .map(|t| t.duration_since(a.arrival).as_secs_f64() * 1e3)
                    .unwrap_or(0.0),
                queue_ms: a.admitted.duration_since(a.arrival).as_secs_f64()
                    * 1e3,
            }));
            arena_pool.push(a.cache);
        }
        iter += 1;
    }

    c.iterations = iter;
    c.wall_seconds = start.elapsed().as_secs_f64();
    Ok(c)
}

/// Run the fixed-workload generation benchmark: [`run_engine`] over a
/// [`WorkloadSource`].  Returns aggregate stats plus every completed
/// request (sorted by id; generated tokens are deterministic for a given
/// engine + config).
pub fn run_decode(sess: &Session, params: &ParamStore, engine: &Engine,
                  requests: &[DecodeRequest], cfg: &DecodeConfig)
                  -> Result<(DecodeStats, Vec<CompletedRequest>)> {
    anyhow::ensure!(!requests.is_empty(), "no decode requests");
    for r in requests {
        anyhow::ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
        anyhow::ensure!(r.prompt.len() <= sess.cfg.seq_len,
                        "request {}: prompt {} exceeds seq_len {}",
                        r.id, r.prompt.len(), sess.cfg.seq_len);
    }

    let mut source = WorkloadSource::new(requests, cfg.arrival_steps);
    let mut done: Vec<CompletedRequest> = Vec::with_capacity(requests.len());
    let counters = {
        let mut sink = |ev: DecodeEvent| {
            if let DecodeEvent::Done(c) = ev {
                done.push(c);
            }
        };
        run_engine(sess, params, engine, cfg, &mut source, &mut sink)?
    };

    done.sort_by_key(|c| c.id);
    let lats: Vec<f64> = done.iter().map(|c| c.latency_ms).collect();
    let ttfts: Vec<f64> = done.iter().map(|c| c.ttft_ms).collect();
    let stats = DecodeStats {
        engine: engine.label(),
        requests: done.len(),
        prefill_tokens: counters.prefill_tokens,
        decode_tokens: counters.decode_tokens,
        wall_seconds: counters.wall_seconds,
        decode_tok_per_sec: counters.decode_tok_per_sec(),
        prefill_tok_per_sec: counters.prefill_tok_per_sec(),
        total_tok_per_sec: (counters.prefill_tokens + counters.decode_tokens)
            as f64
            / counters.wall_seconds,
        latency: LatencySummary::from_samples(&lats),
        ttft: LatencySummary::from_samples(&ttfts),
        kv_bytes_per_slot: KvCache::arena_bytes_for(&sess.cfg),
        peak_mem_bytes: peak_rss_bytes(),
    };
    Ok((stats, done))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_requests_shapes() {
        let cfg = crate::model::Manifest::builtin().config("tiny").clone();
        let reqs = synth_requests(&cfg, 5, 16, 8, 1);
        assert_eq!(reqs.len(), 5);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.prompt.len(), 16);
            assert_eq!(r.max_new_tokens, 8);
            assert!(r.temperature.is_none() && r.seed.is_none());
            assert!(r.prompt.iter().all(|&t| t >= 1 && (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn synth_prompt_len_clamped_to_seq() {
        let cfg = crate::model::Manifest::builtin().config("tiny").clone();
        let reqs = synth_requests(&cfg, 1, 10 * cfg.seq_len, 4, 2);
        assert_eq!(reqs[0].prompt.len(), cfg.seq_len);
        let reqs = synth_requests(&cfg, 1, 0, 4, 2);
        assert_eq!(reqs[0].prompt.len(), 1);
    }

    #[test]
    fn workload_source_respects_virtual_clock() {
        let reqs: Vec<DecodeRequest> =
            (0..3).map(|i| DecodeRequest::new(i, vec![1], 2)).collect();
        let mut src = WorkloadSource::new(&reqs, 2.0);
        // iter 0: only request 0 is eligible
        src.tick(0);
        assert!(matches!(src.poll(0), SourcePoll::Ready(r, _) if r.id == 0));
        assert!(matches!(src.poll(0), SourcePoll::Pending));
        // fast-forward lands exactly on request 1's due iteration
        assert_eq!(src.idle_wait(0), 2);
        src.tick(2);
        assert!(matches!(src.poll(2), SourcePoll::Ready(r, _) if r.id == 1));
        src.tick(4);
        assert!(matches!(src.poll(4), SourcePoll::Ready(r, _) if r.id == 2));
        assert!(matches!(src.poll(4), SourcePoll::Drained));
    }

    #[test]
    fn sampler_seed_mixes_ids() {
        assert_ne!(sampler_seed(1, 0), sampler_seed(1, 1));
        assert_eq!(sampler_seed(7, 3), sampler_seed(7, 3));
    }
}
