//! Token sampling for the decode loop: greedy argmax and temperature
//! softmax.  Each sequence owns its sampler (seeded per request id), so
//! generations are reproducible regardless of slot assignment, scheduling
//! order, or thread count.
//!
//! Edge-case contract (unit-tested below):
//! * temperature → 0 reproduces greedy argmax **exactly** — any temperature
//!   at or below [`GREEDY_TEMP_EPS`] selects the greedy path, so ties also
//!   break by index there instead of depending on underflowed softmax
//!   weights;
//! * greedy logit ties break deterministically to the lowest index;
//! * temperature sampling is a pure function of (logits, seed): the same
//!   `util::rng` seed replays the same tokens.

use crate::util::rng::Rng;

/// Temperatures at or below this are treated as exactly greedy.  Softmax at
/// such temperatures already underflows every non-maximal weight to zero;
/// routing them through `argmax` additionally pins tie-breaking to the
/// lowest index (`sample_softmax` would pick among tied maxima by rng).
pub const GREEDY_TEMP_EPS: f32 = 1e-6;

/// First index of the maximum logit (ties break to the lowest index, so
/// greedy decoding is fully deterministic).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Per-sequence sampling policy.
pub enum Sampler {
    /// argmax (temperature <= 1e-6), ties broken by lowest index
    Greedy,
    /// softmax sampling at `temp` from a per-request seeded stream
    Temperature {
        /// sampling temperature (> 0)
        temp: f32,
        /// per-request random stream
        rng: Rng,
    },
}

impl Sampler {
    /// `temperature <= GREEDY_TEMP_EPS` (including 0 and negative values)
    /// selects greedy decoding.
    pub fn new(temperature: f32, seed: u64) -> Sampler {
        if temperature > GREEDY_TEMP_EPS {
            Sampler::Temperature { temp: temperature, rng: Rng::new(seed) }
        } else {
            Sampler::Greedy
        }
    }

    /// Whether this sampler is the greedy argmax policy.  Greedy sampling
    /// consumes no rng state, so speculative decode can verify draft tokens
    /// through `sample` without perturbing the random stream — which is why
    /// speculation is gated on this predicate (temperature slots fall back
    /// to plain one-token decode).
    pub fn is_greedy(&self) -> bool {
        matches!(self, Sampler::Greedy)
    }

    /// Draw the next token id from a logits row.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        match self {
            Sampler::Greedy => argmax(logits),
            Sampler::Temperature { temp, rng } => {
                sample_softmax(logits, *temp, rng)
            }
        }
    }
}

/// Draw from softmax(logits / temp), numerically stable in f64.
fn sample_softmax(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    let t = (temp as f64).max(1e-6);
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let weights: Vec<f64> = logits
        .iter()
        .map(|&z| ((z as f64 - maxv) / t).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    if !(total > 0.0) || !total.is_finite() {
        // degenerate logits (all -inf / NaN): fall back to the greedy rule
        return argmax(logits);
    }
    let u = rng.uniform() * total;
    let mut acc = 0.0f64;
    let mut last_positive = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        if w > 0.0 {
            last_positive = i;
        }
        acc += w;
        if u < acc {
            return i;
        }
    }
    // floating-point slack put u at/over the final accumulator: return the
    // last index that actually carried probability mass, never a zero-weight
    // trailing entry
    last_positive
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-5.0, -2.0]), 1);
        assert_eq!(argmax(&[7.0]), 0);
    }

    #[test]
    fn greedy_matches_argmax() {
        let mut s = Sampler::new(0.0, 1);
        assert_eq!(s.sample(&[0.1, 9.0, 2.0]), 1);
    }

    #[test]
    fn temperature_to_zero_is_exactly_greedy() {
        // at, below, and just above zero — all take the greedy path,
        // including on tied maxima (index tie-break, no rng draw)
        let tied = vec![1.0f32, 5.0, 5.0, 0.0];
        for temp in [0.0f32, -1.0, 1e-9, GREEDY_TEMP_EPS] {
            for seed in [1u64, 2, 99] {
                let mut s = Sampler::new(temp, seed);
                assert_eq!(s.sample(&tied), argmax(&tied),
                           "temp {temp} seed {seed}");
                assert_eq!(s.sample(&[0.3f32, 0.1, 0.2]), 0);
            }
        }
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let logits = vec![0.5f32, 1.5, -0.3, 2.0, 0.0];
        let draw = |seed: u64| {
            let mut s = Sampler::new(0.8, seed);
            (0..20).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(draw(42), draw(42));
        // in range
        assert!(draw(7).iter().all(|&i| i < logits.len()));
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let logits = vec![0.0f32, 10.0, 1.0];
        let mut s = Sampler::new(0.01, 3);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn softmax_never_returns_zero_weight_tail() {
        // huge logit gap underflows every non-max weight to exactly 0.0; the
        // trailing entries must never be selected even when the uniform draw
        // lands at the top of the accumulator
        let logits = vec![1000.0f32, -1000.0, -1000.0];
        for seed in 0..50u64 {
            let mut s = Sampler::new(0.5, seed);
            assert_eq!(s.sample(&logits), 0, "seed {seed}");
        }
    }

    #[test]
    fn degenerate_logits_fall_back_to_greedy_rule() {
        let all_neg_inf = vec![f32::NEG_INFINITY; 4];
        let mut s = Sampler::new(0.7, 11);
        assert_eq!(s.sample(&all_neg_inf), argmax(&all_neg_inf));
    }
}
