//! Token sampling for the decode loop: greedy argmax and temperature
//! softmax.  Each sequence owns its sampler (seeded per request id), so
//! generations are reproducible regardless of slot assignment, scheduling
//! order, or thread count.

use crate::util::rng::Rng;

/// First index of the maximum logit (ties break to the lowest index, so
/// greedy decoding is fully deterministic).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Per-sequence sampling policy.
pub enum Sampler {
    Greedy,
    Temperature { temp: f32, rng: Rng },
}

impl Sampler {
    /// `temperature <= 0` selects greedy decoding.
    pub fn new(temperature: f32, seed: u64) -> Sampler {
        if temperature > 0.0 {
            Sampler::Temperature { temp: temperature, rng: Rng::new(seed) }
        } else {
            Sampler::Greedy
        }
    }

    pub fn sample(&mut self, logits: &[f32]) -> usize {
        match self {
            Sampler::Greedy => argmax(logits),
            Sampler::Temperature { temp, rng } => {
                sample_softmax(logits, *temp, rng)
            }
        }
    }
}

/// Draw from softmax(logits / temp), numerically stable in f64.
fn sample_softmax(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    let t = (temp as f64).max(1e-6);
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let weights: Vec<f64> = logits
        .iter()
        .map(|&z| ((z as f64 - maxv) / t).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let u = rng.uniform() * total;
    let mut acc = 0.0f64;
    for (i, w) in weights.iter().enumerate() {
        acc += w;
        if u < acc {
            return i;
        }
    }
    logits.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-5.0, -2.0]), 1);
        assert_eq!(argmax(&[7.0]), 0);
    }

    #[test]
    fn greedy_matches_argmax() {
        let mut s = Sampler::new(0.0, 1);
        assert_eq!(s.sample(&[0.1, 9.0, 2.0]), 1);
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let logits = vec![0.5f32, 1.5, -0.3, 2.0, 0.0];
        let draw = |seed: u64| {
            let mut s = Sampler::new(0.8, seed);
            (0..20).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(draw(42), draw(42));
        // in range
        assert!(draw(7).iter().all(|&i| i < logits.len()));
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let logits = vec![0.0f32, 10.0, 1.0];
        let mut s = Sampler::new(0.01, 3);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }
}
