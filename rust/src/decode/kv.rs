//! Per-sequence KV-cache arena for incremental decoding.
//!
//! One `KvCache` holds, per transformer layer, a `(max_len × d_model)` K
//! matrix and V matrix plus a length cursor.  `decode_step` appends the
//! current position's post-RoPE key and value rows and attends over rows
//! `0..=pos`; the batched `decode_batch` kernel appends a whole token run
//! (a prefill chunk, or one token per scheduled slot) the same way, rows
//! in ascending position order.  Rows `>= len` are never read, so
//! `reset()` (slot reuse in the continuous-batching scheduler) only
//! rewinds the cursor — the arena allocation survives for the life of the
//! slot.
//!
//! The RoPE cos/sin tables (llama models) are precomputed here once per
//! cache instead of once per token; they are bit-identical to the tables
//! the full forward pass builds, which the decode parity gate relies on.

use std::sync::Arc;

use crate::model::ConfigMeta;
use crate::runtime::native::{layer_names, rope_tables, LayerNames};
use crate::tensor::Mat;

/// Per-sequence KV cache: one K/V arena per layer + the position cursor.
pub struct KvCache {
    /// arena capacity in positions (== the model's `seq_len`)
    pub max_len: usize,
    /// filled positions; the next `decode_step` writes row `len`
    pub len: usize,
    /// model width (row length of the arenas)
    pub d: usize,
    /// per-layer keys, post-RoPE, `(max_len × d)`
    pub k: Vec<Mat>,
    /// per-layer values, `(max_len × d)`
    pub v: Vec<Mat>,
    /// RoPE tables `(max_len × dh/2)` flattened; empty for non-llama archs
    pub(crate) cos: Vec<f32>,
    pub(crate) sin: Vec<f32>,
    /// pre-rendered per-layer parameter names (process-wide table, shared):
    /// the per-token step does zero string formatting or cache lookups
    pub(crate) names: Arc<Vec<LayerNames>>,
}

impl KvCache {
    /// Fresh arena sized for `cfg` (capacity `seq_len` positions).
    pub fn new(cfg: &ConfigMeta) -> KvCache {
        let dh = cfg.d_model / cfg.n_heads;
        let (cos, sin) = if cfg.arch == "llama" {
            rope_tables(cfg.seq_len, dh, cfg.rope_theta)
        } else {
            (Vec::new(), Vec::new())
        };
        KvCache {
            max_len: cfg.seq_len,
            len: 0,
            d: cfg.d_model,
            k: (0..cfg.n_layers)
                .map(|_| Mat::zeros(cfg.seq_len, cfg.d_model))
                .collect(),
            v: (0..cfg.n_layers)
                .map(|_| Mat::zeros(cfg.seq_len, cfg.d_model))
                .collect(),
            cos,
            sin,
            names: layer_names(cfg),
        }
    }

    /// Rewind for slot reuse.  Stale rows are unreachable (attention reads
    /// only rows `< len`), so no zeroing is needed.
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Partial rewind — the dual of [`KvCache::reset`].  Speculative decode
    /// rolls the cursor back past rejected draft positions with this; like
    /// `reset`, it only moves the cursor.  Rows `>= len` become unreachable
    /// again and are overwritten in place by the next append at those
    /// positions.  A rewind can never extend the cache, so `len` must not
    /// exceed the current cursor.
    pub fn truncate(&mut self, len: usize) {
        assert!(
            len <= self.len,
            "KvCache::truncate to {} beyond cursor {}",
            len,
            self.len
        );
        self.len = len;
    }

    /// Remaining positions before the arena is full.
    pub fn remaining(&self) -> usize {
        self.max_len - self.len
    }

    /// f32 bytes one arena of this shape holds (K + V, all layers).
    pub fn arena_bytes_for(cfg: &ConfigMeta) -> usize {
        2 * cfg.n_layers * cfg.seq_len * cfg.d_model * 4
    }

    /// f32 bytes held by this cache's K/V arenas.
    pub fn arena_bytes(&self) -> usize {
        self.k
            .iter()
            .chain(self.v.iter())
            .map(|m| m.data.len() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn tiny() -> ConfigMeta {
        Manifest::builtin().config("tiny").clone()
    }

    #[test]
    fn arena_shapes_match_config() {
        let cfg = tiny();
        let c = KvCache::new(&cfg);
        assert_eq!(c.k.len(), cfg.n_layers);
        assert_eq!(c.v.len(), cfg.n_layers);
        assert_eq!((c.k[0].rows, c.k[0].cols), (cfg.seq_len, cfg.d_model));
        assert_eq!(c.max_len, cfg.seq_len);
        assert_eq!(c.len, 0);
        assert_eq!(c.arena_bytes(), KvCache::arena_bytes_for(&cfg));
        // llama arch precomputes RoPE tables for every position
        assert_eq!(c.cos.len(), cfg.seq_len * (cfg.d_model / cfg.n_heads) / 2);
    }

    #[test]
    fn reset_rewinds_cursor_only() {
        let cfg = tiny();
        let mut c = KvCache::new(&cfg);
        c.len = 5;
        c.k[0].row_mut(0)[0] = 7.0;
        c.reset();
        assert_eq!(c.len, 0);
        assert_eq!(c.remaining(), c.max_len);
        assert_eq!(c.k[0].row(0)[0], 7.0); // arena survives
    }

    #[test]
    fn truncate_rewinds_cursor_only() {
        let cfg = tiny();
        let mut c = KvCache::new(&cfg);
        c.len = 5;
        c.k[0].row_mut(4)[0] = 3.0;
        c.truncate(5); // no-op at the cursor
        assert_eq!(c.len, 5);
        c.truncate(2);
        assert_eq!(c.len, 2);
        assert_eq!(c.remaining(), c.max_len - 2);
        assert_eq!(c.k[0].row(4)[0], 3.0); // stale row survives, unreachable
    }

    #[test]
    #[should_panic(expected = "beyond cursor")]
    fn truncate_cannot_extend() {
        let cfg = tiny();
        let mut c = KvCache::new(&cfg);
        c.len = 2;
        c.truncate(3);
    }
}
