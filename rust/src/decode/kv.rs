//! Per-sequence KV cache over the paged block pool.
//!
//! A `KvCache` is a **block table**: position `pos` of layer `li` lives in
//! block `pos / block` at in-block row `li * block + pos % block` (see
//! [`kvpool`](super::kvpool) for the block layout).  `decode_step` appends
//! the current position's post-RoPE key and value rows and attends over
//! positions `0..=pos` through a [`KvLayerView`]; the batched
//! `decode_batch` kernel appends a whole token run (a prefill chunk, or one
//! token per scheduled slot) the same way, positions in ascending order.
//! Positions `>= len` are never read, so `reset()` (slot reuse in the
//! continuous-batching scheduler) releases the blocks back to the pool
//! without zeroing them.
//!
//! Blocks adopted from the prefix tree ([`adopt_prefix`](
//! KvCache::adopt_prefix)) are shared read-only; a write into a shared
//! block privatizes it first (copy-on-write), so tree-held K/V bits can
//! never be mutated by a slot.  With block-aligned prefix matching the COW
//! path is never actually taken — writes always target positions past the
//! adopted prefix — but the guard makes immutability structural rather
//! than conventional.
//!
//! The RoPE cos/sin tables (llama models) are precomputed here once per
//! cache instead of once per token; they are bit-identical to the tables
//! the full forward pass builds, which the decode parity gate relies on.

use std::sync::Arc;

use super::kvpool::{self, BlockRef, DEFAULT_KV_BLOCK};
use crate::model::ConfigMeta;
use crate::runtime::native::{layer_names, rope_tables, LayerNames};
use crate::tensor::Mat;

/// Per-sequence KV cache: a ref-counted block table + the position cursor.
pub struct KvCache {
    /// capacity in positions (== the model's `seq_len`)
    pub max_len: usize,
    /// filled positions; the next `decode_step` writes position `len`
    pub len: usize,
    /// model width (row length of every K/V row)
    pub d: usize,
    /// transformer layers each block spans
    pub n_layers: usize,
    /// positions per block
    pub block: usize,
    /// the block table: block `i` holds positions `i*block .. (i+1)*block`
    pub(crate) blocks: Vec<BlockRef>,
    /// RoPE tables `(max_len × dh/2)` flattened; empty for non-llama archs
    pub(crate) cos: Vec<f32>,
    pub(crate) sin: Vec<f32>,
    /// pre-rendered per-layer parameter names (process-wide table, shared):
    /// the per-token step does zero string formatting or cache lookups
    pub(crate) names: Arc<Vec<LayerNames>>,
}

impl KvCache {
    /// Fresh cache sized for `cfg` (capacity `seq_len` positions) with the
    /// default block size.  Blocks are acquired lazily as positions fill.
    pub fn new(cfg: &ConfigMeta) -> KvCache {
        KvCache::with_block(cfg, DEFAULT_KV_BLOCK)
    }

    /// Fresh cache with an explicit positions-per-block size (0 selects
    /// [`DEFAULT_KV_BLOCK`]).  Every cache that shares blocks through the
    /// prefix tree must use the tree's block size.
    pub fn with_block(cfg: &ConfigMeta, block: usize) -> KvCache {
        let dh = cfg.d_model / cfg.n_heads;
        let (cos, sin) = if cfg.arch == "llama" {
            rope_tables(cfg.seq_len, dh, cfg.rope_theta)
        } else {
            (Vec::new(), Vec::new())
        };
        KvCache {
            max_len: cfg.seq_len,
            len: 0,
            d: cfg.d_model,
            n_layers: cfg.n_layers,
            block: if block == 0 { DEFAULT_KV_BLOCK } else { block },
            blocks: Vec::new(),
            cos,
            sin,
            names: layer_names(cfg),
        }
    }

    /// Grow the block table so positions `< len` are all backed by storage,
    /// acquiring blocks from the process-wide pool as needed.
    pub(crate) fn ensure_len(&mut self, len: usize) {
        assert!(len <= self.max_len,
                "KvCache::ensure_len {} beyond capacity {}", len, self.max_len);
        while self.blocks.len() * self.block < len {
            self.blocks
                .push(kvpool::acquire(self.n_layers, self.block, self.d));
        }
    }

    #[inline]
    fn offset(&self, li: usize, pos: usize) -> (usize, usize) {
        (pos / self.block, (li * self.block + pos % self.block) * self.d)
    }

    /// Key row (post-RoPE) of layer `li` at position `pos`.
    #[inline]
    pub fn k_row(&self, li: usize, pos: usize) -> &[f32] {
        let (bi, o) = self.offset(li, pos);
        &self.blocks[bi].k[o..o + self.d]
    }

    /// Value row of layer `li` at position `pos`.
    #[inline]
    pub fn v_row(&self, li: usize, pos: usize) -> &[f32] {
        let (bi, o) = self.offset(li, pos);
        &self.blocks[bi].v[o..o + self.d]
    }

    /// Unique (writable) access to block `bi`, privatizing it first if it
    /// is shared with the prefix tree or another slot — the copy-on-write
    /// step.  Shared bits are copied verbatim, so the divergent sequence
    /// still reads identical prefix values.
    fn writable_block(&mut self, bi: usize) -> &mut kvpool::KvBlock {
        if Arc::get_mut(&mut self.blocks[bi]).is_none() {
            let copy = kvpool::privatize(&self.blocks[bi]);
            let shared = std::mem::replace(&mut self.blocks[bi], copy);
            kvpool::release(shared);
        }
        Arc::get_mut(&mut self.blocks[bi]).expect("unique after privatize")
    }

    /// Store the key row of layer `li` at position `pos` (copy-on-write
    /// when the target block is shared).
    pub(crate) fn set_k_row(&mut self, li: usize, pos: usize, row: &[f32]) {
        let (bi, o) = self.offset(li, pos);
        let d = self.d;
        self.writable_block(bi).k[o..o + d].copy_from_slice(row);
    }

    /// Store the value row of layer `li` at position `pos` (copy-on-write
    /// when the target block is shared).
    pub(crate) fn set_v_row(&mut self, li: usize, pos: usize, row: &[f32]) {
        let (bi, o) = self.offset(li, pos);
        let d = self.d;
        self.writable_block(bi).v[o..o + d].copy_from_slice(row);
    }

    /// Read-only attention view of one layer (implements [`KvRows`]).
    pub(crate) fn layer_view(&self, li: usize) -> KvLayerView<'_> {
        KvLayerView {
            blocks: &self.blocks,
            li_off: li * self.block,
            block: self.block,
            d: self.d,
        }
    }

    /// Clone of the block handle backing block-table entry `i` (the prefix
    /// tree ref-bumps completed prompts' blocks through this).
    pub(crate) fn block_ref(&self, i: usize) -> BlockRef {
        self.blocks[i].clone()
    }

    /// Start this (empty) cache from a matched prefix: the block table
    /// begins with `matched / block` shared read-only blocks and the cursor
    /// at `matched`, so prefill resumes at the divergence point instead of
    /// position 0.  `matched` must be block-aligned.
    pub(crate) fn adopt_prefix(&mut self, shared: &[BlockRef],
                               matched: usize) {
        assert!(self.len == 0 && self.blocks.is_empty(),
                "adopt_prefix on a non-empty cache");
        assert_eq!(matched % self.block, 0,
                   "prefix match must be block-aligned");
        let n = matched / self.block;
        assert!(shared.len() >= n, "prefix chain shorter than match");
        self.blocks.extend(shared[..n].iter().cloned());
        self.len = matched;
    }

    /// Rewind for slot reuse: the cursor returns to 0 and every block —
    /// private or shared — is released (private blocks return to the pool;
    /// shared ones just drop this table's reference).
    pub fn reset(&mut self) {
        for b in self.blocks.drain(..) {
            kvpool::release(b);
        }
        self.len = 0;
    }

    /// Partial rewind — the dual of [`KvCache::reset`].  Speculative decode
    /// rolls the cursor back past rejected draft positions with this.
    /// Whole blocks past the new cursor are released; only **private**
    /// storage actually returns to the pool (a shared block merely loses
    /// this table's reference — the prefix tree's copy is untouched).
    /// Positions `>= len` become unreachable again and are overwritten in
    /// place (or re-acquired) by the next append.  A rewind can never
    /// extend the cache, so `len` must not exceed the current cursor.
    pub fn truncate(&mut self, len: usize) {
        assert!(
            len <= self.len,
            "KvCache::truncate to {} beyond cursor {}",
            len,
            self.len
        );
        self.len = len;
        let keep = len.div_ceil(self.block);
        for b in self.blocks.drain(keep..) {
            kvpool::release(b);
        }
    }

    /// Remaining positions before the cache is full.
    pub fn remaining(&self) -> usize {
        self.max_len - self.len
    }

    /// f32 bytes one fully-extended cache of this shape holds (K + V, all
    /// layers, capacity positions) — the per-slot budget number the serving
    /// stats report.  Paged slots usually hold less (blocks are acquired
    /// lazily); see [`KvCache::arena_bytes`] for actual residency.
    pub fn arena_bytes_for(cfg: &ConfigMeta) -> usize {
        2 * cfg.n_layers * cfg.seq_len * cfg.d_model * 4
    }

    /// f32 bytes currently backed by this cache's block table (shared
    /// blocks count fully here; they are deduplicated process-wide by the
    /// pool, not per table).
    pub fn arena_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum()
    }

    /// Number of this cache's blocks whose storage is shared (also held by
    /// the prefix tree or another slot).
    pub fn shared_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| Arc::strong_count(b) > 1).count()
    }
}

impl Drop for KvCache {
    /// Return every still-held block to the pool (a dropped cache must not
    /// leak pool accounting).
    fn drop(&mut self) {
        self.reset();
    }
}

/// Position-indexed K/V row access for attention: one implementation over
/// the paged block table, one over plain matrices (the full-forward
/// reference shape).  `attention_step_row` is generic over this, which is
/// the whole paging abstraction — the kernel reads identical f32 rows
/// wherever they live, so storage layout cannot change logits.
pub(crate) trait KvRows {
    /// Key row (post-RoPE) at position `t`.
    fn k_row(&self, t: usize) -> &[f32];
    /// Value row at position `t`.
    fn v_row(&self, t: usize) -> &[f32];
}

/// [`KvRows`] over one layer of a paged cache's block table.
pub(crate) struct KvLayerView<'a> {
    blocks: &'a [BlockRef],
    /// `li * block`: row offset of this layer's band inside each block
    li_off: usize,
    block: usize,
    d: usize,
}

impl KvRows for KvLayerView<'_> {
    #[inline]
    fn k_row(&self, t: usize) -> &[f32] {
        let o = (self.li_off + t % self.block) * self.d;
        &self.blocks[t / self.block].k[o..o + self.d]
    }

    #[inline]
    fn v_row(&self, t: usize) -> &[f32] {
        let o = (self.li_off + t % self.block) * self.d;
        &self.blocks[t / self.block].v[o..o + self.d]
    }
}

/// [`KvRows`] over contiguous `(len × d)` K and V matrices — the layout
/// `attention_fwd` produces and the one `attention_step`'s unit tests use.
pub(crate) struct MatKv<'a> {
    /// keys, `(len × d)`
    pub k: &'a Mat,
    /// values, `(len × d)`
    pub v: &'a Mat,
}

impl KvRows for MatKv<'_> {
    #[inline]
    fn k_row(&self, t: usize) -> &[f32] {
        let d = self.k.cols;
        &self.k.data[t * d..(t + 1) * d]
    }

    #[inline]
    fn v_row(&self, t: usize) -> &[f32] {
        let d = self.v.cols;
        &self.v.data[t * d..(t + 1) * d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn tiny() -> ConfigMeta {
        Manifest::builtin().config("tiny").clone()
    }

    #[test]
    fn block_table_matches_config() {
        let cfg = tiny();
        let mut c = KvCache::with_block(&cfg, 4);
        assert_eq!(c.n_layers, cfg.n_layers);
        assert_eq!(c.d, cfg.d_model);
        assert_eq!(c.max_len, cfg.seq_len);
        assert_eq!((c.len, c.blocks.len()), (0, 0));
        assert_eq!(c.arena_bytes(), 0); // lazy: nothing acquired yet
        c.ensure_len(6); // 6 positions at block 4 → 2 blocks
        assert_eq!(c.blocks.len(), 2);
        assert_eq!(c.arena_bytes(),
                   2 * kvpool::KvBlock::bytes_for(cfg.n_layers, 4,
                                                  cfg.d_model));
        // a fully-extended table reaches the per-slot budget number
        c.ensure_len(cfg.seq_len);
        assert_eq!(c.arena_bytes(), KvCache::arena_bytes_for(&cfg));
        // llama arch precomputes RoPE tables for every position
        assert_eq!(c.cos.len(), cfg.seq_len * (cfg.d_model / cfg.n_heads) / 2);
    }

    #[test]
    fn rows_round_trip_through_blocks() {
        let cfg = tiny();
        let mut c = KvCache::with_block(&cfg, 4);
        c.ensure_len(7);
        let row: Vec<f32> = (0..cfg.d_model).map(|i| i as f32 + 0.5).collect();
        // position 6 lives in block 1; layer 1's band starts at row `block`
        c.set_k_row(1, 6, &row);
        c.set_v_row(1, 6, &row);
        assert_eq!(c.k_row(1, 6), &row[..]);
        assert_eq!(c.v_row(1, 6), &row[..]);
        let view = c.layer_view(1);
        assert_eq!(view.k_row(6), &row[..]);
        assert_eq!(view.v_row(6), &row[..]);
        // neighbours are untouched
        assert_ne!(c.k_row(0, 6), &row[..]);
    }

    #[test]
    fn reset_releases_blocks() {
        let cfg = tiny();
        let mut c = KvCache::with_block(&cfg, 4);
        c.ensure_len(5);
        c.len = 5;
        c.reset();
        assert_eq!(c.len, 0);
        assert_eq!(c.blocks.len(), 0);
        assert_eq!(c.remaining(), c.max_len);
    }

    #[test]
    fn truncate_releases_only_trailing_blocks() {
        let cfg = tiny();
        let mut c = KvCache::with_block(&cfg, 4);
        c.ensure_len(10); // 3 blocks
        c.len = 10;
        c.set_k_row(0, 5, &vec![3.0; cfg.d_model]);
        c.truncate(10); // no-op at the cursor
        assert_eq!((c.len, c.blocks.len()), (10, 3));
        c.truncate(6);
        // positions 0..6 span 2 blocks: the third was released, the block
        // holding the (now unreachable) tail of block 1 survives in place
        assert_eq!((c.len, c.blocks.len()), (6, 2));
        assert_eq!(c.remaining(), c.max_len - 6);
        assert_eq!(c.k_row(0, 5)[0], 3.0);
        c.truncate(0);
        assert_eq!((c.len, c.blocks.len()), (0, 0));
    }

    #[test]
    fn truncate_keeps_shared_blocks_alive_elsewhere() {
        // the PR-6 drafter-rollback contract: truncate must release only
        // this table's references — storage shared with the prefix tree
        // stays intact and still readable through the tree's handle
        let cfg = tiny();
        let mut c = KvCache::with_block(&cfg, 4);
        c.ensure_len(8);
        c.len = 8;
        let marker = vec![9.25f32; cfg.d_model];
        c.set_k_row(0, 1, &marker);
        let tree_ref = c.block_ref(0); // block 0 now shared
        assert_eq!(c.shared_blocks(), 1);
        c.truncate(0); // rollback past everything
        assert_eq!(c.blocks.len(), 0);
        // the tree's copy still holds the bits
        assert_eq!(&tree_ref.k[cfg.d_model..2 * cfg.d_model], &marker[..]);
        kvpool::release(tree_ref);
    }

    #[test]
    fn writes_into_shared_blocks_copy_on_write() {
        let cfg = tiny();
        let mut c = KvCache::with_block(&cfg, 4);
        c.ensure_len(4);
        c.len = 4;
        let before = vec![1.5f32; cfg.d_model];
        c.set_k_row(0, 2, &before);
        let tree_ref = c.block_ref(0);
        // overwriting a position inside the shared block privatizes it:
        // the slot sees the new bits, the tree's handle the old ones
        let after = vec![-2.5f32; cfg.d_model];
        c.set_k_row(0, 2, &after);
        assert_eq!(c.k_row(0, 2), &after[..]);
        assert_eq!(&tree_ref.k[2 * cfg.d_model..3 * cfg.d_model],
                   &before[..]);
        assert_eq!(c.shared_blocks(), 0); // divergence made it private
        kvpool::release(tree_ref);
    }

    #[test]
    fn adopt_prefix_starts_cursor_past_shared_blocks() {
        let cfg = tiny();
        let mut warm = KvCache::with_block(&cfg, 4);
        warm.ensure_len(8);
        warm.len = 8;
        let row = vec![7.0f32; cfg.d_model];
        warm.set_k_row(0, 3, &row);
        let chain = vec![warm.block_ref(0), warm.block_ref(1)];
        let mut c = KvCache::with_block(&cfg, 4);
        c.adopt_prefix(&chain, 8);
        assert_eq!(c.len, 8);
        assert_eq!(c.blocks.len(), 2);
        assert_eq!(c.k_row(0, 3), &row[..]); // reads go through shared bits
        assert_eq!(c.shared_blocks(), 2);
        for b in chain {
            kvpool::release(b);
        }
    }

    #[test]
    #[should_panic(expected = "beyond cursor")]
    fn truncate_cannot_extend() {
        let cfg = tiny();
        let mut c = KvCache::new(&cfg);
        c.ensure_len(2);
        c.len = 2;
        c.truncate(3);
    }
}
