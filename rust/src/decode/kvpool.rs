//! Process-wide paged KV block pool: fixed-size, ref-counted K/V storage
//! shared by every decode slot (and the speculative drafter's mirrored
//! caches).
//!
//! A [`KvBlock`] holds `block` consecutive sequence positions for **all**
//! layers of one sequence: layer `li`, in-block position `p` lives at row
//! `li * block + p` of the block's K (and V) storage, each row `d_model`
//! floats.  A [`KvCache`](super::KvCache) is a table of [`BlockRef`]s
//! (`Arc<KvBlock>`) instead of one monolithic per-slot arena, which is what
//! makes prefix sharing possible: the prefix tree
//! ([`PrefixTree`](super::prefix::PrefixTree)) and any number of slots can
//! hold the *same* immutable block, and a slot that needs to write into a
//! shared block first privatizes it (copy-on-write — see
//! `KvCache::set_k_row`).
//!
//! Blocks are recycled through a process-wide free list keyed by shape
//! (`n_layers`, `block`, `d`), mirroring the `layer_names` process-wide
//! table: a retired slot's private blocks go back to the pool and the next
//! admission reuses them without reallocating.  Recycled blocks are **not**
//! zeroed — attention only ever reads positions `< cache.len`, and every
//! such position was written by the current generation before any read, so
//! stale floats are unreachable by construction (the same argument that
//! lets `KvCache::reset` skip zeroing).
//!
//! # Determinism
//!
//! The pool stores bits; it never transforms them.  Whether a position's
//! K/V row lives in a freshly allocated block, a recycled one, or a block
//! shared through the prefix tree, attention reads the identical f32
//! values — so paged storage cannot change any logit bit
//! (`rust/tests/prefix_cache.rs` and the decode parity gates prove it).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Default positions per block (`--kv-block` / `ExperimentConfig::kv_block`
/// override it).
pub const DEFAULT_KV_BLOCK: usize = 16;

/// Free blocks retained per shape; beyond this, released blocks are dropped
/// so an atypical burst cannot pin memory forever.
const FREE_CAP_PER_SHAPE: usize = 4096;

/// One fixed-size paged unit of KV storage: `block` positions × all layers.
///
/// Layer `li`, in-block position `p` is the `d`-float slice starting at
/// `(li * block + p) * d` of [`KvBlock::k`] (keys, post-RoPE) and
/// [`KvBlock::v`] (values).
#[derive(Clone)]
pub struct KvBlock {
    /// keys for all layers, `(n_layers · block) × d` row-major
    pub(crate) k: Vec<f32>,
    /// values for all layers, same layout as `k`
    pub(crate) v: Vec<f32>,
    /// (n_layers, block, d) — the pool's free-list key
    pub(crate) shape: (usize, usize, usize),
}

/// Shared handle to one block.  Cloning bumps the ref count; the prefix
/// tree and any number of slot block tables may hold the same block.
pub type BlockRef = Arc<KvBlock>;

impl KvBlock {
    /// Bytes of f32 K+V storage one block of this shape holds.
    pub fn bytes_for(n_layers: usize, block: usize, d: usize) -> usize {
        2 * n_layers * block * d * 4
    }

    /// Bytes of f32 K+V storage this block holds.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

/// Per-shape free list + live count (per-shape so concurrent users of
/// different shapes — e.g. parallel tests — cannot perturb each other's
/// accounting).
#[derive(Default)]
struct ShapePool {
    free: Vec<BlockRef>,
    live: usize,
}

fn pool() -> &'static Mutex<BTreeMap<(usize, usize, usize), ShapePool>> {
    static POOL: OnceLock<Mutex<BTreeMap<(usize, usize, usize), ShapePool>>> =
        OnceLock::new();
    POOL.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Take a block of the given shape from the free list (or allocate one).
/// The block is uniquely owned; its contents are unspecified (see the
/// module docs for why that is safe).
pub(crate) fn acquire(n_layers: usize, block: usize, d: usize) -> BlockRef {
    let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
    let sp = p.entry((n_layers, block, d)).or_default();
    sp.live += 1;
    sp.free.pop().unwrap_or_else(|| {
        let n = n_layers * block * d;
        Arc::new(KvBlock {
            k: vec![0.0; n],
            v: vec![0.0; n],
            shape: (n_layers, block, d),
        })
    })
}

/// Drop one reference to a block.  If it was the last, the block returns to
/// the free list (bounded; surplus is freed) and stops counting as live.
/// Blocks still shared elsewhere (prefix tree, another slot) just lose one
/// ref and stay live.
pub(crate) fn release(b: BlockRef) {
    if let Ok(block) = Arc::try_unwrap(b) {
        let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
        let sp = p.entry(block.shape).or_default();
        sp.live -= 1;
        if sp.free.len() < FREE_CAP_PER_SHAPE {
            sp.free.push(Arc::new(block));
        }
    }
}

/// Pool-accounted private copy of a shared block — the copy-on-write step.
/// The copy is acquired through the pool (so the gauges stay honest) and
/// then overwritten with `src`'s bits, bit-for-bit.
pub(crate) fn privatize(src: &BlockRef) -> BlockRef {
    let (nl, bl, d) = src.shape;
    let mut out = acquire(nl, bl, d);
    let m = Arc::get_mut(&mut out).expect("freshly acquired block is unique");
    m.k.copy_from_slice(&src.k);
    m.v.copy_from_slice(&src.v);
    out
}

/// Point-in-time pool occupancy, for the always-on serving gauges.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// blocks referenced by at least one cache or the prefix tree
    pub live_blocks: usize,
    /// recycled blocks parked on the free lists
    pub free_blocks: usize,
}

/// Whole-pool occupancy, summed over every shape this process has used.
pub fn stats() -> PoolStats {
    let p = pool().lock().unwrap_or_else(|e| e.into_inner());
    let mut s = PoolStats::default();
    for sp in p.values() {
        s.live_blocks += sp.live;
        s.free_blocks += sp.free.len();
    }
    s
}

/// Occupancy of one shape's sub-pool (used by tests, which pick shapes no
/// other code touches so parallel test threads cannot skew the counts).
#[cfg(test)]
fn stats_for(n_layers: usize, block: usize, d: usize) -> PoolStats {
    let p = pool().lock().unwrap_or_else(|e| e.into_inner());
    p.get(&(n_layers, block, d))
        .map(|sp| PoolStats { live_blocks: sp.live, free_blocks: sp.free.len() })
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_recycles() {
        // shape unique to this test: parallel tests can't perturb it
        let (nl, bl, d) = (7, 3, 5);
        let a = acquire(nl, bl, d);
        assert_eq!(a.k.len(), nl * bl * d);
        assert_eq!(a.v.len(), nl * bl * d);
        assert_eq!(a.shape, (nl, bl, d));
        assert_eq!(a.bytes(), KvBlock::bytes_for(nl, bl, d));
        assert_eq!(stats_for(nl, bl, d),
                   PoolStats { live_blocks: 1, free_blocks: 0 });
        release(a);
        assert_eq!(stats_for(nl, bl, d),
                   PoolStats { live_blocks: 0, free_blocks: 1 });
        // the next acquire of the same shape reuses the parked block
        let b = acquire(nl, bl, d);
        assert_eq!(stats_for(nl, bl, d),
                   PoolStats { live_blocks: 1, free_blocks: 0 });
        release(b);
    }

    #[test]
    fn shared_block_stays_live_until_last_release() {
        let (nl, bl, d) = (7, 3, 6);
        let a = acquire(nl, bl, d);
        let shared = a.clone(); // e.g. the prefix tree's reference
        release(a);
        // one holder remains: still live, not recycled
        assert_eq!(stats_for(nl, bl, d),
                   PoolStats { live_blocks: 1, free_blocks: 0 });
        release(shared);
        assert_eq!(stats_for(nl, bl, d),
                   PoolStats { live_blocks: 0, free_blocks: 1 });
    }

    #[test]
    fn privatize_copies_bits_and_accounts() {
        let (nl, bl, d) = (7, 3, 7);
        let mut a = acquire(nl, bl, d);
        Arc::get_mut(&mut a).unwrap().k[5] = 42.5;
        let tree_ref = a.clone();
        let copy = privatize(&a);
        assert_eq!(stats_for(nl, bl, d).live_blocks, 2);
        assert_eq!(copy.k, a.k);
        assert_eq!(copy.v, a.v);
        assert!(!Arc::ptr_eq(&copy, &a));
        release(copy);
        release(a);
        release(tree_ref);
        assert_eq!(stats_for(nl, bl, d).live_blocks, 0);
    }
}
