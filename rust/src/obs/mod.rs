//! Observability substrate: thread-aware spans, monotonic counters, bounded
//! histograms, point-in-time gauges, per-kernel timing aggregates, and a
//! bounded trace-event ring exportable as chrome://tracing JSON — all
//! dependency-free, built on `util::json` like the rest of the crate.
//!
//! # The observe-only contract
//!
//! Nothing recorded here may ever feed back into computation.  Hooks read
//! clocks and copy values *out* of the hot paths; they never influence
//! scheduling decisions, kernel dispatch, sampling, or any other value the
//! engine produces.  Tracing on vs. off — at any thread count and any
//! kernel backend — therefore leaves all logits, generated tokens, and
//! compression plans **bit-identical** (`rust/tests/trace_equiv.rs` is the
//! gate).  If you add a hook, keep it on the observe side of that line.
//!
//! # Near-zero cost when disabled
//!
//! Every gated hook ([`span`], [`emit`], [`counter_add`], [`histo_record`],
//! [`kernel_record`]) starts with one relaxed atomic load ([`enabled`]) and
//! returns immediately when tracing is off — the same discipline
//! `linalg::kernels` uses for backend dispatch.  [Gauges](gauge_set) and
//! [reports](set_report) are *not* gated: they belong to the always-on
//! metrics surface (the wire `metrics` snapshot), are written at
//! per-scheduler-iteration / per-compression-run granularity, and cost one
//! short mutex hold each — far off any per-token or per-GEMM path.
//!
//! # Bounded memory
//!
//! All storage is bounded: the event ring holds at most [`RING_CAP`]
//! events (oldest overwritten first, overwrites counted in `dropped`),
//! histograms are fixed at [`HISTO_BINS`] power-of-two bins, and counters /
//! gauges / kernel aggregates are one map entry per distinct name.  A
//! serving run can trace forever without growing without bound.
//!
//! # Enabling
//!
//! Three equivalent knobs, mirroring `threads` / `no_simd`:
//!
//! * `PALLAS_TRACE=1` environment variable (read once per process);
//! * `ExperimentConfig::trace` (applied by `coordinator::prepare`);
//! * `--trace` / `--trace-out FILE` on the CLI (`--trace-out` also writes
//!   the chrome-trace JSON on exit — open it at `ui.perfetto.dev`).
//!
//! # Trace model
//!
//! Events are chrome://tracing "complete" (`ph:"X"`) spans.  Engine-side
//! work (decode steps, prefill chunks, draft/verify, kernel batches) is
//! recorded on the real thread that ran it under [`PID_ENGINE`];
//! per-request lifecycle spans (queue → prefill → decode) are emitted on a
//! synthetic request track ([`PID_REQUESTS`], `tid` = request id) so
//! Perfetto shows one swim-lane per request.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Maximum events held by the global trace ring; older events are
/// overwritten (and counted as dropped) once a run exceeds this.
pub const RING_CAP: usize = 65_536;

/// Fixed number of power-of-two histogram bins: bin `k` counts values `v`
/// with `v.max(1)` in `[2^k, 2^(k+1))`, so 32 bins cover any u64 duration
/// in microseconds a run can realistically produce.
pub const HISTO_BINS: usize = 32;

/// `pid` of the engine track: events carry the real worker thread id.
pub const PID_ENGINE: u32 = 1;

/// `pid` of the synthetic per-request track: `tid` is the request id, so
/// each request renders as its own row (queue → prefill → decode spans).
pub const PID_REQUESTS: u32 = 2;

// ---------------------------------------------------------------------------
// enablement — the relaxed-atomic gate every hook starts with
// ---------------------------------------------------------------------------

const OBS_UNSET: u8 = 0;
const OBS_OFF: u8 = 1;
const OBS_ON: u8 = 2;

/// Tri-state so the `PALLAS_TRACE` env read happens at most once, exactly
/// like `linalg::kernels::MODE`; [`set_enabled`] stores directly.
static STATE: AtomicU8 = AtomicU8::new(OBS_UNSET);

/// `PALLAS_TRACE` semantics: any non-empty value other than `0` enables
/// tracing.  Factored out so the parse is unit-testable.
fn parse_trace_env(v: Option<&str>) -> bool {
    match v {
        Some(s) => {
            let t = s.trim();
            !t.is_empty() && t != "0"
        }
        None => false,
    }
}

fn env_trace() -> bool {
    static TRACE: OnceLock<bool> = OnceLock::new();
    *TRACE
        .get_or_init(|| parse_trace_env(std::env::var("PALLAS_TRACE").ok().as_deref()))
}

#[inline]
fn state() -> u8 {
    let s = STATE.load(Ordering::Relaxed);
    if s != OBS_UNSET {
        return s;
    }
    let r = if env_trace() { OBS_ON } else { OBS_OFF };
    STATE.store(r, Ordering::Relaxed);
    r
}

/// Whether tracing hooks record anything right now — one relaxed atomic
/// load, the entire cost of a disabled hook.
#[inline]
pub fn enabled() -> bool {
    state() == OBS_ON
}

/// Programmatic override (`ExperimentConfig::trace`, the CLI, tests).
/// Process-global, like `exec::set_threads` / `kernels::force_backend`.
pub fn set_enabled(on: bool) {
    STATE.store(if on { OBS_ON } else { OBS_OFF }, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// clock + thread ids
// ---------------------------------------------------------------------------

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (first clock use).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Microseconds-since-epoch of an [`Instant`] stamped elsewhere (request
/// arrival/admission times); saturates to 0 for stamps before the epoch.
pub fn us_of(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

/// Small dense per-thread id for the engine track (`std::thread::ThreadId`
/// is opaque; chrome-trace wants small integers).  Assigned on first use,
/// stable for the thread's lifetime.
pub fn tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

// ---------------------------------------------------------------------------
// the bounded event ring
// ---------------------------------------------------------------------------

/// One chrome-trace "complete" span (`ph:"X"`).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Span name (the Perfetto slice label).
    pub name: String,
    /// Category, e.g. `"engine"`, `"request"`, `"compress"`, `"exec"`.
    pub cat: &'static str,
    /// Start, microseconds since the trace epoch.
    pub ts_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// Track: [`PID_ENGINE`] or [`PID_REQUESTS`].
    pub pid: u32,
    /// Thread id ([`tid`]) or, on the request track, the request id.
    pub tid: u64,
    /// Extra key/value payload rendered in the Perfetto args pane.
    pub args: Vec<(&'static str, Json)>,
}

impl TraceEvent {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("cat", Json::str(self.cat)),
            ("ph", Json::str("X")),
            ("ts", Json::num(self.ts_us as f64)),
            ("dur", Json::num(self.dur_us as f64)),
            ("pid", Json::num(self.pid as f64)),
            ("tid", Json::num(self.tid as f64)),
        ];
        if !self.args.is_empty() {
            pairs.push((
                "args",
                Json::Obj(self.args.iter()
                    .map(|(k, v)| (k.to_string(), v.clone()))
                    .collect()),
            ));
        }
        Json::obj(pairs)
    }
}

/// Fixed-capacity circular buffer: once full, each push overwrites the
/// oldest event and increments `dropped`.
struct EventRing {
    cap: usize,
    buf: Vec<TraceEvent>,
    /// Next write position once `buf` has reached `cap`.
    next: usize,
    dropped: u64,
}

impl EventRing {
    fn new(cap: usize) -> Self {
        EventRing { cap, buf: Vec::new(), next: 0, dropped: 0 }
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events oldest-first (the ring rotation is undone).
    fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.next..]);
        out.extend_from_slice(&self.buf[..self.next]);
        out
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.next = 0;
        self.dropped = 0;
    }
}

fn ring() -> &'static Mutex<EventRing> {
    static RING: OnceLock<Mutex<EventRing>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(EventRing::new(RING_CAP)))
}

/// Record one pre-built event into the ring.  No-op when tracing is off.
pub fn emit(ev: TraceEvent) {
    if !enabled() {
        return;
    }
    ring().lock().expect("obs ring poisoned").push(ev);
}

/// Record a complete span whose endpoints were stamped elsewhere — how the
/// scheduler emits per-request queue/prefill/decode lifecycle spans after
/// the fact, on the request track.  No-op when tracing is off.
#[allow(clippy::too_many_arguments)]
pub fn emit_span(name: &str, cat: &'static str, ts_us: u64, dur_us: u64,
                 pid: u32, tid: u64, args: Vec<(&'static str, Json)>) {
    if !enabled() {
        return;
    }
    emit(TraceEvent { name: name.to_string(), cat, ts_us, dur_us, pid, tid,
                      args });
}

// ---------------------------------------------------------------------------
// span guard
// ---------------------------------------------------------------------------

/// RAII span: created by [`span`], records a complete event over its
/// lifetime on drop.  When tracing is off it is inert (one atomic load at
/// creation, nothing at drop).
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start_us: u64,
    args: Vec<(&'static str, Json)>,
    active: bool,
}

/// Open a span covering the enclosing scope on the current thread's engine
/// track.  `let _sp = obs::span("decode_step", "engine");`
pub fn span(name: &'static str, cat: &'static str) -> Span {
    let active = enabled();
    Span {
        name,
        cat,
        start_us: if active { now_us() } else { 0 },
        args: Vec::new(),
        active,
    }
}

impl Span {
    /// Attach an arg (shown in the Perfetto args pane).  Builder-style:
    /// `obs::span("verify", "engine").arg("slots", Json::num(n as f64))`.
    pub fn arg(mut self, key: &'static str, value: Json) -> Self {
        if self.active {
            self.args.push((key, value));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_us();
        emit(TraceEvent {
            name: self.name.to_string(),
            cat: self.cat,
            ts_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            pid: PID_ENGINE,
            tid: tid(),
            args: std::mem::take(&mut self.args),
        });
    }
}

// ---------------------------------------------------------------------------
// counters + histograms (gated) and gauges (always-on)
// ---------------------------------------------------------------------------

fn counters() -> &'static Mutex<BTreeMap<&'static str, u64>> {
    static C: OnceLock<Mutex<BTreeMap<&'static str, u64>>> = OnceLock::new();
    C.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Add to a monotonic counter.  No-op when tracing is off.
pub fn counter_add(name: &'static str, n: u64) {
    if !enabled() {
        return;
    }
    *counters().lock().expect("obs counters poisoned").entry(name)
        .or_insert(0) += n;
}

/// Current value of a counter (0 if never written).
pub fn counter(name: &str) -> u64 {
    counters().lock().expect("obs counters poisoned").get(name).copied()
        .unwrap_or(0)
}

/// Fixed-bin power-of-two histogram: bounded memory whatever the value
/// distribution.  Tracks count / sum / max alongside the bins.
#[derive(Clone, Debug, Default)]
pub struct Histo {
    /// `bins[k]` counts recorded values `v` with `v.max(1)` in
    /// `[2^k, 2^(k+1))`.
    pub bins: [u64; HISTO_BINS],
    /// Number of recorded values.
    pub count: u64,
    /// Sum of recorded values.
    pub sum: u64,
    /// Largest recorded value.
    pub max: u64,
}

impl Histo {
    fn record(&mut self, v: u64) {
        let bin = (63 - v.max(1).leading_zeros() as usize).min(HISTO_BINS - 1);
        self.bins[bin] += 1;
        self.count += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    fn to_json(&self) -> Json {
        // trim trailing empty bins: deterministic and compact on the wire
        let hi = self.bins.iter().rposition(|&b| b > 0).map_or(0, |i| i + 1);
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("max", Json::num(self.max as f64)),
            ("bins_pow2",
             Json::arr(self.bins[..hi].iter().map(|&b| Json::num(b as f64)))),
        ])
    }
}

fn histos() -> &'static Mutex<BTreeMap<&'static str, Histo>> {
    static H: OnceLock<Mutex<BTreeMap<&'static str, Histo>>> = OnceLock::new();
    H.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Record a value into a named histogram.  No-op when tracing is off.
pub fn histo_record(name: &'static str, v: u64) {
    if !enabled() {
        return;
    }
    histos().lock().expect("obs histos poisoned").entry(name)
        .or_default().record(v);
}

/// A copy of a named histogram, if it has ever been written.
pub fn histo(name: &str) -> Option<Histo> {
    histos().lock().expect("obs histos poisoned").get(name).cloned()
}

fn gauges() -> &'static Mutex<BTreeMap<String, f64>> {
    static G: OnceLock<Mutex<BTreeMap<String, f64>>> = OnceLock::new();
    G.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Set a point-in-time gauge (active slots, KV occupancy, pool sizes).
/// Always on — gauges feed the wire `metrics` snapshot, which must work
/// without tracing; writers publish at scheduler-iteration granularity.
pub fn gauge_set(name: &str, v: f64) {
    let mut g = gauges().lock().expect("obs gauges poisoned");
    match g.get_mut(name) {
        Some(slot) => *slot = v,
        None => {
            g.insert(name.to_string(), v);
        }
    }
}

/// All gauges as one JSON object (the `gauges` block of the `metrics`
/// wire snapshot).
pub fn gauges_json() -> Json {
    Json::Obj(gauges().lock().expect("obs gauges poisoned").iter()
        .map(|(k, &v)| (k.clone(), Json::num(v)))
        .collect())
}

// ---------------------------------------------------------------------------
// kernel timing aggregates
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
struct KernelStat {
    calls: u64,
    ns: u64,
    macs: u64,
}

type KernelKey = (&'static str, &'static str);

fn kernel_stats() -> &'static Mutex<BTreeMap<KernelKey, KernelStat>> {
    static K: OnceLock<Mutex<BTreeMap<KernelKey, KernelStat>>> =
        OnceLock::new();
    K.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Record one GEMM-shaped kernel call: `(m, k, n)` gives `m·k·n` MACs, so
/// per-(kernel, backend) GFLOP/s falls out as `2·macs / ns`.  Aggregated —
/// not one ring event per call — because decode issues thousands of small
/// GEMMs per second and per-call events would only churn the ring.  No-op
/// when tracing is off.
pub fn kernel_record(kernel: &'static str, backend: &'static str, m: usize,
                     k: usize, n: usize, ns: u64) {
    if !enabled() {
        return;
    }
    let st_macs = (m as u64) * (k as u64) * (n as u64);
    let mut map = kernel_stats().lock().expect("obs kernels poisoned");
    let st = map.entry((kernel, backend)).or_default();
    st.calls += 1;
    st.ns += ns;
    st.macs += st_macs;
}

/// Kernel aggregates as JSON: `{"matmul/avx2": {calls, ns, macs, gflops}}`.
pub fn kernel_stats_json() -> Json {
    Json::Obj(kernel_stats().lock().expect("obs kernels poisoned").iter()
        .map(|((kernel, backend), st)| {
            let gflops = if st.ns > 0 {
                2.0 * st.macs as f64 / st.ns as f64
            } else {
                0.0
            };
            (format!("{kernel}/{backend}"),
             Json::obj(vec![
                 ("calls", Json::num(st.calls as f64)),
                 ("ns", Json::num(st.ns as f64)),
                 ("macs", Json::num(st.macs as f64)),
                 ("gflops", Json::num(gflops)),
             ]))
        })
        .collect())
}

// ---------------------------------------------------------------------------
// named reports (compress_report.json et al.)
// ---------------------------------------------------------------------------

fn reports() -> &'static Mutex<BTreeMap<String, Json>> {
    static R: OnceLock<Mutex<BTreeMap<String, Json>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Stash a named report document (e.g. the compression pipeline's
/// per-matrix selection record) for a CLI flag to export later.  Always on:
/// report assembly happens once per offline run, never on a serving path.
pub fn set_report(name: &str, doc: Json) {
    reports().lock().expect("obs reports poisoned")
        .insert(name.to_string(), doc);
}

/// Fetch a stashed report by name.
pub fn report(name: &str) -> Option<Json> {
    reports().lock().expect("obs reports poisoned").get(name).cloned()
}

// ---------------------------------------------------------------------------
// export
// ---------------------------------------------------------------------------

fn process_name_meta(pid: u32, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(0.0)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// The whole ring as a chrome://tracing Trace Event Format document —
/// `{"traceEvents": [...]}` — loadable at `ui.perfetto.dev` or
/// `chrome://tracing`.  Includes process-name metadata so the engine and
/// request tracks are labeled.
pub fn chrome_trace_json() -> Json {
    let (events, dropped) = {
        let r = ring().lock().expect("obs ring poisoned");
        (r.snapshot(), r.dropped)
    };
    let mut arr = vec![
        process_name_meta(PID_ENGINE, "engine"),
        process_name_meta(PID_REQUESTS, "requests"),
    ];
    arr.extend(events.iter().map(TraceEvent::to_json));
    Json::obj(vec![
        ("traceEvents", Json::Arr(arr)),
        ("displayTimeUnit", Json::str("ms")),
        ("otherData", Json::obj(vec![
            ("dropped_events", Json::num(dropped as f64)),
        ])),
    ])
}

/// Write [`chrome_trace_json`] to a file (the `--trace-out` flag).
pub fn write_chrome_trace(path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json().to_string_pretty() + "\n")
}

/// The wire `trace` snapshot: the most recent `max_events` ring events plus
/// counters, histograms, and kernel aggregates — the protocol-side
/// companion of the `metrics` snapshot.
pub fn snapshot_json(max_events: usize) -> Json {
    let (events, dropped, total) = {
        let r = ring().lock().expect("obs ring poisoned");
        let snap = r.snapshot();
        let total = snap.len();
        let tail = snap.len().saturating_sub(max_events);
        (snap[tail..].to_vec(), r.dropped, total)
    };
    Json::obj(vec![
        ("type", Json::str("trace")),
        ("enabled", Json::Bool(enabled())),
        ("events_total", Json::num(total as f64)),
        ("events_dropped", Json::num(dropped as f64)),
        ("events",
         Json::arr(events.iter().map(TraceEvent::to_json))),
        ("counters",
         Json::Obj(counters().lock().expect("obs counters poisoned").iter()
             .map(|(k, &v)| (k.to_string(), Json::num(v as f64)))
             .collect())),
        ("histograms",
         Json::Obj(histos().lock().expect("obs histos poisoned").iter()
             .map(|(k, h)| (k.to_string(), h.to_json()))
             .collect())),
        ("kernels", kernel_stats_json()),
        ("gauges", gauges_json()),
    ])
}

/// Clear the ring, counters, histograms, kernel aggregates, and gauges —
/// for bench harnesses attributing one run at a time, and for tests.
/// Stashed reports survive (they describe a completed offline run).
pub fn reset() {
    ring().lock().expect("obs ring poisoned").clear();
    counters().lock().expect("obs counters poisoned").clear();
    histos().lock().expect("obs histos poisoned").clear();
    kernel_stats().lock().expect("obs kernels poisoned").clear();
    gauges().lock().expect("obs gauges poisoned").clear();
}

// ---------------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the unit tests that flip the process-global enable flag.
    /// (Flipping it mid-run is harmless to every other test by the
    /// observe-only contract, but these tests also assert on shared
    /// storage, so they take turns.)
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static L: OnceLock<Mutex<()>> = OnceLock::new();
        L.get_or_init(|| Mutex::new(()))
            .lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn trace_env_parse() {
        assert!(!parse_trace_env(None));
        assert!(!parse_trace_env(Some("")));
        assert!(!parse_trace_env(Some(" ")));
        assert!(!parse_trace_env(Some("0")));
        assert!(parse_trace_env(Some("1")));
        assert!(parse_trace_env(Some("chrome")));
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        // a local ring, not the global one: exact assertions without
        // cross-test interference
        let mut r = EventRing::new(4);
        let ev = |i: u64| TraceEvent {
            name: format!("e{i}"),
            cat: "test",
            ts_us: i,
            dur_us: 1,
            pid: PID_ENGINE,
            tid: 1,
            args: Vec::new(),
        };
        for i in 0..10 {
            r.push(ev(i));
        }
        assert_eq!(r.buf.len(), 4);
        assert_eq!(r.dropped, 6);
        let snap = r.snapshot();
        // oldest-first, holding exactly the newest four
        let ts: Vec<u64> = snap.iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, vec![6, 7, 8, 9]);
    }

    #[test]
    fn histogram_bins_are_bounded_and_correct() {
        let mut h = Histo::default();
        h.record(0); // clamps into bin 0
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(1024);
        h.record(u64::MAX); // clamps into the last bin
        assert_eq!(h.count, 6);
        assert_eq!(h.max, u64::MAX);
        assert_eq!(h.bins[0], 2); // 0 (clamped) and 1
        assert_eq!(h.bins[1], 2); // 2 and 3
        assert_eq!(h.bins[10], 1); // 1024
        assert_eq!(h.bins[HISTO_BINS - 1], 1);
        let j = h.to_json();
        assert_eq!(j.usize_or("count", 0), 6);
        // serialized bins reparse through the repo's own JSON layer
        let text = j.to_string();
        let back = crate::util::json::parse(&text).expect("histo json");
        assert_eq!(back.usize_or("count", 0), 6);
    }

    #[test]
    fn disabled_hooks_record_nothing() {
        let _g = test_lock();
        set_enabled(false);
        counter_add("obs.test.disabled", 5);
        histo_record("obs.test.disabled_h", 5);
        emit_span("nothing", "test", 0, 1, PID_ENGINE, 1, Vec::new());
        {
            let _sp = span("nothing_span", "test");
        }
        assert_eq!(counter("obs.test.disabled"), 0);
        assert!(histo("obs.test.disabled_h").is_none());
    }

    #[test]
    fn enabled_hooks_record_and_export_well_formed_json() {
        let _g = test_lock();
        set_enabled(true);
        counter_add("obs.test.enabled", 2);
        counter_add("obs.test.enabled", 3);
        histo_record("obs.test.enabled_h", 100);
        kernel_record("testmm", "portable", 4, 8, 16, 1000);
        {
            let _sp = span("unit_span", "test").arg("x", Json::num(7.0));
        }
        emit_span("req_span", "request", 10, 20, PID_REQUESTS, 42,
                  vec![("id", Json::num(42.0))]);
        set_enabled(false);

        assert_eq!(counter("obs.test.enabled"), 5);
        assert_eq!(histo("obs.test.enabled_h").expect("histo").count, 1);

        // chrome export: reparses via util::json and carries the required
        // Trace Event Format keys on every event
        let doc = chrome_trace_json();
        let text = doc.to_string_pretty();
        let back = crate::util::json::parse(&text).expect("chrome json");
        let events = back.get("traceEvents").and_then(Json::as_arr)
            .expect("traceEvents array");
        assert!(!events.is_empty());
        for ev in events {
            assert!(ev.get("name").is_some(), "event missing name: {ev:?}");
            assert!(ev.get("ph").is_some(), "event missing ph: {ev:?}");
            assert!(ev.get("pid").is_some(), "event missing pid: {ev:?}");
            assert!(ev.get("tid").is_some(), "event missing tid: {ev:?}");
        }
        let names: Vec<String> = events.iter()
            .map(|e| e.str_or("name", "")).collect();
        assert!(names.iter().any(|n| n == "unit_span"));
        assert!(names.iter().any(|n| n == "req_span"));

        // the wire snapshot caps its event list but reports totals
        let snap = snapshot_json(1);
        assert_eq!(snap.get("events").and_then(Json::as_arr).expect("events")
                       .len(), 1);
        assert!(snap.usize_or("events_total", 0) >= 2);
        let kj = snap.get("kernels").expect("kernels");
        assert!(kj.get("testmm/portable").is_some());
    }

    #[test]
    fn gauges_are_always_on() {
        let _g = test_lock();
        set_enabled(false);
        gauge_set("obs.test.gauge", 3.5);
        let j = gauges_json();
        assert_eq!(j.f64_or("obs.test.gauge", 0.0), 3.5);
        gauge_set("obs.test.gauge", 4.5);
        assert_eq!(gauges_json().f64_or("obs.test.gauge", 0.0), 4.5);
    }

    #[test]
    fn reports_roundtrip() {
        let _g = test_lock();
        set_report("obs.test.report",
                   Json::obj(vec![("k", Json::num(1.0))]));
        assert_eq!(report("obs.test.report").expect("report")
                       .f64_or("k", 0.0), 1.0);
        assert!(report("obs.test.missing").is_none());
    }

    #[test]
    fn tid_is_stable_per_thread() {
        let a = tid();
        let b = tid();
        assert_eq!(a, b);
        let other = std::thread::spawn(tid).join().expect("tid thread");
        assert_ne!(a, other);
    }
}
