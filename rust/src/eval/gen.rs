//! Generation-path evaluation: teacher-forced greedy next-token accuracy
//! driven through the KV-cached incremental decode kernel.
//!
//! Perplexity (`eval::ppl`) measures the same model through the batched
//! prefill graph; this metric walks each held-out sequence token by token
//! through `decode_step`, predicting greedily at every position.  Because
//! decode logits bit-match the full forward, the number doubles as an
//! end-to-end exercise of the cache over a full-context horizon — a
//! regression here that ppl misses means the incremental path drifted.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::data::Corpus;
use crate::decode::sampler::argmax;
use crate::model::ParamStore;
use crate::runtime::session::Session;
use crate::tensor::Mat;

/// Teacher-forced greedy next-token accuracy over up to `max_rows` held-out
/// sequences.  `lowrank = Some((tag, factors))` routes every step through
/// the fused low-rank path instead of the dense weights.
pub fn greedy_next_token_acc(sess: &Session, params: &ParamStore,
                             lowrank: Option<(&str, &BTreeMap<String, (Mat, Mat)>)>,
                             corpus: &Corpus, max_rows: usize) -> Result<f64> {
    let seq = sess.cfg.seq_len;
    let rows = corpus.eval_batches(1, seq, max_rows.max(1));
    anyhow::ensure!(!rows.is_empty(), "no eval rows for {}", corpus.name);
    let mut hits = 0usize;
    let mut total = 0usize;
    let mut cache = sess.new_kv_cache();
    for row in &rows {
        cache.reset();
        for t in 0..seq {
            let tok = row.data[t];
            let logits = match lowrank {
                None => sess.decode_step(params, &mut cache, tok)?,
                Some((tag, f)) => {
                    sess.lowrank_decode_step(tag, params, f, &mut cache, tok)?
                }
            };
            if argmax(&logits.data) as i32 == row.data[t + 1] {
                hits += 1;
            }
            total += 1;
        }
    }
    Ok(hits as f64 / total as f64)
}
