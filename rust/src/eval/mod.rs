//! Evaluation: perplexity over the three corpora and zero-shot accuracy over
//! the seven task families — the two axes of every table in the paper.

pub mod gen;
pub mod ppl;
pub mod zeroshot;

use anyhow::Result;

use crate::data::{Corpus, TaskFamily, TaskInstance, World, ALL_FAMILIES};
use crate::model::ParamStore;
use crate::runtime::session::Session;

pub use gen::greedy_next_token_acc;
pub use ppl::perplexity;
pub use zeroshot::score_tasks;

/// One model's full evaluation: PPL per corpus + accuracy per task family.
#[derive(Clone, Debug)]
pub struct EvalReport {
    /// (corpus name, perplexity)
    pub ppl: Vec<(String, f64)>,
    /// (family name, accuracy)
    pub acc: Vec<(String, f64)>,
}

impl EvalReport {
    /// Mean zero-shot accuracy across every task family.
    pub fn avg_acc(&self) -> f64 {
        if self.acc.is_empty() {
            return 0.0;
        }
        self.acc.iter().map(|(_, a)| a).sum::<f64>() / self.acc.len() as f64
    }

    /// Relative accuracy drop vs a baseline report (the paper's Drop ↓, %).
    pub fn drop_vs(&self, baseline: &EvalReport) -> f64 {
        let b = baseline.avg_acc();
        if b <= 0.0 {
            return 0.0;
        }
        100.0 * (b - self.avg_acc()) / b
    }

    /// Perplexity on one named corpus; panics on an unknown name.
    pub fn ppl_of(&self, name: &str) -> f64 {
        self.ppl
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| *p)
            .unwrap_or(f64::NAN)
    }
}

/// Evaluation workload sizes (kept explicit so benches can trade speed for
/// precision; ZS_BENCH_FAST shrinks them further at the harness level).
#[derive(Clone, Copy, Debug)]
pub struct EvalSpec {
    /// eval batches per PPL corpus
    pub ppl_batches: usize,
    /// zero-shot instances generated per task family
    pub instances_per_family: usize,
    /// task-generation seed (fixed across methods for paired comparisons)
    pub task_seed: u64,
}

impl Default for EvalSpec {
    fn default() -> Self {
        EvalSpec { ppl_batches: 6, instances_per_family: 48, task_seed: 0xE1 }
    }
}

/// Evaluate a parameter set on corpora + all task families.
pub fn evaluate(sess: &Session, params: &ParamStore, corpora: &[Corpus],
                world: &World, spec: &EvalSpec) -> Result<EvalReport> {
    evaluate_subset(sess, params, corpora, world, spec, &ALL_FAMILIES)
}

/// Subset evaluation (e.g. Table 5 uses 6 tasks, excluding arc_c).
pub fn evaluate_subset(sess: &Session, params: &ParamStore, corpora: &[Corpus],
                       world: &World, spec: &EvalSpec,
                       families: &[TaskFamily]) -> Result<EvalReport> {
    let mut ppl = Vec::new();
    for c in corpora {
        ppl.push((c.name.clone(), perplexity(sess, params, c, spec.ppl_batches)?));
    }
    let mut acc = Vec::new();
    for &fam in families {
        let instances: Vec<TaskInstance> =
            crate::data::generate_set(world, fam, spec.instances_per_family,
                                      spec.task_seed);
        acc.push((fam.name().to_string(), score_tasks(sess, params, &instances)?));
    }
    Ok(EvalReport { ppl, acc })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_aggregation() {
        let base = EvalReport {
            ppl: vec![("w".into(), 5.0)],
            acc: vec![("a".into(), 0.8), ("b".into(), 0.6)],
        };
        let comp = EvalReport {
            ppl: vec![("w".into(), 7.0)],
            acc: vec![("a".into(), 0.7), ("b".into(), 0.5)],
        };
        assert!((base.avg_acc() - 0.7).abs() < 1e-12);
        let drop = comp.drop_vs(&base);
        assert!((drop - 100.0 * (0.7 - 0.6) / 0.7).abs() < 1e-9);
        assert_eq!(base.ppl_of("w"), 5.0);
        assert!(base.ppl_of("missing").is_nan());
    }
}
