//! Zero-shot multiple-choice scoring, LM-eval-harness style: each option is
//! scored by the length-normalized log-probability of its tokens given the
//! prompt; the model is correct when the gold option scores highest.

use anyhow::Result;

use crate::data::TaskInstance;
use crate::model::ParamStore;
use crate::runtime::session::Session;
use crate::tensor::IntTensor;

/// One scoring row: byte tokens + the (start, end) span of option positions.
struct Row {
    tokens: Vec<i32>,
    opt_start: usize,
    opt_end: usize,
    instance: usize,
    option: usize,
}

/// Accuracy of `params` over `instances`.
pub fn score_tasks(sess: &Session, params: &ParamStore,
                   instances: &[TaskInstance]) -> Result<f64> {
    let span = sess.cfg.seq_len + 1;
    let mut rows = Vec::new();
    for (ii, inst) in instances.iter().enumerate() {
        for (oi, opt) in inst.options.iter().enumerate() {
            let mut bytes: Vec<u8> = inst.prompt.bytes().collect();
            let p_len = bytes.len();
            bytes.extend(opt.bytes());
            anyhow::ensure!(bytes.len() <= span,
                            "instance too long ({} > {span})", bytes.len());
            let mut tokens: Vec<i32> = bytes.iter().map(|&b| b as i32).collect();
            tokens.resize(span, 0); // pad; causal mask keeps scores clean
            rows.push(Row {
                tokens,
                opt_start: p_len,
                opt_end: p_len + opt.len(),
                instance: ii,
                option: oi,
            });
        }
    }

    // batch rows through the fwd artifact
    let b = sess.cfg.batch;
    let vocab = sess.cfg.vocab;
    let seq = sess.cfg.seq_len;
    let mut scores: Vec<Vec<f64>> = instances
        .iter()
        .map(|i| vec![f64::NEG_INFINITY; i.options.len()])
        .collect();

    for chunk in rows.chunks(b) {
        let mut data = Vec::with_capacity(b * span);
        for r in chunk {
            data.extend_from_slice(&r.tokens);
        }
        // pad the batch with copies of the first row (discarded)
        for _ in chunk.len()..b {
            data.extend_from_slice(&chunk[0].tokens);
        }
        let toks = IntTensor::from_vec(&[b, span], data);
        let (_, logits) = sess.fwd(params, &toks)?;
        debug_assert_eq!(logits.shape, vec![b, seq, vocab]);

        for (bi, r) in chunk.iter().enumerate() {
            // token at position t (1-indexed into the row) is predicted by
            // logits[bi, t-1, :]
            let mut logprob = 0.0f64;
            let mut count = 0usize;
            for t in r.opt_start.max(1)..r.opt_end {
                let target = r.tokens[t] as usize;
                let base = (bi * seq + (t - 1)) * vocab;
                let row = &logits.data[base..base + vocab];
                // log-softmax at this position
                let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                let lse: f64 = row.iter()
                    .map(|&v| ((v - maxv) as f64).exp())
                    .sum::<f64>()
                    .ln() + maxv as f64;
                logprob += row[target] as f64 - lse;
                count += 1;
            }
            let norm = logprob / count.max(1) as f64;
            scores[r.instance][r.option] = norm;
        }
    }

    let mut correct = 0usize;
    for (inst, sc) in instances.iter().zip(&scores) {
        let best = sc
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        if best == inst.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / instances.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    // scoring correctness is covered by the integration test
    // `zeroshot_beats_chance_after_training` (rust/tests/pipeline.rs) which
    // exercises real logits; here we check row assembly edge cases via the
    // public API indirectly (padding/row-span logic is internal).

    #[test]
    fn chance_level_math() {
        // sanity on the accuracy denominator semantics used above
        let correct = 3usize;
        let total = 12usize;
        assert!((correct as f64 / total as f64 - 0.25).abs() < 1e-12);
    }
}
