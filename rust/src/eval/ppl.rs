//! Perplexity evaluation: exp(mean next-token NLL) over a corpus' held-out
//! split, aggregated across deterministic eval batches.

use anyhow::Result;

use crate::data::Corpus;
use crate::model::ParamStore;
use crate::runtime::session::Session;

/// PPL of `params` on `corpus`'s eval split over up to `max_batches`.
pub fn perplexity(sess: &Session, params: &ParamStore, corpus: &Corpus,
                  max_batches: usize) -> Result<f64> {
    let batches = corpus.eval_batches(sess.cfg.batch, sess.cfg.seq_len, max_batches);
    anyhow::ensure!(!batches.is_empty(), "no eval batches for {}", corpus.name);
    let mut total = 0.0f64;
    for b in &batches {
        let (loss, _) = sess.fwd(params, b)?;
        anyhow::ensure!(loss.is_finite(), "non-finite loss on {}", corpus.name);
        total += loss as f64;
    }
    // every batch covers the same token count: plain mean
    Ok((total / batches.len() as f64).exp())
}

/// PPL computed from logits (used where the loss output is unavailable).
pub fn ppl_from_mean_nll(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

#[cfg(test)]
mod tests {
    #[test]
    fn ppl_monotone_in_nll() {
        assert!(super::ppl_from_mean_nll(2.0) < super::ppl_from_mean_nll(3.0));
        assert!((super::ppl_from_mean_nll(0.0) - 1.0).abs() < 1e-12);
    }
}
