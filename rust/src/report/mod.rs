//! Paper-style table rendering (markdown + aligned ASCII) used by every
//! bench harness and by EXPERIMENTS.md generation, plus the shared latency
//! column shape every serving surface reports.

use std::fmt::Write as _;

use crate::util::stats::LatencySummary;

#[derive(Clone, Debug)]
/// Titled table rendered as aligned ASCII or markdown.
pub struct Table {
    /// table title
    pub title: String,
    /// column headers
    pub headers: Vec<String>,
    /// data rows (cell strings, one per header)
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(),
                   "row width {} != header width {}", cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned ASCII (stdout of the bench harnesses).
    pub fn to_ascii(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], w: &[usize], out: &mut String| {
            let parts: Vec<String> = cells
                .iter()
                .zip(w)
                .map(|(c, &wi)| format!("{c:<wi$}"))
                .collect();
            let _ = writeln!(out, "| {} |", parts.join(" | "));
        };
        line(&self.headers, &w, &mut out);
        let sep: Vec<String> = w.iter().map(|&wi| "-".repeat(wi)).collect();
        let _ = writeln!(out, "|-{}-|", sep.join("-|-"));
        for r in &self.rows {
            line(r, &w, &mut out);
        }
        out
    }

    /// GitHub-flavored markdown (EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(out, "|{}|", vec!["---"; self.headers.len()].join("|"));
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }

    /// Append the markdown form to a results file (created if absent).
    pub fn append_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        writeln!(f, "\n{}", self.to_markdown())
    }
}

/// Numeric formatting helpers matching the paper's precision conventions.
pub fn f2(v: f64) -> String {
    if !v.is_finite() {
        return "inf".into();
    }
    if v >= 10_000.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

/// Percentage cell with one decimal.
pub fn pct(v: f64) -> String {
    format!("{v:.1}")
}

/// Accuracy cell: two decimals.
pub fn acc2(v: f64) -> String {
    format!("{v:.2}")
}

/// Bytes rendered as MB (memory columns of the serving tables).
pub fn mb(bytes: f64) -> String {
    f2(bytes / 1e6)
}

/// The one latency column shape (prefill serve, decode scheduler, network
/// server): pair with [`latency_cells`] so every table agrees on which
/// percentiles exist.
pub const LATENCY_HEADERS: [&str; 4] = ["p50 ms", "p95 ms", "p99 ms",
                                        "mean ms"];

/// Cells matching [`LATENCY_HEADERS`].
pub fn latency_cells(l: &LatencySummary) -> Vec<String> {
    vec![f2(l.p50), f2(l.p95), f2(l.p99), f2(l.mean)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_alignment() {
        let mut t = Table::new("T", &["method", "ppl"]);
        t.row(vec!["zs-svd".into(), "8.20".into()]);
        t.row(vec!["svd-llm-longer".into(), "9.50".into()]);
        let s = t.to_ascii();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].contains("method"));
        // all data lines equal width
        assert_eq!(lines[2].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("X", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("X", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn latency_cells_match_headers() {
        let l = LatencySummary::from_samples(&[1.0, 2.0, 3.0, 100.0]);
        let cells = latency_cells(&l);
        assert_eq!(cells.len(), LATENCY_HEADERS.len());
        assert_eq!(cells[0], f2(l.p50));
        assert_eq!(cells[2], f2(l.p99));
        assert_eq!(cells[3], f2(l.mean));
    }

    #[test]
    fn formatting() {
        assert_eq!(f2(8.204), "8.20");
        assert_eq!(f2(57057.3), "57057");
        assert_eq!(f2(f64::INFINITY), "inf");
        assert_eq!(pct(9.09), "9.1");
        assert_eq!(acc2(0.547), "0.55");
        assert_eq!(mb(1.5e6), "1.50");
        assert_eq!(mb(0.0), "0.00");
    }
}
