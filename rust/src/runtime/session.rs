//! Typed execution facade over a (Runtime, config) pair.
//!
//! Each method assembles the exact ordered literal list the artifact's
//! manifest signature declares, executes, and unpacks outputs into host
//! types.  All request-path model math goes through here.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::Runtime;
use crate::model::{ConfigMeta, ParamStore};
use crate::tensor::{IntTensor, Mat, Tensor};

/// Per-site calibration statistics accumulated from the moments artifact.
#[derive(Clone, Debug)]
pub struct SiteMoments {
    pub site: String,
    /// Σ X Xᵀ over all calibration tokens (n×n)
    pub xx: Mat,
    /// Σ x (n)
    pub sum: Vec<f32>,
    /// Σ |x| (n)
    pub abssum: Vec<f32>,
    /// token count the sums were taken over
    pub count: usize,
}

pub struct Session<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ConfigMeta,
}

impl<'rt> Session<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str) -> Session<'rt> {
        Session { rt, cfg: rt.manifest.config(config).clone() }
    }

    fn param_literals(&self, params: &ParamStore) -> Result<Vec<xla::Literal>> {
        params.check_matches(&self.cfg)?;
        params.ordered().iter().map(|t| t.to_literal()).collect()
    }

    /// Dense forward: mean loss + logits. Dispatches to the b1 artifact for
    /// single-sequence batches when available.
    pub fn fwd(&self, params: &ParamStore, tokens: &IntTensor) -> Result<(f32, Tensor)> {
        let file = self.fwd_file(tokens)?;
        let mut inputs = self.param_literals(params)?;
        inputs.push(tokens.to_literal()?);
        let outs = self.rt.exec(&file, &inputs)?;
        ensure!(outs.len() == 2, "fwd returned {} outputs", outs.len());
        let loss = Tensor::from_literal(&outs[0])?.data[0];
        let logits = Tensor::from_literal(&outs[1])?;
        Ok((loss, logits))
    }

    fn fwd_file(&self, tokens: &IntTensor) -> Result<String> {
        let b = tokens.shape[0];
        ensure!(tokens.shape.len() == 2 && tokens.shape[1] == self.cfg.seq_len + 1,
                "tokens must be (B, T+1), got {:?}", tokens.shape);
        if b == self.cfg.batch {
            Ok(self.cfg.fwd.file.clone())
        } else if b == 1 {
            self.cfg
                .fwd_b1
                .as_ref()
                .map(|a| a.file.clone())
                .ok_or_else(|| anyhow::anyhow!("no b1 artifact for {}", self.cfg.name))
        } else {
            anyhow::bail!("unsupported batch {b} (artifacts: {} and 1)", self.cfg.batch)
        }
    }

    /// Calibration gradients for every target matrix.
    pub fn grads(&self, params: &ParamStore, tokens: &IntTensor)
                 -> Result<(f32, BTreeMap<String, Mat>)> {
        let mut inputs = self.param_literals(params)?;
        inputs.push(tokens.to_literal()?);
        let outs = self.rt.exec_tensors(&self.cfg.grads.file, &inputs)?;
        ensure!(outs.len() == 1 + self.cfg.targets.len());
        let loss = outs[0].data[0];
        let mut grads = BTreeMap::new();
        for (t, g) in self.cfg.targets.iter().zip(&outs[1..]) {
            grads.insert(t.name.clone(), g.to_mat());
        }
        Ok((loss, grads))
    }

    /// One moments pass; `accumulate_moments` sums over calibration batches.
    pub fn moments(&self, params: &ParamStore, tokens: &IntTensor)
                   -> Result<Vec<SiteMoments>> {
        let mut inputs = self.param_literals(params)?;
        inputs.push(tokens.to_literal()?);
        let outs = self.rt.exec_tensors(&self.cfg.moments.file, &inputs)?;
        // outputs: loss (graph anchor, see aot.py), then 3 per site
        ensure!(outs.len() == 1 + 3 * self.cfg.sites.len());
        let count = tokens.shape[0] * (tokens.shape[1] - 1);
        let mut result = Vec::with_capacity(self.cfg.sites.len());
        for (i, s) in self.cfg.sites.iter().enumerate() {
            result.push(SiteMoments {
                site: s.name.clone(),
                xx: outs[1 + 3 * i].to_mat(),
                sum: outs[1 + 3 * i + 1].data.clone(),
                abssum: outs[1 + 3 * i + 2].data.clone(),
                count,
            });
        }
        Ok(result)
    }

    /// Accumulate moments over several calibration batches.
    pub fn accumulate_moments(&self, params: &ParamStore, batches: &[IntTensor])
                              -> Result<Vec<SiteMoments>> {
        ensure!(!batches.is_empty());
        let mut acc = self.moments(params, &batches[0])?;
        for b in &batches[1..] {
            let next = self.moments(params, b)?;
            for (a, n) in acc.iter_mut().zip(next) {
                a.xx.add_assign(&n.xx);
                for (x, y) in a.sum.iter_mut().zip(&n.sum) {
                    *x += y;
                }
                for (x, y) in a.abssum.iter_mut().zip(&n.abssum) {
                    *x += y;
                }
                a.count += n.count;
            }
        }
        Ok(acc)
    }

    /// Average gradients (and Fisher diag Σg²) over calibration batches.
    pub fn mean_grads(&self, params: &ParamStore, batches: &[IntTensor])
                      -> Result<(f32, BTreeMap<String, Mat>, BTreeMap<String, Mat>)> {
        ensure!(!batches.is_empty());
        let mut mean_loss = 0.0f32;
        let mut mean: BTreeMap<String, Mat> = BTreeMap::new();
        let mut fisher: BTreeMap<String, Mat> = BTreeMap::new();
        for (i, b) in batches.iter().enumerate() {
            let (loss, grads) = self.grads(params, b)?;
            mean_loss += loss;
            for (name, g) in grads {
                let e = mean.entry(name.clone()).or_insert_with(|| Mat::zeros(g.rows, g.cols));
                e.add_assign(&g);
                let f = fisher.entry(name).or_insert_with(|| Mat::zeros(g.rows, g.cols));
                for (fv, gv) in f.data.iter_mut().zip(&g.data) {
                    *fv += gv * gv;
                }
            }
            let _ = i;
        }
        let inv = 1.0 / batches.len() as f32;
        mean_loss *= inv;
        for m in mean.values_mut() {
            m.scale(inv);
        }
        for f in fisher.values_mut() {
            f.scale(inv);
        }
        Ok((mean_loss, mean, fisher))
    }

    /// One Adam step via the train artifact; updates params/m/v in place.
    pub fn train_step(&self, params: &mut ParamStore, m: &mut ParamStore,
                      v: &mut ParamStore, step: i32, lr: f32,
                      tokens: &IntTensor) -> Result<f32> {
        let p = self.cfg.params.len();
        let mut inputs = self.param_literals(params)?;
        inputs.extend(self.param_literals(m)?);
        inputs.extend(self.param_literals(v)?);
        inputs.push(IntTensor::scalar(step).to_literal()?);
        inputs.push(Tensor::scalar(lr).to_literal()?);
        inputs.push(tokens.to_literal()?);
        let outs = self.rt.exec_tensors(&self.cfg.train.file, &inputs)?;
        ensure!(outs.len() == 3 * p + 1);
        let names: Vec<String> = self.cfg.params.iter().map(|q| q.name.clone()).collect();
        for (i, name) in names.iter().enumerate() {
            params.set(name, outs[i].clone());
            m.set(name, outs[p + i].clone());
            v.set(name, outs[2 * p + i].clone());
        }
        Ok(outs[3 * p].data[0])
    }

    /// Low-rank (Pallas-kernel) forward at a given ratio tag ("60", "40",
    /// "60_b1", ...).  `factors[target] = (wu, wv)`; ranks smaller than the
    /// artifact's uniform rank are zero-padded (numerically exact — see
    /// `test_lowrank_zero_rank_component` on the python side).
    pub fn lowrank_fwd(&self, tag: &str, params: &ParamStore,
                       factors: &BTreeMap<String, (Mat, Mat)>,
                       tokens: &IntTensor) -> Result<(f32, Tensor)> {
        let lm = self
            .cfg
            .lowrank
            .get(tag)
            .ok_or_else(|| anyhow::anyhow!("no lowrank artifact `{tag}`"))?;
        let mut inputs: Vec<xla::Literal> = Vec::new();
        for name in self.cfg.base_param_names() {
            inputs.push(params.get(&name).to_literal()?);
        }
        for t in &self.cfg.targets {
            let k_art = lm.ranks[&t.name];
            let (wu, wv) = factors
                .get(&t.name)
                .ok_or_else(|| anyhow::anyhow!("missing factors for {}", t.name))?;
            ensure!(wu.cols == wv.rows, "factor rank mismatch for {}", t.name);
            ensure!(wu.cols <= k_art,
                    "{}: rank {} exceeds artifact rank {k_art}", t.name, wu.cols);
            inputs.push(pad_wu(wu, k_art).to_literal()?);
            inputs.push(pad_wv(wv, k_art).to_literal()?);
        }
        inputs.push(tokens.to_literal()?);
        let outs = self.rt.exec(&lm.art.file, &inputs)?;
        let loss = Tensor::from_literal(&outs[0])?.data[0];
        let logits = Tensor::from_literal(&outs[1])?;
        Ok((loss, logits))
    }
}

fn pad_wu(wu: &Mat, k: usize) -> Tensor {
    let mut out = Mat::zeros(wu.rows, k);
    for r in 0..wu.rows {
        out.row_mut(r)[..wu.cols].copy_from_slice(wu.row(r));
    }
    Tensor::from_mat(&out)
}

fn pad_wv(wv: &Mat, k: usize) -> Tensor {
    let mut out = Mat::zeros(k, wv.cols);
    for r in 0..wv.rows {
        out.row_mut(r).copy_from_slice(wv.row(r));
    }
    Tensor::from_mat(&out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_factors_shapes() {
        let wu = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let p = pad_wu(&wu, 4);
        assert_eq!(p.shape, vec![3, 4]);
        assert_eq!(p.data[0..2], [1., 2.]);
        assert_eq!(p.data[2..4], [0., 0.]);
        let wv = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let q = pad_wv(&wv, 4);
        assert_eq!(q.shape, vec![4, 3]);
        assert_eq!(q.data[6..], [0.0; 6]);
    }
}
