//! Typed execution facade over a (Runtime, config) pair.
//!
//! Each method validates the exact ordered signature the artifact's
//! manifest declares (the rust↔build-side ABI), then executes the graph on
//! the native kernels (`runtime::native`) and unpacks outputs into host
//! types.  All request-path model math goes through here — batched prefill
//! (`fwd` / `lowrank_fwd`), KV-cached incremental decode (`decode_step` /
//! `lowrank_decode_step`), the batched serving advance (`decode_batch` /
//! `lowrank_decode_batch`: chunked prompt prefill and across-slot step
//! GEMMs in one kernel), and the calibration passes, whose per-batch
//! work fans out across the `exec` pool with a fixed-order tree reduction.
//! `Session` is `Sync` — the serving drain and the continuous-batching
//! scheduler share one session across worker threads.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::{native, Runtime};
use crate::decode::kv::KvCache;
use crate::runtime::native::LogitsMode;
use crate::model::{ConfigMeta, ParamStore};
use crate::tensor::{IntTensor, Mat, Tensor};

/// Per-site calibration statistics accumulated from the moments pass.
#[derive(Clone, Debug)]
pub struct SiteMoments {
    /// whitening-site name these moments belong to
    pub site: String,
    /// Σ X Xᵀ over all calibration tokens (n×n)
    pub xx: Mat,
    /// Σ x (n)
    pub sum: Vec<f32>,
    /// Σ |x| (n)
    pub abssum: Vec<f32>,
    /// token count the sums were taken over
    pub count: usize,
}

/// Typed execution facade over one (runtime, model config) pair.
pub struct Session<'rt> {
    /// the artifact runtime every dispatch validates against
    pub rt: &'rt Runtime,
    /// the model configuration this session executes
    pub cfg: ConfigMeta,
}

impl<'rt> Session<'rt> {
    /// Session for the named manifest config.
    pub fn new(rt: &'rt Runtime, config: &str) -> Session<'rt> {
        Session { rt, cfg: rt.manifest.config(config).clone() }
    }

    /// Dense forward: mean loss + logits. Dispatches to the b1 artifact for
    /// single-sequence batches when available.
    pub fn fwd(&self, params: &ParamStore, tokens: &IntTensor) -> Result<(f32, Tensor)> {
        let file = self.fwd_file(tokens)?;
        self.rt.mark_compiled(&file);
        params.check_matches(&self.cfg)?;
        native::forward(&self.cfg, params, tokens, None)
    }

    fn fwd_file(&self, tokens: &IntTensor) -> Result<String> {
        let b = tokens.shape[0];
        ensure!(tokens.shape.len() == 2 && tokens.shape[1] == self.cfg.seq_len + 1,
                "tokens must be (B, T+1), got {:?}", tokens.shape);
        if b == self.cfg.batch {
            Ok(self.cfg.fwd.file.clone())
        } else if b == 1 {
            self.cfg
                .fwd_b1
                .as_ref()
                .map(|a| a.file.clone())
                .ok_or_else(|| anyhow::anyhow!("no b1 artifact for {}", self.cfg.name))
        } else {
            anyhow::bail!("unsupported batch {b} (artifacts: {} and 1)", self.cfg.batch)
        }
    }

    /// Calibration gradients for every target matrix.
    pub fn grads(&self, params: &ParamStore, tokens: &IntTensor)
                 -> Result<(f32, BTreeMap<String, Mat>)> {
        self.rt.mark_compiled(&self.cfg.grads.file);
        params.check_matches(&self.cfg)?;
        let (loss, all) = native::loss_and_param_grads(&self.cfg, params, tokens)?;
        let mut grads = BTreeMap::new();
        for t in &self.cfg.targets {
            let g = all
                .get(&t.name)
                .ok_or_else(|| anyhow::anyhow!("no gradient for {}", t.name))?;
            grads.insert(t.name.clone(), g.to_mat());
        }
        Ok((loss, grads))
    }

    /// One moments pass; `accumulate_moments` sums over calibration batches.
    pub fn moments(&self, params: &ParamStore, tokens: &IntTensor)
                   -> Result<Vec<SiteMoments>> {
        self.rt.mark_compiled(&self.cfg.moments.file);
        params.check_matches(&self.cfg)?;
        let (_, sites) = native::forward_sites(&self.cfg, params, tokens)?;
        ensure!(sites.len() == self.cfg.sites.len());
        let count = tokens.shape[0] * (tokens.shape[1] - 1);
        let mut result = Vec::with_capacity(self.cfg.sites.len());
        for (meta, (name, flat)) in self.cfg.sites.iter().zip(sites) {
            ensure!(meta.name == name, "site order mismatch: {} vs {name}",
                    meta.name);
            ensure!(flat.cols == meta.dim);
            let xx = crate::linalg::gram(&flat);
            let mut sum = vec![0.0f32; meta.dim];
            let mut abssum = vec![0.0f32; meta.dim];
            for r in 0..flat.rows {
                for (j, &v) in flat.row(r).iter().enumerate() {
                    sum[j] += v;
                    abssum[j] += v.abs();
                }
            }
            result.push(SiteMoments { site: name, xx, sum, abssum, count });
        }
        Ok(result)
    }

    /// Whether batch-level fan-out pays off: with fewer batches than
    /// workers, the outer fan-out would *suppress* the row-parallel matmuls
    /// inside each pass (nested `par_*` degrades to serial) and shrink
    /// total parallelism to the batch count — keep the inner parallelism
    /// instead.  Either path produces identical bits: per-batch passes are
    /// thread-count independent and the reduction order is fixed.
    fn fan_out_batches(batches: &[IntTensor]) -> bool {
        batches.len() >= crate::exec::threads()
    }

    /// Accumulate moments over several calibration batches.
    ///
    /// Batches are independent, so the per-batch moments passes fan out
    /// across the `exec` worker pool (when there are enough of them — see
    /// `fan_out_batches`); the sums then come from a fixed-order pairwise
    /// tree reduction, so the result is bit-identical for any thread count
    /// (`rust/tests/parallel_equiv.rs`).
    pub fn accumulate_moments(&self, params: &ParamStore, batches: &[IntTensor])
                              -> Result<Vec<SiteMoments>> {
        ensure!(!batches.is_empty());
        let per: Result<Vec<Vec<SiteMoments>>> = if Self::fan_out_batches(batches) {
            crate::exec::par_map(batches, |_, b| self.moments(params, b))
                .into_iter()
                .collect()
        } else {
            batches.iter().map(|b| self.moments(params, b)).collect()
        };
        let acc = crate::exec::tree_reduce(per?, |a, n| {
            for (x, y) in a.iter_mut().zip(n) {
                x.xx.add_assign(&y.xx);
                for (u, v) in x.sum.iter_mut().zip(&y.sum) {
                    *u += v;
                }
                for (u, v) in x.abssum.iter_mut().zip(&y.abssum) {
                    *u += v;
                }
                x.count += y.count;
            }
        });
        Ok(acc.expect("non-empty batches"))
    }

    /// Average gradients (and Fisher diag Σg²) over calibration batches.
    ///
    /// Same batch-level fan-out + fixed-order tree reduction as
    /// `accumulate_moments`.  Fisher terms (g²) are materialized lazily
    /// inside the reduction — each batch's term exists only while its pair
    /// combines, instead of one extra param-store-sized map per batch up
    /// front.
    pub fn mean_grads(&self, params: &ParamStore, batches: &[IntTensor])
                      -> Result<(f32, BTreeMap<String, Mat>, BTreeMap<String, Mat>)> {
        ensure!(!batches.is_empty());
        fn square(g: &BTreeMap<String, Mat>) -> BTreeMap<String, Mat> {
            g.iter()
                .map(|(name, g)| {
                    let mut f = Mat::zeros(g.rows, g.cols);
                    for (fv, gv) in f.data.iter_mut().zip(&g.data) {
                        *fv = gv * gv;
                    }
                    (name.clone(), f)
                })
                .collect()
        }
        let per: Result<Vec<(f32, BTreeMap<String, Mat>)>> =
            if Self::fan_out_batches(batches) {
                crate::exec::par_map(batches, |_, b| self.grads(params, b))
                    .into_iter()
                    .collect()
            } else {
                batches.iter().map(|b| self.grads(params, b)).collect()
            };
        type Item = (f32, BTreeMap<String, Mat>, Option<BTreeMap<String, Mat>>);
        let items: Vec<Item> =
            per?.into_iter().map(|(l, g)| (l, g, None)).collect();
        let (mut mean_loss, mut mean, fisher) =
            crate::exec::tree_reduce(items, |a, mut b| {
                a.0 += b.0;
                if a.2.is_none() {
                    a.2 = Some(square(&a.1));
                }
                let bf = b.2.take().unwrap_or_else(|| square(&b.1));
                let af = a.2.as_mut().expect("materialized above");
                for (name, f) in bf {
                    af.get_mut(&name).expect("same targets").add_assign(&f);
                }
                for (name, g) in b.1 {
                    a.1.get_mut(&name).expect("same targets").add_assign(&g);
                }
            })
            .expect("non-empty batches");
        // single batch: the fold never ran, Fisher is just g²
        let mut fisher = fisher.unwrap_or_else(|| square(&mean));
        let inv = 1.0 / batches.len() as f32;
        mean_loss *= inv;
        for m in mean.values_mut() {
            m.scale(inv);
        }
        for f in fisher.values_mut() {
            f.scale(inv);
        }
        Ok((mean_loss, mean, fisher))
    }

    /// One Adam step via the train graph; updates params/m/v in place.
    pub fn train_step(&self, params: &mut ParamStore, m: &mut ParamStore,
                      v: &mut ParamStore, step: i32, lr: f32,
                      tokens: &IntTensor) -> Result<f32> {
        self.rt.mark_compiled(&self.cfg.train.file);
        params.check_matches(&self.cfg)?;
        m.check_matches(&self.cfg)?;
        v.check_matches(&self.cfg)?;
        native::adam_step(&self.cfg, params, m, v, step, lr, tokens)
    }

    /// Low-rank (fused-kernel) forward at a given ratio tag ("60", "40",
    /// "60_b1", ...).  `factors[target] = (wu, wv)`.  The fixed-shape HLO
    /// artifacts required zero-padding heterogeneous ranks up to the
    /// artifact's uniform rank; natively the zero rows/cols contribute
    /// exactly 0.0 to every accumulation, so the factors run unpadded (bit
    /// -identical result, no per-request copies, FLOPs at the actual kept
    /// rank).  The ABI validation — rank ≤ artifact rank — is kept.
    pub fn lowrank_fwd(&self, tag: &str, params: &ParamStore,
                       factors: &BTreeMap<String, (Mat, Mat)>,
                       tokens: &IntTensor) -> Result<(f32, Tensor)> {
        let lm = self
            .cfg
            .lowrank
            .get(tag)
            .ok_or_else(|| anyhow::anyhow!("no lowrank artifact `{tag}`"))?;
        self.rt.mark_compiled(&lm.art.file);
        for t in &self.cfg.targets {
            let k_art = lm.ranks[&t.name];
            let (wu, wv) = factors
                .get(&t.name)
                .ok_or_else(|| anyhow::anyhow!("missing factors for {}", t.name))?;
            ensure!(wu.cols == wv.rows, "factor rank mismatch for {}", t.name);
            ensure!(wu.cols <= k_art,
                    "{}: rank {} exceeds artifact rank {k_art}", t.name, wu.cols);
        }
        native::forward(&self.cfg, params, tokens, Some(factors))
    }

    // -----------------------------------------------------------------------
    // incremental decode (KV-cached generation)
    // -----------------------------------------------------------------------

    /// Fresh per-sequence KV cache sized for this config (capacity
    /// `seq_len` positions; reusable across requests via `reset()`).
    pub fn new_kv_cache(&self) -> KvCache {
        KvCache::new(&self.cfg)
    }

    /// [`Session::new_kv_cache`] with an explicit paged-block size (0
    /// selects the default).  The scheduler sizes every slot's cache to
    /// its `--kv-block` knob so block tables can share prefix-tree blocks.
    ///
    /// Note on the first-position ABI gate: a cache that adopts a cached
    /// prefix starts past position 0, so the per-sequence ABI validation
    /// ran when the *prefix* was originally prefilled — same session, same
    /// artifact, so the check's outcome is unchanged.
    pub fn new_kv_cache_with_block(&self, block: usize) -> KvCache {
        KvCache::with_block(&self.cfg, block)
    }

    /// One dense KV-cached decode step: `token` at position `cache.len` →
    /// next-token logits (shape `[V]`).  Uses the b1 artifact when the config
    /// ships one (decode is single-sequence per slot), else the batch
    /// artifact's graph.
    ///
    /// ABI validation (artifact mark + parameter shape check) runs on the
    /// FIRST position of each sequence; later steps of the same sequence
    /// reuse it — per-token revalidation would put a global mutex and a
    /// full param walk on the generation hot path.  The kernel itself
    /// still checks token range and cache shape every step.
    pub fn decode_step(&self, params: &ParamStore, cache: &mut KvCache,
                       token: i32) -> Result<Tensor> {
        if cache.len == 0 {
            self.dense_decode_abi(params)?;
        }
        let logits = native::decode_step(&self.cfg, params, None, cache, token)?;
        Ok(Tensor::from_vec(&[self.cfg.vocab], logits))
    }

    /// Dense decode ABI gate: mark the single-position forward artifact
    /// compiled and shape-check the param store.  Shared by the per-token
    /// step and every batched decode entry point (they all execute the same
    /// kernel).
    fn dense_decode_abi(&self, params: &ParamStore) -> Result<()> {
        let file = self
            .cfg
            .fwd_b1
            .as_ref()
            .map(|a| a.file.as_str())
            .unwrap_or(&self.cfg.fwd.file);
        self.rt.mark_compiled(file);
        params.check_matches(&self.cfg)
    }

    /// Low-rank decode ABI gate: every compression target needs factors
    /// with matching inner rank, ≤ the artifact's baked-in rank.  Shared by
    /// the per-token step and the batched decode entry points.
    fn lowrank_decode_abi(&self, tag: &str,
                          factors: &BTreeMap<String, (Mat, Mat)>)
                          -> Result<()> {
        let lm = self
            .cfg
            .lowrank
            .get(tag)
            .ok_or_else(|| anyhow::anyhow!("no lowrank artifact `{tag}`"))?;
        self.rt.mark_compiled(&lm.art.file);
        for t in &self.cfg.targets {
            let k_art = lm.ranks[&t.name];
            let (wu, wv) = factors.get(&t.name).ok_or_else(|| {
                anyhow::anyhow!("missing factors for {}", t.name)
            })?;
            ensure!(wu.cols == wv.rows, "factor rank mismatch for {}", t.name);
            ensure!(wu.cols <= k_art,
                    "{}: rank {} exceeds artifact rank {k_art}",
                    t.name, wu.cols);
        }
        Ok(())
    }

    /// One low-rank (fused-path) KV-cached decode step at ratio tag `tag`.
    /// ABI validation matches `lowrank_fwd` — every target needs factors
    /// with matching inner rank, ≤ the artifact's baked-in rank — and runs
    /// on the first position of each sequence (see `decode_step`).
    pub fn lowrank_decode_step(&self, tag: &str, params: &ParamStore,
                               factors: &BTreeMap<String, (Mat, Mat)>,
                               cache: &mut KvCache, token: i32)
                               -> Result<Tensor> {
        if cache.len == 0 {
            self.lowrank_decode_abi(tag, factors)?;
        }
        let logits =
            native::decode_step(&self.cfg, params, Some(factors), cache, token)?;
        Ok(Tensor::from_vec(&[self.cfg.vocab], logits))
    }

    /// Batched dense KV-cached advance: every sequence's token run flows
    /// through ONE set of per-layer GEMMs (`native::decode_batch`) and each
    /// sequence with `want_logits[s]` set gets back the next-token logits
    /// after its last token (shape `[V]`; `None` for unrequested sequences
    /// — interior prefill chunks skip the vocab projection).  Covers
    /// chunked prefill (one sequence, many tokens) and
    /// batched-across-slots decode (many sequences, one token each);
    /// results bit-match per-token [`Session::decode_step`] calls for any
    /// grouping and thread count.
    ///
    /// ABI validation runs when the call contains a sequence at its FIRST
    /// position, exactly like `decode_step`'s per-sequence policy.
    pub fn decode_batch(&self, params: &ParamStore,
                        seqs: &mut [(&mut KvCache, &[i32])],
                        want_logits: &[bool])
                        -> Result<Vec<Option<Tensor>>> {
        if seqs.iter().any(|(c, _)| c.len == 0) {
            self.dense_decode_abi(params)?;
        }
        let logits =
            native::decode_batch(&self.cfg, params, None, seqs, want_logits)?;
        Ok(logits
            .into_iter()
            .map(|l| l.map(|l| Tensor::from_vec(&[self.cfg.vocab], l)))
            .collect())
    }

    /// Batched dense advance with a per-sequence [`LogitsMode`]: the
    /// speculative-verify entry point.  `LogitsMode::All` sequences get a
    /// `(run_len × V)` matrix — row `j` holds the next-token logits after
    /// run position `j`, each row bit-identical to what a `Last`-mode call
    /// ending at that position would return (see
    /// `native::decode_batch_modes`).  ABI validation follows the same
    /// first-position policy as [`Session::decode_batch`].
    pub fn decode_batch_modes(&self, params: &ParamStore,
                              seqs: &mut [(&mut KvCache, &[i32])],
                              modes: &[LogitsMode])
                              -> Result<Vec<Option<Mat>>> {
        if seqs.iter().any(|(c, _)| c.len == 0) {
            self.dense_decode_abi(params)?;
        }
        native::decode_batch_modes(&self.cfg, params, None, seqs, modes)
    }

    /// Batched low-rank (fused-path) KV-cached advance at ratio tag `tag` —
    /// the low-rank sibling of [`Session::decode_batch`].  Factor
    /// validation matches [`Session::lowrank_decode_step`] and runs when
    /// the call contains a sequence at its first position.
    pub fn lowrank_decode_batch(&self, tag: &str, params: &ParamStore,
                                factors: &BTreeMap<String, (Mat, Mat)>,
                                seqs: &mut [(&mut KvCache, &[i32])],
                                want_logits: &[bool])
                                -> Result<Vec<Option<Tensor>>> {
        if seqs.iter().any(|(c, _)| c.len == 0) {
            self.lowrank_decode_abi(tag, factors)?;
        }
        let logits = native::decode_batch(&self.cfg, params, Some(factors),
                                          seqs, want_logits)?;
        Ok(logits
            .into_iter()
            .map(|l| l.map(|l| Tensor::from_vec(&[self.cfg.vocab], l)))
            .collect())
    }

    /// Low-rank sibling of [`Session::decode_batch_modes`] — the drafter
    /// runs through this when speculation needs anything beyond last-row
    /// logits (and the scheduler uses it uniformly for drafter calls so
    /// both engines share one entry-point shape).
    pub fn lowrank_decode_batch_modes(&self, tag: &str, params: &ParamStore,
                                      factors: &BTreeMap<String, (Mat, Mat)>,
                                      seqs: &mut [(&mut KvCache, &[i32])],
                                      modes: &[LogitsMode])
                                      -> Result<Vec<Option<Mat>>> {
        if seqs.iter().any(|(c, _)| c.len == 0) {
            self.lowrank_decode_abi(tag, factors)?;
        }
        native::decode_batch_modes(&self.cfg, params, Some(factors), seqs,
                                   modes)
    }
}
