//! Typed execution facade over a (Runtime, config) pair.
//!
//! Each method validates the exact ordered signature the artifact's
//! manifest declares (the rust↔build-side ABI), then executes the graph on
//! the native kernels (`runtime::native`) and unpacks outputs into host
//! types.  All request-path model math goes through here.  `Session` is
//! `Sync` — the serving drain shares one session across worker threads.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::{native, Runtime};
use crate::model::{ConfigMeta, ParamStore};
use crate::tensor::{IntTensor, Mat, Tensor};

/// Per-site calibration statistics accumulated from the moments pass.
#[derive(Clone, Debug)]
pub struct SiteMoments {
    pub site: String,
    /// Σ X Xᵀ over all calibration tokens (n×n)
    pub xx: Mat,
    /// Σ x (n)
    pub sum: Vec<f32>,
    /// Σ |x| (n)
    pub abssum: Vec<f32>,
    /// token count the sums were taken over
    pub count: usize,
}

pub struct Session<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ConfigMeta,
}

impl<'rt> Session<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str) -> Session<'rt> {
        Session { rt, cfg: rt.manifest.config(config).clone() }
    }

    /// Dense forward: mean loss + logits. Dispatches to the b1 artifact for
    /// single-sequence batches when available.
    pub fn fwd(&self, params: &ParamStore, tokens: &IntTensor) -> Result<(f32, Tensor)> {
        let file = self.fwd_file(tokens)?;
        self.rt.mark_compiled(&file);
        params.check_matches(&self.cfg)?;
        native::forward(&self.cfg, params, tokens, None)
    }

    fn fwd_file(&self, tokens: &IntTensor) -> Result<String> {
        let b = tokens.shape[0];
        ensure!(tokens.shape.len() == 2 && tokens.shape[1] == self.cfg.seq_len + 1,
                "tokens must be (B, T+1), got {:?}", tokens.shape);
        if b == self.cfg.batch {
            Ok(self.cfg.fwd.file.clone())
        } else if b == 1 {
            self.cfg
                .fwd_b1
                .as_ref()
                .map(|a| a.file.clone())
                .ok_or_else(|| anyhow::anyhow!("no b1 artifact for {}", self.cfg.name))
        } else {
            anyhow::bail!("unsupported batch {b} (artifacts: {} and 1)", self.cfg.batch)
        }
    }

    /// Calibration gradients for every target matrix.
    pub fn grads(&self, params: &ParamStore, tokens: &IntTensor)
                 -> Result<(f32, BTreeMap<String, Mat>)> {
        self.rt.mark_compiled(&self.cfg.grads.file);
        params.check_matches(&self.cfg)?;
        let (loss, all) = native::loss_and_param_grads(&self.cfg, params, tokens)?;
        let mut grads = BTreeMap::new();
        for t in &self.cfg.targets {
            let g = all
                .get(&t.name)
                .ok_or_else(|| anyhow::anyhow!("no gradient for {}", t.name))?;
            grads.insert(t.name.clone(), g.to_mat());
        }
        Ok((loss, grads))
    }

    /// One moments pass; `accumulate_moments` sums over calibration batches.
    pub fn moments(&self, params: &ParamStore, tokens: &IntTensor)
                   -> Result<Vec<SiteMoments>> {
        self.rt.mark_compiled(&self.cfg.moments.file);
        params.check_matches(&self.cfg)?;
        let (_, sites) = native::forward_sites(&self.cfg, params, tokens)?;
        ensure!(sites.len() == self.cfg.sites.len());
        let count = tokens.shape[0] * (tokens.shape[1] - 1);
        let mut result = Vec::with_capacity(self.cfg.sites.len());
        for (meta, (name, flat)) in self.cfg.sites.iter().zip(sites) {
            ensure!(meta.name == name, "site order mismatch: {} vs {name}",
                    meta.name);
            ensure!(flat.cols == meta.dim);
            let xx = crate::linalg::gram(&flat);
            let mut sum = vec![0.0f32; meta.dim];
            let mut abssum = vec![0.0f32; meta.dim];
            for r in 0..flat.rows {
                for (j, &v) in flat.row(r).iter().enumerate() {
                    sum[j] += v;
                    abssum[j] += v.abs();
                }
            }
            result.push(SiteMoments { site: name, xx, sum, abssum, count });
        }
        Ok(result)
    }

    /// Accumulate moments over several calibration batches.
    pub fn accumulate_moments(&self, params: &ParamStore, batches: &[IntTensor])
                              -> Result<Vec<SiteMoments>> {
        ensure!(!batches.is_empty());
        let mut acc = self.moments(params, &batches[0])?;
        for b in &batches[1..] {
            let next = self.moments(params, b)?;
            for (a, n) in acc.iter_mut().zip(next) {
                a.xx.add_assign(&n.xx);
                for (x, y) in a.sum.iter_mut().zip(&n.sum) {
                    *x += y;
                }
                for (x, y) in a.abssum.iter_mut().zip(&n.abssum) {
                    *x += y;
                }
                a.count += n.count;
            }
        }
        Ok(acc)
    }

    /// Average gradients (and Fisher diag Σg²) over calibration batches.
    pub fn mean_grads(&self, params: &ParamStore, batches: &[IntTensor])
                      -> Result<(f32, BTreeMap<String, Mat>, BTreeMap<String, Mat>)> {
        ensure!(!batches.is_empty());
        let mut mean_loss = 0.0f32;
        let mut mean: BTreeMap<String, Mat> = BTreeMap::new();
        let mut fisher: BTreeMap<String, Mat> = BTreeMap::new();
        for b in batches {
            let (loss, grads) = self.grads(params, b)?;
            mean_loss += loss;
            for (name, g) in grads {
                let e = mean.entry(name.clone()).or_insert_with(|| Mat::zeros(g.rows, g.cols));
                e.add_assign(&g);
                let f = fisher.entry(name).or_insert_with(|| Mat::zeros(g.rows, g.cols));
                for (fv, gv) in f.data.iter_mut().zip(&g.data) {
                    *fv += gv * gv;
                }
            }
        }
        let inv = 1.0 / batches.len() as f32;
        mean_loss *= inv;
        for m in mean.values_mut() {
            m.scale(inv);
        }
        for f in fisher.values_mut() {
            f.scale(inv);
        }
        Ok((mean_loss, mean, fisher))
    }

    /// One Adam step via the train graph; updates params/m/v in place.
    pub fn train_step(&self, params: &mut ParamStore, m: &mut ParamStore,
                      v: &mut ParamStore, step: i32, lr: f32,
                      tokens: &IntTensor) -> Result<f32> {
        self.rt.mark_compiled(&self.cfg.train.file);
        params.check_matches(&self.cfg)?;
        m.check_matches(&self.cfg)?;
        v.check_matches(&self.cfg)?;
        native::adam_step(&self.cfg, params, m, v, step, lr, tokens)
    }

    /// Low-rank (fused-kernel) forward at a given ratio tag ("60", "40",
    /// "60_b1", ...).  `factors[target] = (wu, wv)`.  The fixed-shape HLO
    /// artifacts required zero-padding heterogeneous ranks up to the
    /// artifact's uniform rank; natively the zero rows/cols contribute
    /// exactly 0.0 to every accumulation, so the factors run unpadded (bit
    /// -identical result, no per-request copies, FLOPs at the actual kept
    /// rank).  The ABI validation — rank ≤ artifact rank — is kept.
    pub fn lowrank_fwd(&self, tag: &str, params: &ParamStore,
                       factors: &BTreeMap<String, (Mat, Mat)>,
                       tokens: &IntTensor) -> Result<(f32, Tensor)> {
        let lm = self
            .cfg
            .lowrank
            .get(tag)
            .ok_or_else(|| anyhow::anyhow!("no lowrank artifact `{tag}`"))?;
        self.rt.mark_compiled(&lm.art.file);
        for t in &self.cfg.targets {
            let k_art = lm.ranks[&t.name];
            let (wu, wv) = factors
                .get(&t.name)
                .ok_or_else(|| anyhow::anyhow!("missing factors for {}", t.name))?;
            ensure!(wu.cols == wv.rows, "factor rank mismatch for {}", t.name);
            ensure!(wu.cols <= k_art,
                    "{}: rank {} exceeds artifact rank {k_art}", t.name, wu.cols);
        }
        native::forward(&self.cfg, params, tokens, Some(factors))
    }
}
