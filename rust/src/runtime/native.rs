//! Native CPU execution of the model graphs — the offline replacement for
//! the PJRT/HLO path.
//!
//! `python/compile/model.py` remains the semantic reference: every function
//! here mirrors one of its AOT entry points (`make_fwd_loss`, `make_grads`,
//! `make_moments`, `make_train_step`, `make_fwd_lowrank`) operation for
//! operation, so rust-trained models share dynamics with the python tests.
//! Supported architectures match `configs.py`:
//!
//! * `llama` — RMSNorm, RoPE, causal MHA, SwiGLU MLP, tied embedding head.
//! * `opt`   — learned positions, scale-only LayerNorm, GELU MLP, tied head.
//!
//! All heavy projections route through `linalg::{matmul, matmul_bt}`, and
//! the per-row reductions (RMSNorm/LayerNorm moments, attention score dots
//! and value merges) through the same `linalg::kernels` micro-kernel layer
//! those are built on — so the row-partitioned parallel kernels (see
//! `crate::exec`) and the SIMD backends accelerate the serving and
//! calibration paths while keeping results bit-identical across thread
//! counts and kernel backends (every remaining loop here is serial and
//! fixed-order, and every kernel executes one canonical lane-strided
//! accumulation order — see `linalg::kernels`).
//!
//! [`decode_step`] is the incremental sibling of [`forward`]: one token
//! against a per-sequence KV cache (`crate::decode::kv`), sharing the
//! per-row building blocks so cached decoding reproduces full-forward
//! logits bit for bit.  [`decode_batch`] generalizes it to the serving hot
//! path — many sequences and/or multi-token prompt chunks through ONE set
//! of batched per-layer GEMMs (chunked prefill, batched-across-slots decode
//! steps) — while keeping the same bit-identity contract: every projection
//! is row-independent, so a sequence's logits cannot depend on which other
//! rows share the GEMM.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{ensure, Result};

use crate::decode::kv::{KvCache, KvRows};
use crate::exec;
use crate::linalg::kernels;
use crate::linalg::matmul::{dot_f32, matmul, matmul_bt, matmul_bt_flat,
                            matmul_flat};
use crate::model::{ConfigMeta, ParamStore};
use crate::tensor::{IntTensor, Mat, Tensor};

// ---------------------------------------------------------------------------
// per-layer parameter-name tables
// ---------------------------------------------------------------------------

/// Pre-rendered parameter names for one transformer layer.  `decode_step`
/// runs once per generated token and used to re-`format!` every
/// `layers.{li}.*` string on each call — the tables are built once per
/// (config, arch) and cached for the life of the process, so the per-token
/// path does zero string allocation for name lookups.  The KV cache holds
/// an `Arc` to its config's table (`decode::kv`), so the decode hot path
/// doesn't even pay the cache lookup per token.
pub(crate) struct LayerNames {
    /// `layers.{li}.` — kept for the site names the calibration pass builds
    prefix: String,
    ln1: String,
    wq: String,
    wk: String,
    wv: String,
    wo: String,
    ln2: String,
    /// llama: `wgate`; opt: `win`
    mlp_gate: String,
    /// llama: `wup` (unused for opt)
    mlp_up: String,
    /// llama: `wdown`; opt: `wout`
    mlp_down: String,
}

/// One process-wide table per config.  Keyed by config name with an
/// (arch, layer-count) verification so ad-hoc test configs sharing a name
/// cannot alias a stale table; the hit path allocates nothing.
struct NamesEntry {
    arch: String,
    n_layers: usize,
    names: Arc<Vec<LayerNames>>,
}

pub(crate) fn layer_names(cfg: &ConfigMeta) -> Arc<Vec<LayerNames>> {
    static CACHE: OnceLock<Mutex<BTreeMap<String, NamesEntry>>> =
        OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut m = cache.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(e) = m.get(cfg.name.as_str()) {
        if e.arch == cfg.arch && e.n_layers == cfg.n_layers {
            return Arc::clone(&e.names);
        }
    }
    let llama = cfg.arch == "llama";
    let names: Vec<LayerNames> = (0..cfg.n_layers)
        .map(|li| {
            let p = format!("layers.{li}.");
            LayerNames {
                ln1: format!("{p}ln1"),
                wq: format!("{p}wq"),
                wk: format!("{p}wk"),
                wv: format!("{p}wv"),
                wo: format!("{p}wo"),
                ln2: format!("{p}ln2"),
                mlp_gate: if llama { format!("{p}wgate") } else { format!("{p}win") },
                mlp_up: format!("{p}wup"),
                mlp_down: if llama { format!("{p}wdown") } else { format!("{p}wout") },
                prefix: p,
            }
        })
        .collect();
    let a = Arc::new(names);
    m.insert(cfg.name.clone(), NamesEntry {
        arch: cfg.arch.clone(),
        n_layers: cfg.n_layers,
        names: Arc::clone(&a),
    });
    a
}

// ---------------------------------------------------------------------------
// public entry points
// ---------------------------------------------------------------------------

/// Dense (or low-rank) forward: mean next-token loss + logits (B, T, V).
pub fn forward(cfg: &ConfigMeta, params: &ParamStore, tokens: &IntTensor,
               lowrank: Option<&BTreeMap<String, (Mat, Mat)>>)
               -> Result<(f32, Tensor)> {
    let (loss, logits, _, _) = run(cfg, params, tokens, lowrank, false, false)?;
    let b = tokens.shape[0];
    Ok((loss, Tensor::from_vec(&[b, cfg.seq_len, cfg.vocab], logits.data)))
}

/// Forward pass that also returns the whitening-site activations, flattened
/// to (B·T, site_dim) row-major, in `cfg.sites` order.
pub fn forward_sites(cfg: &ConfigMeta, params: &ParamStore, tokens: &IntTensor)
                     -> Result<(f32, Vec<(String, Mat)>)> {
    let (loss, _, _, sites) = run(cfg, params, tokens, None, false, true)?;
    Ok((loss, sites))
}

/// Mean loss + gradient of the loss w.r.t. EVERY parameter.
pub fn loss_and_param_grads(cfg: &ConfigMeta, params: &ParamStore,
                            tokens: &IntTensor)
                            -> Result<(f32, BTreeMap<String, Tensor>)> {
    let (loss, _, trace, _) = run(cfg, params, tokens, None, true, false)?;
    let trace = trace.expect("trace requested");
    let grads = backward(cfg, params, &trace);
    Ok((loss, grads))
}

/// One KV-cached incremental decode step: run `token` (at position
/// `cache.len`) through the graph against the per-sequence cache and return
/// the next-token logits (length V).  `lowrank` selects the fused low-rank
/// path with a compression plan's `(Wu, Wv)` factors, exactly as in
/// [`forward`].
///
/// Every operation reuses the per-row kernels and loop structures of the
/// full forward pass — projections are single-row `matmul_bt` dots, the
/// norm/activation scalar code is shared, and `attention_step` mirrors
/// `attention_fwd`'s per-position accumulation order — so the returned
/// logits **bit-match** a full forward over the same prefix for every
/// thread count (`rust/tests/decode_parity.rs`).
pub fn decode_step(cfg: &ConfigMeta, params: &ParamStore,
                   lowrank: Option<&BTreeMap<String, (Mat, Mat)>>,
                   cache: &mut KvCache, token: i32) -> Result<Vec<f32>> {
    let pos = cache.len;
    ensure!(pos < cache.max_len, "kv cache full ({} positions)", cache.max_len);
    let (d, h, ff, vocab) = (cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.vocab);
    let dh = d / h;
    let llama = cfg.arch == "llama";
    let eps = cfg.norm_eps;
    ensure!(token >= 0 && (token as usize) < vocab,
            "token {token} out of range [0, {vocab})");
    ensure!(cache.n_layers == cfg.n_layers && cache.d == d,
            "kv cache shaped for a different config");
    cache.ensure_len(pos + 1);

    let embed = params.get("embed");
    let mut x = Mat::zeros(1, d);
    x.row_mut(0).copy_from_slice(trow(embed, token as usize));
    if !llama {
        let pe = params.get("pos_embed");
        for (xv, pv) in x.row_mut(0).iter_mut().zip(trow(pe, pos)) {
            *xv += pv;
        }
    }

    let linear = |name: &str, xin: &Mat| -> Mat {
        if let Some(lr) = lowrank {
            if let Some((wu, wv)) = lr.get(name) {
                return matmul_bt(&matmul_bt(xin, wv), wu);
            }
        }
        project(xin, params.get(name))
    };

    // the cache carries its config's pre-rendered name table (built once
    // per model via `layer_names`): zero lookups or allocations per token
    let names = Arc::clone(&cache.names);
    let half = dh / 2;
    for li in 0..cfg.n_layers {
        let ln = &names[li];

        let ln1 = norm_fwd(&x, param_1d(params, &ln.ln1), eps, llama);
        let mut q = linear(&ln.wq, &ln1.y);
        let mut k = linear(&ln.wk, &ln1.y);
        let v = linear(&ln.wv, &ln1.y);
        if llama {
            rope_rotate_row(q.row_mut(0), pos * half, h, dh, &cache.cos,
                            &cache.sin, false);
            rope_rotate_row(k.row_mut(0), pos * half, h, dh, &cache.cos,
                            &cache.sin, false);
        }
        cache.set_k_row(li, pos, k.row(0));
        cache.set_v_row(li, pos, v.row(0));
        let mut attn = Mat::zeros(1, d);
        attention_step_row(q.row(0), &cache.layer_view(li), pos, h, dh,
                           attn.row_mut(0));
        let attn_o = linear(&ln.wo, &attn);
        x.add_assign(&attn_o);

        let ln2 = norm_fwd(&x, param_1d(params, &ln.ln2), eps, llama);
        let act = if llama {
            let g = linear(&ln.mlp_gate, &ln2.y);
            let u = linear(&ln.mlp_up, &ln2.y);
            let mut act = Mat::zeros(1, ff);
            for i in 0..act.data.len() {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            act
        } else {
            let g = linear(&ln.mlp_gate, &ln2.y);
            let mut act = Mat::zeros(1, ff);
            for i in 0..act.data.len() {
                act.data[i] = gelu(g.data[i]);
            }
            act
        };
        let down = linear(&ln.mlp_down, &act);
        x.add_assign(&down);
    }

    let fin = norm_fwd(&x, param_1d(params, "final_ln"), eps, llama);
    let logits = project(&fin.y, embed); // tied head: (1, V)
    cache.len = pos + 1;
    Ok(logits.data)
}

/// Which logits a sequence's run requests from [`decode_batch_modes`].
///
/// `Last` is the classic decode shape (one next-token row after the run's
/// final token); `All` is the speculative-verify shape — the target engine
/// scores every position of a `[pending, draft_1 .. draft_K]` run in one
/// pass, because row `i`'s logits predict the token *after* run position
/// `i`, which is exactly what greedy verification compares against draft
/// `i+1`.  `None` skips the head GEMM entirely (interior prefill chunks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogitsMode {
    /// no logits for this sequence
    None,
    /// next-token logits after the run's last token (1 row)
    Last,
    /// logits at every run position, in run order (`run_len` rows)
    All,
}

/// Batched KV-cached advance: run every sequence's token run through ONE
/// set of per-layer GEMMs and return, per requested sequence, the
/// next-token logits after its last token.
///
/// Each entry of `seqs` is a (cache, tokens) pair; the tokens occupy
/// positions `cache.len ..` of that sequence and the cache cursor advances
/// past them on return.  `want_logits[s]` selects which sequences pay the
/// final-norm + tied-head vocab projection (`None` entries otherwise) —
/// interior prefill chunks feed no sampler, so the scheduler skips their
/// head GEMM entirely.  Two serving shapes collapse onto this one kernel:
///
/// * **chunked prefill** — one sequence, a multi-token run: a prompt chunk
///   flows through the batched matmul kernels (`chunk` rows per projection)
///   instead of one token-at-a-time [`decode_step`] call per position;
/// * **batched decode** — many sequences, one token each: the active slots
///   of the continuous-batching scheduler share a single activation matrix
///   per layer instead of issuing per-slot single-row GEMMs.
///
/// Mixed runs (several sequences, several tokens each) also work, which is
/// how the scheduler prefills multiple admitted prompts in one call.
///
/// # Bit-identity
///
/// The returned logits — and every K/V row written — are **bit-identical**
/// to driving the same tokens through [`decode_step`] one at a time, for
/// any grouping and any thread count (`rust/tests/decode_parity.rs`).  The
/// contract rests on three properties:
///
/// * every projection routes through `matmul_bt`, whose output rows are
///   each one fixed-order `dot_f32` accumulation — a row's bits cannot
///   depend on which other rows share the GEMM (see `linalg::matmul`);
/// * the norm / activation scalar code operates row-locally;
/// * attention runs per position through the shared `attention_step_row`
///   helper, after the whole run's K/V rows are appended — in-run causality
///   (a chunk position attending to earlier positions of the same chunk)
///   needs exactly the rows that an incremental step would already have
///   written.  The rows are independent, so they fan out across the
///   persistent `exec` pool in contiguous bands; each output row is
///   computed by exactly one worker with the serial loop body, so the
///   partition cannot change bits.
pub fn decode_batch(cfg: &ConfigMeta, params: &ParamStore,
                    lowrank: Option<&BTreeMap<String, (Mat, Mat)>>,
                    seqs: &mut [(&mut KvCache, &[i32])],
                    want_logits: &[bool])
                    -> Result<Vec<Option<Vec<f32>>>> {
    ensure!(want_logits.len() == seqs.len(),
            "decode_batch: want_logits length {} != {} sequences",
            want_logits.len(), seqs.len());
    let modes: Vec<LogitsMode> = want_logits
        .iter()
        .map(|&w| if w { LogitsMode::Last } else { LogitsMode::None })
        .collect();
    let out = decode_batch_modes(cfg, params, lowrank, seqs, &modes)?;
    // a Last-mode result is a single-row matrix; its backing vec IS the row
    Ok(out.into_iter().map(|m| m.map(|m| m.data)).collect())
}

/// [`decode_batch`] with a per-sequence [`LogitsMode`] instead of a bool:
/// the verify half of speculative decoding needs logits at **all** K+1 run
/// positions (`LogitsMode::All`), not just the last.  The returned matrix
/// for sequence `s` has one row per requested position, in run order.
///
/// The bit-identity contract extends unchanged: the final norm is row-local
/// and every projection row is an independent fixed-order dot, so the row
/// computed for run position `j` is bit-identical to the single row a
/// `Last`-mode call (or token-at-a-time [`decode_step`]) would produce
/// after that position — regardless of which other rows share the head
/// GEMM.  That is what lets greedy verification reproduce plain decode
/// exactly (`rust/tests/decode_parity.rs`).
pub fn decode_batch_modes(cfg: &ConfigMeta, params: &ParamStore,
                          lowrank: Option<&BTreeMap<String, (Mat, Mat)>>,
                          seqs: &mut [(&mut KvCache, &[i32])],
                          modes: &[LogitsMode])
                          -> Result<Vec<Option<Mat>>> {
    ensure!(!seqs.is_empty(), "decode_batch: no sequences");
    ensure!(modes.len() == seqs.len(),
            "decode_batch: modes length {} != {} sequences",
            modes.len(), seqs.len());
    let (d, h, ff, vocab) = (cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.vocab);
    let dh = d / h;
    let llama = cfg.arch == "llama";
    let eps = cfg.norm_eps;
    let half = dh / 2;

    // row layout: sequence `s` owns rows `base[s] .. base[s] + len_s`
    let mut base = Vec::with_capacity(seqs.len());
    let mut total = 0usize;
    for (cache, toks) in seqs.iter_mut() {
        ensure!(!toks.is_empty(), "decode_batch: empty token run");
        ensure!(cache.len + toks.len() <= cache.max_len,
                "kv cache full ({} + {} > {} positions)", cache.len,
                toks.len(), cache.max_len);
        ensure!(cache.n_layers == cfg.n_layers && cache.d == d,
                "kv cache shaped for a different config");
        for &t in toks.iter() {
            ensure!(t >= 0 && (t as usize) < vocab,
                    "token {t} out of range [0, {vocab})");
        }
        // back the whole run with blocks up front so the per-layer loop
        // never reallocates mid-flight
        cache.ensure_len(cache.len + toks.len());
        base.push(total);
        total += toks.len();
    }

    // token gather (+ learned positions for opt, at each row's own position)
    let embed = params.get("embed");
    // llama stores carry no "pos_embed" (the lookup would panic), so the
    // hoisted fetch stays arch-conditional
    let pos_embed = (!llama).then(|| params.get("pos_embed"));
    let mut x = Mat::zeros(total, d);
    for (s, (cache, toks)) in seqs.iter().enumerate() {
        for (j, &t) in toks.iter().enumerate() {
            x.row_mut(base[s] + j).copy_from_slice(trow(embed, t as usize));
        }
        if let Some(pe) = pos_embed {
            for j in 0..toks.len() {
                let xr = x.row_mut(base[s] + j);
                for (xv, pv) in xr.iter_mut().zip(trow(pe, cache.len + j)) {
                    *xv += pv;
                }
            }
        }
    }

    let linear = |name: &str, xin: &Mat| -> Mat {
        if let Some(lr) = lowrank {
            if let Some((wu, wv)) = lr.get(name) {
                return matmul_bt(&matmul_bt(xin, wv), wu);
            }
        }
        project(xin, params.get(name))
    };

    // all caches share one config, hence one name table (shape-checked
    // above); per-sequence RoPE tables are bit-identical for equal configs
    let names = Arc::clone(&seqs[0].0.names);
    for li in 0..cfg.n_layers {
        let ln = &names[li];

        let ln1 = norm_fwd(&x, param_1d(params, &ln.ln1), eps, llama);
        let mut q = linear(&ln.wq, &ln1.y);
        let mut k = linear(&ln.wk, &ln1.y);
        let v = linear(&ln.wv, &ln1.y);
        // rotate each row at its own absolute position, then append the
        // whole run's K/V rows BEFORE any attention — later in-run
        // positions attend over earlier ones through the cache
        for (s, (cache, toks)) in seqs.iter_mut().enumerate() {
            for j in 0..toks.len() {
                let r = base[s] + j;
                let pos = cache.len + j;
                if llama {
                    rope_rotate_row(q.row_mut(r), pos * half, h, dh,
                                    &cache.cos, &cache.sin, false);
                    rope_rotate_row(k.row_mut(r), pos * half, h, dh,
                                    &cache.cos, &cache.sin, false);
                }
                cache.set_k_row(li, pos, k.row(r));
                cache.set_v_row(li, pos, v.row(r));
            }
        }
        // attention rows are independent (each reads only its own cache and
        // its q row, and writes its own output row), so they fan out across
        // the pool in contiguous bands — this keeps the multi-slot decode
        // attention parallel, not just the GEMMs.  Per-row (K, V, position)
        // tables are snapshotted first so workers only read shared state.
        let mut attn = Mat::zeros(total, d);
        {
            let mut row_seq = Vec::with_capacity(total);
            let mut row_pos = Vec::with_capacity(total);
            for (s, (cache, toks)) in seqs.iter().enumerate() {
                for j in 0..toks.len() {
                    row_seq.push(s);
                    row_pos.push(cache.len + j);
                }
            }
            // per-sequence layer views over the (now fully written) block
            // tables: workers read shared `Arc<KvBlock>` storage only
            let kv: Vec<_> =
                seqs.iter().map(|(c, _)| c.layer_view(li)).collect();
            let band = total.div_ceil(exec::threads().min(total));
            exec::par_chunks_mut(&mut attn.data, band * d, |ci, chunk| {
                for (i, out) in chunk.chunks_mut(d).enumerate() {
                    let r = ci * band + i;
                    attention_step_row(q.row(r), &kv[row_seq[r]], row_pos[r],
                                       h, dh, out);
                }
            });
        }
        let attn_o = linear(&ln.wo, &attn);
        x.add_assign(&attn_o);

        let ln2 = norm_fwd(&x, param_1d(params, &ln.ln2), eps, llama);
        let act = if llama {
            let g = linear(&ln.mlp_gate, &ln2.y);
            let u = linear(&ln.mlp_up, &ln2.y);
            let mut act = Mat::zeros(total, ff);
            for i in 0..act.data.len() {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            act
        } else {
            let g = linear(&ln.mlp_gate, &ln2.y);
            let mut act = Mat::zeros(total, ff);
            for i in 0..act.data.len() {
                act.data[i] = gelu(g.data[i]);
            }
            act
        };
        let down = linear(&ln.mlp_down, &act);
        x.add_assign(&down);
    }

    // only the requested rows pay for the head: gather them and push them
    // through one batched final-norm + tied-head projection.  `Last` runs
    // contribute their final row, `All` runs (speculative verify) every row,
    // interior prefill chunks nothing — those skip the vocab GEMM entirely.
    // Norm + projection are row-local, so batching rows from several
    // sequences cannot change any row's bits.
    let mut wanted: Vec<(usize, usize)> = Vec::new(); // (seq, run-local row)
    for (s, (_, toks)) in seqs.iter().enumerate() {
        match modes[s] {
            LogitsMode::None => {}
            LogitsMode::Last => wanted.push((s, toks.len() - 1)),
            LogitsMode::All => wanted.extend((0..toks.len()).map(|j| (s, j))),
        }
    }
    let mut out: Vec<Option<Mat>> = (0..seqs.len()).map(|_| None).collect();
    if !wanted.is_empty() {
        let mut xl = Mat::zeros(wanted.len(), d);
        for (w, &(s, j)) in wanted.iter().enumerate() {
            xl.row_mut(w).copy_from_slice(x.row(base[s] + j));
        }
        let fin = norm_fwd(&xl, param_1d(params, "final_ln"), eps, llama);
        let logits = project(&fin.y, embed); // tied head: (W, V)
        // rows were gathered in (seq, run-position) order, so each
        // sequence's rows are contiguous in `logits`
        let mut w = 0usize;
        for (s, (_, toks)) in seqs.iter().enumerate() {
            let n = match modes[s] {
                LogitsMode::None => 0,
                LogitsMode::Last => 1,
                LogitsMode::All => toks.len(),
            };
            if n == 0 {
                continue;
            }
            let mut m = Mat::zeros(n, vocab);
            for r in 0..n {
                m.set_row(r, logits.row(w + r));
            }
            out[s] = Some(m);
            w += n;
        }
    }

    for (cache, toks) in seqs.iter_mut() {
        cache.len += toks.len();
    }
    Ok(out)
}

/// One Adam step (beta1 = 0.9, beta2 = 0.95, eps = 1e-8, no weight decay —
/// `model.py::make_train_step`'s constants).  Updates params/m/v in place
/// and returns the pre-update loss.
pub fn adam_step(cfg: &ConfigMeta, params: &mut ParamStore, m: &mut ParamStore,
                 v: &mut ParamStore, step: i32, lr: f32, tokens: &IntTensor)
                 -> Result<f32> {
    let (loss, grads) = loss_and_param_grads(cfg, params, tokens)?;
    let t = step + 1;
    let bc1 = (1.0 - 0.9f64.powi(t)) as f32;
    let bc2 = (1.0 - 0.95f64.powi(t)) as f32;
    const B1: f32 = 0.9;
    const B2: f32 = 0.95;
    const EPS: f32 = 1e-8;
    let names: Vec<String> = cfg.params.iter().map(|p| p.name.clone()).collect();
    for name in &names {
        let g = &grads[name];
        let pt = params.get_mut(name);
        let mt = m.get_mut(name);
        let vt = v.get_mut(name);
        for i in 0..pt.data.len() {
            let gi = g.data[i];
            let mn = B1 * mt.data[i] + (1.0 - B1) * gi;
            let vn = B2 * vt.data[i] + (1.0 - B2) * gi * gi;
            let upd = (mn / bc1) / ((vn / bc2).sqrt() + EPS);
            pt.data[i] -= lr * upd;
            mt.data[i] = mn;
            vt.data[i] = vn;
        }
    }
    Ok(loss)
}

// ---------------------------------------------------------------------------
// forward with optional trace
// ---------------------------------------------------------------------------

struct NormTrace {
    y: Mat,
    rstd: Vec<f32>,
    /// per-row mean (layernorm only; empty for rmsnorm)
    mean: Vec<f32>,
}

struct LayerTrace {
    x_in: Mat,
    ln1: NormTrace,
    /// q/k post-RoPE (llama) or raw (opt); (B·T, d)
    q: Mat,
    k: Mat,
    v: Mat,
    /// softmax probabilities, (B·H·T, T), strictly causal rows
    probs: Mat,
    /// merged attention output (pre-Wo), (B·T, d)
    attn: Mat,
    x_mid: Mat,
    ln2: NormTrace,
    /// llama: gate / up pre-activations; opt: g = win output, u unused
    g: Mat,
    u: Mat,
    /// MLP activation feeding the down projection, (B·T, ff)
    act: Mat,
}

struct Trace {
    b: usize,
    inp: Vec<usize>,
    /// next-token target per row (for the loss backward)
    tgts: Vec<usize>,
    layers: Vec<LayerTrace>,
    x_last: Mat,
    fin: NormTrace,
    logits: Mat,
}

/// Row `r` of a 2-D weight tensor, borrowed in place.
#[inline]
fn trow(t: &Tensor, r: usize) -> &[f32] {
    let cols = t.shape[1];
    &t.data[r * cols..(r + 1) * cols]
}

/// `x · Wᵀ` with W borrowed straight out of the parameter store.
#[inline]
fn project(x: &Mat, w: &Tensor) -> Mat {
    matmul_bt_flat(x, &w.data, w.shape[0], w.shape[1])
}

/// `x · W` with W borrowed straight out of the parameter store.
#[inline]
fn project_t(x: &Mat, w: &Tensor) -> Mat {
    matmul_flat(x, &w.data, w.shape[0], w.shape[1])
}

#[allow(clippy::too_many_lines)]
fn run(cfg: &ConfigMeta, params: &ParamStore, tokens: &IntTensor,
       lowrank: Option<&BTreeMap<String, (Mat, Mat)>>, keep: bool,
       want_sites: bool)
       -> Result<(f32, Mat, Option<Trace>, Vec<(String, Mat)>)> {
    ensure!(tokens.shape.len() == 2 && tokens.shape[1] == cfg.seq_len + 1,
            "tokens must be (B, T+1), got {:?}", tokens.shape);
    let b = tokens.shape[0];
    ensure!(b >= 1, "empty batch");
    let t_len = cfg.seq_len;
    let (d, h, ff, vocab) = (cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.vocab);
    let dh = d / h;
    let bt = b * t_len;
    let llama = cfg.arch == "llama";
    let eps = cfg.norm_eps;

    let embed = params.get("embed");

    // token gather (+ learned positions for opt)
    let mut inp = vec![0usize; bt];
    let mut x = Mat::zeros(bt, d);
    for bi in 0..b {
        for ti in 0..t_len {
            let tok = tokens.data[bi * (t_len + 1) + ti];
            ensure!(tok >= 0 && (tok as usize) < vocab,
                    "token {tok} out of range [0, {vocab})");
            let r = bi * t_len + ti;
            inp[r] = tok as usize;
            x.row_mut(r).copy_from_slice(trow(embed, tok as usize));
        }
    }
    if !llama {
        let pos = params.get("pos_embed");
        for bi in 0..b {
            for ti in 0..t_len {
                let r = bi * t_len + ti;
                let xr = x.row_mut(r);
                for (xv, pv) in xr.iter_mut().zip(trow(pos, ti)) {
                    *xv += pv;
                }
            }
        }
    }

    let (cos_tab, sin_tab) = if llama {
        rope_tables(t_len, dh, cfg.rope_theta)
    } else {
        (Vec::new(), Vec::new())
    };

    let linear = |name: &str, xin: &Mat| -> Mat {
        if let Some(lr) = lowrank {
            if let Some((wu, wv)) = lr.get(name) {
                return matmul_bt(&matmul_bt(xin, wv), wu);
            }
        }
        project(xin, params.get(name))
    };

    let mut sites: Vec<(String, Mat)> = Vec::new();
    let mut layers: Vec<LayerTrace> = Vec::new();

    let names = layer_names(cfg);
    for li in 0..cfg.n_layers {
        let ln = &names[li];
        let x_in = if keep { x.clone() } else { Mat::zeros(0, 0) };

        let ln1 = norm_fwd(&x, param_1d(params, &ln.ln1), eps, llama);
        if want_sites {
            sites.push((format!("{}attn_in", ln.prefix), ln1.y.clone()));
        }
        let mut q = linear(&ln.wq, &ln1.y);
        let mut k = linear(&ln.wk, &ln1.y);
        let v = linear(&ln.wv, &ln1.y);
        if llama {
            rope_apply(&mut q, t_len, h, dh, &cos_tab, &sin_tab, false);
            rope_apply(&mut k, t_len, h, dh, &cos_tab, &sin_tab, false);
        }
        let (attn, probs) = attention_fwd(&q, &k, &v, b, t_len, h, dh);
        if want_sites {
            sites.push((format!("{}attn_out_in", ln.prefix), attn.clone()));
        }
        let attn_o = linear(&ln.wo, &attn);
        x.add_assign(&attn_o);
        let x_mid = if keep { x.clone() } else { Mat::zeros(0, 0) };

        let ln2 = norm_fwd(&x, param_1d(params, &ln.ln2), eps, llama);
        if want_sites {
            sites.push((format!("{}mlp_in", ln.prefix), ln2.y.clone()));
        }
        let (g, u, act) = if llama {
            let g = linear(&ln.mlp_gate, &ln2.y);
            let u = linear(&ln.mlp_up, &ln2.y);
            let mut act = Mat::zeros(bt, ff);
            for i in 0..act.data.len() {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            (g, u, act)
        } else {
            let g = linear(&ln.mlp_gate, &ln2.y);
            let mut act = Mat::zeros(bt, ff);
            for i in 0..act.data.len() {
                act.data[i] = gelu(g.data[i]);
            }
            (g, Mat::zeros(0, 0), act)
        };
        if want_sites {
            sites.push((format!("{}mlp_down_in", ln.prefix), act.clone()));
        }
        let down = linear(&ln.mlp_down, &act);
        x.add_assign(&down);

        if keep {
            layers.push(LayerTrace {
                x_in,
                ln1,
                q,
                k,
                v,
                probs,
                attn,
                x_mid,
                ln2,
                g,
                u,
                act,
            });
        }
    }

    let x_last = if keep { x.clone() } else { Mat::zeros(0, 0) };
    let fin = norm_fwd(&x, param_1d(params, "final_ln"), eps, llama);
    let logits = project(&fin.y, embed); // tied head: (B·T, V)

    // mean next-token cross-entropy
    let mut tgts = vec![0usize; bt];
    let mut loss_sum = 0.0f64;
    for bi in 0..b {
        for ti in 0..t_len {
            let r = bi * t_len + ti;
            let tgt = tokens.data[bi * (t_len + 1) + ti + 1];
            ensure!(tgt >= 0 && (tgt as usize) < vocab,
                    "target {tgt} out of range [0, {vocab})");
            tgts[r] = tgt as usize;
            let row = logits.row(r);
            let maxv = row.iter().fold(f32::NEG_INFINITY, |m2, &z| m2.max(z));
            let mut sum = 0.0f64;
            for &z in row {
                sum += ((z - maxv) as f64).exp();
            }
            let lse = sum.ln() + maxv as f64;
            loss_sum += lse - row[tgt as usize] as f64;
        }
    }
    let loss = (loss_sum / bt as f64) as f32;
    ensure!(loss.is_finite(), "non-finite loss");

    let trace = if keep {
        Some(Trace { b, inp, tgts, layers, x_last, fin, logits: logits.clone() })
    } else {
        None
    };
    Ok((loss, logits, trace, sites))
}

// ---------------------------------------------------------------------------
// backward
// ---------------------------------------------------------------------------

fn backward(cfg: &ConfigMeta, params: &ParamStore, trace: &Trace)
            -> BTreeMap<String, Tensor> {
    let b = trace.b;
    let t_len = cfg.seq_len;
    let (d, h, vocab) = (cfg.d_model, cfg.n_heads, cfg.vocab);
    let dh = d / h;
    let bt = b * t_len;
    let llama = cfg.arch == "llama";
    let eps = cfg.norm_eps;

    let embed = params.get("embed");
    let mut grads: BTreeMap<String, Tensor> = BTreeMap::new();

    // dL/dlogits for mean cross-entropy: (softmax - onehot) / (B·T)
    let inv = 1.0f32 / bt as f32;
    let mut dlogits = Mat::zeros(bt, vocab);
    for r in 0..bt {
        let row = trace.logits.row(r);
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m2, &z| m2.max(z));
        let mut sum = 0.0f64;
        for &z in row {
            sum += ((z - maxv) as f64).exp();
        }
        let dr = dlogits.row_mut(r);
        for j in 0..vocab {
            dr[j] = (((row[j] - maxv) as f64).exp() / sum) as f32 * inv;
        }
        dr[trace.tgts[r]] -= inv;
    }

    // tied head: logits = xf · Eᵀ
    let mut d_embed = matmul(&dlogits.transpose(), &trace.fin.y); // (V, d)
    let dxf = project_t(&dlogits, embed); // (B·T, d)

    let (mut dx, d_final_ln) = norm_bwd(&trace.x_last, &trace.fin,
                                        param_1d(params, "final_ln"), &dxf,
                                        eps, llama);
    grads.insert("final_ln".into(), Tensor::from_vec(&[d], d_final_ln));

    let (cos_tab, sin_tab) = if llama {
        rope_tables(t_len, dh, cfg.rope_theta)
    } else {
        (Vec::new(), Vec::new())
    };

    for li in (0..cfg.n_layers).rev() {
        let p = format!("layers.{li}.");
        let lt = &trace.layers[li];

        // ---- MLP branch ----
        let down_name = if llama { format!("{p}wdown") } else { format!("{p}wout") };
        let dact = project_t(&dx, params.get(&down_name)); // (B·T, ff)
        let d_wdown = matmul(&dx.transpose(), &lt.act); // (d, ff)
        grads.insert(down_name, Tensor::from_mat(&d_wdown));

        let dh2 = if llama {
            let mut dg = Mat::zeros(dact.rows, dact.cols);
            let mut du = Mat::zeros(dact.rows, dact.cols);
            for i in 0..dact.data.len() {
                let gv = lt.g.data[i];
                let sig = sigmoid(gv);
                let si = gv * sig;
                du.data[i] = dact.data[i] * si;
                dg.data[i] = dact.data[i] * lt.u.data[i]
                    * (sig * (1.0 + gv * (1.0 - sig)));
            }
            grads.insert(format!("{p}wgate"),
                         Tensor::from_mat(&matmul(&dg.transpose(), &lt.ln2.y)));
            grads.insert(format!("{p}wup"),
                         Tensor::from_mat(&matmul(&du.transpose(), &lt.ln2.y)));
            let mut dh2 = project_t(&dg, params.get(&format!("{p}wgate")));
            dh2.add_assign(&project_t(&du, params.get(&format!("{p}wup"))));
            dh2
        } else {
            let mut dg = Mat::zeros(dact.rows, dact.cols);
            for i in 0..dact.data.len() {
                dg.data[i] = dact.data[i] * gelu_grad(lt.g.data[i]);
            }
            grads.insert(format!("{p}win"),
                         Tensor::from_mat(&matmul(&dg.transpose(), &lt.ln2.y)));
            project_t(&dg, params.get(&format!("{p}win")))
        };

        let (dx_ln2, d_ln2) = norm_bwd(&lt.x_mid, &lt.ln2,
                                       param_1d(params, &format!("{p}ln2")),
                                       &dh2, eps, llama);
        grads.insert(format!("{p}ln2"), Tensor::from_vec(&[d], d_ln2));
        let mut dx_mid = dx; // residual pass-through
        dx_mid.add_assign(&dx_ln2);

        // ---- attention branch ----
        let dattn = project_t(&dx_mid, params.get(&format!("{p}wo"))); // (B·T, d)
        grads.insert(format!("{p}wo"),
                     Tensor::from_mat(&matmul(&dx_mid.transpose(), &lt.attn)));

        let (mut dq, mut dk, dv) =
            attention_bwd(&lt.q, &lt.k, &lt.v, &lt.probs, &dattn, b, t_len, h, dh);
        if llama {
            rope_apply(&mut dq, t_len, h, dh, &cos_tab, &sin_tab, true);
            rope_apply(&mut dk, t_len, h, dh, &cos_tab, &sin_tab, true);
        }

        grads.insert(format!("{p}wq"),
                     Tensor::from_mat(&matmul(&dq.transpose(), &lt.ln1.y)));
        grads.insert(format!("{p}wk"),
                     Tensor::from_mat(&matmul(&dk.transpose(), &lt.ln1.y)));
        grads.insert(format!("{p}wv"),
                     Tensor::from_mat(&matmul(&dv.transpose(), &lt.ln1.y)));
        let mut dh1 = project_t(&dq, params.get(&format!("{p}wq")));
        dh1.add_assign(&project_t(&dk, params.get(&format!("{p}wk"))));
        dh1.add_assign(&project_t(&dv, params.get(&format!("{p}wv"))));

        let (dx_ln1, d_ln1) = norm_bwd(&lt.x_in, &lt.ln1,
                                       param_1d(params, &format!("{p}ln1")),
                                       &dh1, eps, llama);
        grads.insert(format!("{p}ln1"), Tensor::from_vec(&[d], d_ln1));
        dx = dx_mid;
        dx.add_assign(&dx_ln1);
    }

    // embedding gather backward (+ learned positions for opt)
    for r in 0..bt {
        let tok = trace.inp[r];
        let (dr, erow) = (dx.row(r), d_embed.row_mut(tok));
        for (ev, &dv2) in erow.iter_mut().zip(dr) {
            *ev += dv2;
        }
    }
    if !llama {
        let mut dpos = Mat::zeros(cfg.seq_len, d);
        for bi in 0..b {
            for ti in 0..t_len {
                let r = bi * t_len + ti;
                let (src, prow) = (dx.row(r), dpos.row_mut(ti));
                for (pv, &sv) in prow.iter_mut().zip(src) {
                    *pv += sv;
                }
            }
        }
        grads.insert("pos_embed".into(), Tensor::from_mat(&dpos));
    }
    grads.insert("embed".into(), Tensor::from_mat(&d_embed));
    grads
}

// ---------------------------------------------------------------------------
// building blocks
// ---------------------------------------------------------------------------

fn param_1d<'a>(params: &'a ParamStore, name: &str) -> &'a [f32] {
    &params.get(name).data
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// tanh-approximate GELU (JAX's default `jax.nn.gelu`).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[inline]
fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    let u = C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// RMSNorm (llama) or scale-only LayerNorm (opt) forward over rows.  The
/// per-row moments accumulate through the canonical 4-lane-strided f64
/// reductions in `linalg::kernels`, shared by the full forward and the
/// decode paths — which is one of the three legs the decode-parity
/// bit-match stands on.
fn norm_fwd(x: &Mat, scale: &[f32], eps: f32, rms: bool) -> NormTrace {
    let (rows, d) = (x.rows, x.cols);
    let mut y = Mat::zeros(rows, d);
    let mut rstd = vec![0.0f32; rows];
    let mut mean = if rms { Vec::new() } else { vec![0.0f32; rows] };
    for r in 0..rows {
        let xr = x.row(r);
        if rms {
            let ms: f64 = kernels::sum_sq_f64(xr) / d as f64;
            let rs = (1.0 / (ms + eps as f64).sqrt()) as f32;
            rstd[r] = rs;
            let yr = y.row_mut(r);
            for j in 0..d {
                yr[j] = xr[j] * rs * scale[j];
            }
        } else {
            let mu = (kernels::sum_f64(xr) / d as f64) as f32;
            let var: f64 = kernels::sum_sq_centered_f64(xr, mu) / d as f64;
            let rs = (1.0 / (var + eps as f64).sqrt()) as f32;
            mean[r] = mu;
            rstd[r] = rs;
            let yr = y.row_mut(r);
            for j in 0..d {
                yr[j] = (xr[j] - mu) * rs * scale[j];
            }
        }
    }
    NormTrace { y, rstd, mean }
}

/// Backward of `norm_fwd`: returns (dx, dscale).
fn norm_bwd(x: &Mat, nt: &NormTrace, scale: &[f32], dy: &Mat, _eps: f32,
            rms: bool) -> (Mat, Vec<f32>) {
    let (rows, d) = (x.rows, x.cols);
    let mut dx = Mat::zeros(rows, d);
    let mut dscale = vec![0.0f32; d];
    for r in 0..rows {
        let xr = x.row(r);
        let dyr = dy.row(r);
        let rs = nt.rstd[r] as f64;
        if rms {
            let mut dot = 0.0f64;
            for j in 0..d {
                dot += dyr[j] as f64 * scale[j] as f64 * xr[j] as f64;
            }
            let c = rs * rs * rs * dot / d as f64;
            let dxr = dx.row_mut(r);
            for j in 0..d {
                dxr[j] = (rs * (dyr[j] as f64 * scale[j] as f64)
                    - c * xr[j] as f64) as f32;
                dscale[j] += dyr[j] * xr[j] * nt.rstd[r];
            }
        } else {
            let mu = nt.mean[r] as f64;
            let mut m1 = 0.0f64; // mean of a_j
            let mut m2 = 0.0f64; // mean of a_j * xh_j
            for j in 0..d {
                let xh = (xr[j] as f64 - mu) * rs;
                let a = dyr[j] as f64 * scale[j] as f64;
                m1 += a;
                m2 += a * xh;
            }
            m1 /= d as f64;
            m2 /= d as f64;
            let dxr = dx.row_mut(r);
            for j in 0..d {
                let xh = (xr[j] as f64 - mu) * rs;
                let a = dyr[j] as f64 * scale[j] as f64;
                dxr[j] = (rs * (a - m1 - xh * m2)) as f32;
                dscale[j] += dyr[j] * xh as f32;
            }
        }
    }
    (dx, dscale)
}

/// Rotary-embedding tables: cos/sin of pos·θ^(-i/half), (T × half).
/// `pub(crate)` so the KV cache can precompute them once per sequence.
pub(crate) fn rope_tables(t_len: usize, dh: usize, theta: f64)
                          -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let freqs: Vec<f64> = (0..half)
        .map(|i| theta.powf(-(i as f64) / half as f64))
        .collect();
    let mut cos = vec![0.0f32; t_len * half];
    let mut sin = vec![0.0f32; t_len * half];
    for t in 0..t_len {
        for (i, &freq) in freqs.iter().enumerate() {
            let ang = t as f64 * freq;
            cos[t * half + i] = ang.cos() as f32;
            sin[t * half + i] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// Apply (or invert, for the backward pass) the rotary embedding in place
/// over a (B·T, d) matrix laid out as H heads of dh columns.
fn rope_apply(m: &mut Mat, t_len: usize, h: usize, dh: usize, cos: &[f32],
              sin: &[f32], inverse: bool) {
    let half = dh / 2;
    for r in 0..m.rows {
        let t = r % t_len;
        rope_rotate_row(m.row_mut(r), t * half, h, dh, cos, sin, inverse);
    }
}

/// Rotate one (H heads × dh) row in place at table offset `tab`
/// (= position · dh/2).  Shared by the batched apply above and the
/// single-position decode step, so both produce identical bits.
fn rope_rotate_row(row: &mut [f32], tab: usize, h: usize, dh: usize,
                   cos: &[f32], sin: &[f32], inverse: bool) {
    let half = dh / 2;
    for hi in 0..h {
        let off = hi * dh;
        for i in 0..half {
            let c = cos[tab + i];
            let s = sin[tab + i];
            let x1 = row[off + i];
            let x2 = row[off + half + i];
            if inverse {
                row[off + i] = x1 * c + x2 * s;
                row[off + half + i] = -x1 * s + x2 * c;
            } else {
                row[off + i] = x1 * c - x2 * s;
                row[off + half + i] = x1 * s + x2 * c;
            }
        }
    }
}

/// Causal multi-head attention forward.  Returns the merged (B·T, d) output
/// and the softmax probabilities (B·H·T, T) for the backward pass.
fn attention_fwd(q: &Mat, k: &Mat, v: &Mat, b: usize, t_len: usize, h: usize,
                 dh: usize) -> (Mat, Mat) {
    let d = h * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut attn = Mat::zeros(b * t_len, d);
    let mut probs = Mat::zeros(b * h * t_len, t_len);
    for bi in 0..b {
        let base = bi * t_len;
        for hi in 0..h {
            let off = hi * dh;
            for t in 0..t_len {
                let prow_idx = (bi * h + hi) * t_len + t;
                // scores (masked rows stay zero)
                let mut maxv = f32::NEG_INFINITY;
                {
                    let qrow = &q.row(base + t)[off..off + dh];
                    let prow = probs.row_mut(prow_idx);
                    for u in 0..=t {
                        let krow = &k.data[(base + u) * d + off
                            ..(base + u) * d + off + dh];
                        let s = dot_f32(qrow, krow) * scale;
                        prow[u] = s;
                        maxv = maxv.max(s);
                    }
                    let mut sum = 0.0f64;
                    for u in 0..=t {
                        let e = ((prow[u] - maxv) as f64).exp();
                        prow[u] = e as f32;
                        sum += e;
                    }
                    let isum = (1.0 / sum) as f32;
                    for u in 0..=t {
                        prow[u] *= isum;
                    }
                }
                // out_t = Σ_u p[u] · v_u — one canonical axpy per position,
                // ascending u, exactly as `attention_step_row` merges (the
                // old `pu == 0.0` skip is gone: a skipped `+0.0` term is
                // observable against a `-0.0` accumulator, so it would
                // break the step/batched bit-match the kernels guarantee)
                let prow = probs.row(prow_idx);
                let orow = &mut attn.data[(base + t) * d + off
                    ..(base + t) * d + off + dh];
                for (u, &pu) in prow.iter().enumerate().take(t + 1) {
                    let vrow = &v.data[(base + u) * d + off
                        ..(base + u) * d + off + dh];
                    kernels::axpy_f32(orow, pu, vrow);
                }
            }
        }
    }
    (attn, probs)
}

/// Causal attention for ONE query position `t` against contiguous K/V
/// matrices — the unit-test harness for [`attention_step_row`] (the serving
/// paths read through paged block tables instead; see `decode::kv`).  The
/// score/softmax/merge loops mirror [`attention_fwd`]'s per-position body
/// operation for operation (f32 score + running max, f64 exp-sum, f32
/// normalizer, value merge in ascending-u order), so the output row
/// bit-matches the full forward's row `t`.
#[cfg(test)]
fn attention_step(q: &Mat, kc: &Mat, vc: &Mat, t: usize, h: usize, dh: usize)
                  -> Mat {
    let mut attn = Mat::zeros(1, h * dh);
    attention_step_row(q.row(0), &crate::decode::kv::MatKv { k: kc, v: vc },
                       t, h, dh, attn.row_mut(0));
    attn
}

/// Causal attention for one query row `qr` at position `t` against cached
/// K/V rows `0..=t`, accumulated into the zeroed output row `out`.
/// Generic over [`KvRows`], so the paged block tables (`decode::kv`) and
/// plain contiguous matrices feed the identical score/softmax/merge loops
/// — storage layout cannot change a bit.  Shared by the single-sequence
/// step and the batched [`decode_batch`] kernel, so every path produces
/// identical bits per position.
fn attention_step_row<S: KvRows>(qr: &[f32], kv: &S, t: usize, h: usize,
                                 dh: usize, out: &mut [f32]) {
    let scale = 1.0 / (dh as f32).sqrt();
    let mut prow = vec![0.0f32; t + 1];
    for hi in 0..h {
        let off = hi * dh;
        let qrow = &qr[off..off + dh];
        let mut maxv = f32::NEG_INFINITY;
        for u in 0..=t {
            let krow = &kv.k_row(u)[off..off + dh];
            let s = dot_f32(qrow, krow) * scale;
            prow[u] = s;
            maxv = maxv.max(s);
        }
        let mut sum = 0.0f64;
        for u in 0..=t {
            let e = ((prow[u] - maxv) as f64).exp();
            prow[u] = e as f32;
            sum += e;
        }
        let isum = (1.0 / sum) as f32;
        for u in 0..=t {
            prow[u] *= isum;
        }
        let orow = &mut out[off..off + dh];
        for (u, &pu) in prow.iter().enumerate().take(t + 1) {
            let vrow = &kv.v_row(u)[off..off + dh];
            kernels::axpy_f32(orow, pu, vrow);
        }
    }
}

/// Backward of `attention_fwd`: gradients w.r.t. q, k, v (all (B·T, d)).
#[allow(clippy::too_many_arguments)]
fn attention_bwd(q: &Mat, k: &Mat, v: &Mat, probs: &Mat, dattn: &Mat,
                 b: usize, t_len: usize, h: usize, dh: usize)
                 -> (Mat, Mat, Mat) {
    let d = h * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = Mat::zeros(b * t_len, d);
    let mut dk = Mat::zeros(b * t_len, d);
    let mut dv = Mat::zeros(b * t_len, d);
    let mut dp = vec![0.0f32; t_len];
    for bi in 0..b {
        let base = bi * t_len;
        for hi in 0..h {
            let off = hi * dh;
            for t in 0..t_len {
                let prow = probs.row((bi * h + hi) * t_len + t);
                let dout = &dattn.data[(base + t) * d + off
                    ..(base + t) * d + off + dh];
                // dv_u += p[u]·dout ; dp[u] = dout·v_u
                let mut rowdot = 0.0f64;
                for u in 0..=t {
                    let vrow = &v.data[(base + u) * d + off
                        ..(base + u) * d + off + dh];
                    dp[u] = dot_f32(dout, vrow);
                    rowdot += dp[u] as f64 * prow[u] as f64;
                    let dvrow = &mut dv.data[(base + u) * d + off
                        ..(base + u) * d + off + dh];
                    let pu = prow[u];
                    if pu != 0.0 {
                        for (dst, &src) in dvrow.iter_mut().zip(dout) {
                            *dst += pu * src;
                        }
                    }
                }
                // softmax backward + score scale
                let rowdot = rowdot as f32;
                for u in 0..=t {
                    let ds = prow[u] * (dp[u] - rowdot) * scale;
                    if ds == 0.0 {
                        continue;
                    }
                    let krow = &k.data[(base + u) * d + off
                        ..(base + u) * d + off + dh];
                    let qrow = &q.data[(base + t) * d + off
                        ..(base + t) * d + off + dh];
                    {
                        let dqrow = &mut dq.data[(base + t) * d + off
                            ..(base + t) * d + off + dh];
                        for (dst, &src) in dqrow.iter_mut().zip(krow) {
                            *dst += ds * src;
                        }
                    }
                    {
                        let dkrow = &mut dk.data[(base + u) * d + off
                            ..(base + u) * d + off + dh];
                        for (dst, &src) in dkrow.iter_mut().zip(qrow) {
                            *dst += ds * src;
                        }
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activations_and_grads_consistent() {
        // silu/gelu derivatives vs central differences
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3f32;
            let num = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((num - gelu_grad(x)).abs() < 1e-2, "gelu'({x})");
            let snum = (silu(x + h) - silu(x - h)) / (2.0 * h);
            let sig = sigmoid(x);
            let san = sig * (1.0 + x * (1.0 - sig));
            assert!((snum - san).abs() < 1e-2, "silu'({x})");
        }
    }

    #[test]
    fn rope_roundtrip() {
        let (cos, sin) = rope_tables(8, 4, 10000.0);
        let mut m = Mat::zeros(16, 8); // b=2, t=8, h=2, dh=4
        for (i, v) in m.data.iter_mut().enumerate() {
            *v = (i as f32 * 0.37).sin();
        }
        let orig = m.clone();
        rope_apply(&mut m, 8, 2, 4, &cos, &sin, false);
        rope_apply(&mut m, 8, 2, 4, &cos, &sin, true);
        for (a, b2) in m.data.iter().zip(&orig.data) {
            assert!((a - b2).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_step_bitmatches_batched_rows() {
        let mut rng = crate::util::rng::Rng::new(11);
        let (t_len, h, dh) = (7usize, 2usize, 4usize);
        let d = h * dh;
        let q = Mat::randn(&mut rng, t_len, d, 1.0);
        let k = Mat::randn(&mut rng, t_len, d, 1.0);
        let v = Mat::randn(&mut rng, t_len, d, 1.0);
        let (full, _) = attention_fwd(&q, &k, &v, 1, t_len, h, dh);
        for t in 0..t_len {
            let mut q1 = Mat::zeros(1, d);
            q1.row_mut(0).copy_from_slice(q.row(t));
            let step = attention_step(&q1, &k, &v, t, h, dh);
            assert_eq!(step.row(0), full.row(t), "position {t}");
        }
    }

    #[test]
    fn layer_name_tables_cached_and_correct() {
        let m = crate::model::Manifest::builtin();
        let llama = m.config("tiny");
        let a = layer_names(llama);
        let b = layer_names(llama);
        assert!(Arc::ptr_eq(&a, &b), "second lookup hits the cache");
        assert_eq!(a.len(), llama.n_layers);
        assert_eq!(a[0].wq, "layers.0.wq");
        assert_eq!(a[0].mlp_gate, "layers.0.wgate");
        assert_eq!(a[0].mlp_down, "layers.0.wdown");
        let last = llama.n_layers - 1;
        assert_eq!(a[last].ln2, format!("layers.{last}.ln2"));

        let opt = m.config("opt_tiny");
        let o = layer_names(opt);
        assert_eq!(o[0].mlp_gate, "layers.0.win");
        assert_eq!(o[0].mlp_down, "layers.0.wout");
        assert_eq!(o[0].prefix, "layers.0.");
    }

    #[test]
    fn attention_rows_are_distributions() {
        let mut rng = crate::util::rng::Rng::new(3);
        let (b, t, h, dh) = (2usize, 6usize, 2usize, 4usize);
        let q = Mat::randn(&mut rng, b * t, h * dh, 1.0);
        let k = Mat::randn(&mut rng, b * t, h * dh, 1.0);
        let v = Mat::randn(&mut rng, b * t, h * dh, 1.0);
        let (_, probs) = attention_fwd(&q, &k, &v, b, t, h, dh);
        for r in 0..probs.rows {
            let tpos = r % t;
            let sum: f32 = probs.row(r)[..=tpos].iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {r} sums to {sum}");
            for &z in &probs.row(r)[tpos + 1..] {
                assert_eq!(z, 0.0); // causal mask
            }
        }
    }
}
