//! PJRT runtime: load AOT HLO text, compile once, execute from the hot path.
//!
//! This wraps the `xla` crate exactly as /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  Artifacts are compiled lazily and cached
//! per file; Python is never involved.

pub mod session;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::model::Manifest;
use crate::tensor::Tensor;

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the artifact directory produced by `make artifacts`.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)
            .map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Default artifacts dir: `$ZS_ARTIFACTS` or `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("ZS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }

    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&Self::default_dir())
    }

    fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?,
        );
        self.cache
            .borrow_mut()
            .insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (so first-request latency is predictable).
    pub fn warmup(&self, file: &str) -> Result<()> {
        self.executable(file).map(|_| ())
    }

    /// Execute an artifact with ordered literal inputs; returns the
    /// decomposed output tuple (aot.py lowers with return_tuple=True).
    pub fn exec(&self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(file)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {file}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {file}"))?;
        Ok(lit.to_tuple()?)
    }

    /// Execute and convert every output to a host `Tensor` (f32 outputs only).
    pub fn exec_tensors(&self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<Tensor>> {
        self.exec(file, inputs)?
            .iter()
            .map(Tensor::from_literal)
            .collect()
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_and_lists_configs() {
        let rt = Runtime::load_default().expect("run `make artifacts` first");
        assert!(rt.manifest.configs.contains_key("tiny"));
        assert_eq!(rt.compiled_count(), 0); // lazy
    }
}
