//! Execution runtime: manifest-driven dispatch onto the native CPU kernels.
//!
//! Historically this wrapped the `xla` PJRT client and executed AOT-lowered
//! HLO text.  Offline builds have no XLA, so the runtime now executes every
//! graph natively (`runtime::native`) while keeping the manifest as the ABI
//! contract: artifact *signatures* (input order, shapes, ranks) are still
//! validated, and the per-artifact "compile" cache is preserved so warmup
//! and lazy-compile accounting behave as before.  `Runtime` is `Sync`: the
//! multi-worker serving drain shares one instance across worker threads.
//!
//! # Invariants
//!
//! Every kernel in [`native`] is deterministic and bit-identical for any
//! thread count: heavy projections route through the row-partitioned
//! parallel matmuls (`linalg::matmul`), whose per-element accumulation
//! order is fixed, and everything else is serial fixed-order scalar code.
//! The incremental decode kernels (`native::decode_step`,
//! `native::decode_batch`) additionally bit-match the full forward over
//! the same prefix — for every prompt chunking and across-slot batch
//! composition — which is the contract the decode/serving tiers build on
//! (`rust/tests/decode_parity.rs`, `rust/tests/server_loopback.rs`).
//!
//! The verify-mode entry point (`native::decode_batch_modes`, with a
//! per-sequence [`native::LogitsMode`]) extends that contract to *every*
//! position of a run: the logits row returned for run position `j` is
//! bit-identical to the single row a last-position call ending at `j`
//! would return.  Speculative decode rests on exactly this — the target
//! engine scores a `[pending, draft_1 .. draft_K]` run once, and each
//! accepted row matches what plain one-token-at-a-time decode would have
//! produced.

pub mod native;
pub mod session;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::Result;

use crate::model::Manifest;

/// The loaded artifact directory: manifest + compile-cache bookkeeping.
pub struct Runtime {
    dir: PathBuf,
    /// every model config the artifact set declares
    pub manifest: Manifest,
    /// artifact files "compiled" (first dispatched) so far
    cache: Mutex<BTreeSet<String>>,
}

impl Runtime {
    /// Load the artifact directory (falls back to the built-in manifest when
    /// no `manifest.json` is present — the native runtime needs no files).
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)
            .map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        Ok(Runtime {
            dir: artifacts_dir.to_path_buf(),
            manifest,
            cache: Mutex::new(BTreeSet::new()),
        })
    }

    /// Default artifacts dir: `$ZS_ARTIFACTS` or `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("ZS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }

    /// Load from the default artifacts directory (env-overridable).
    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&Self::default_dir())
    }

    /// The directory this runtime loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Record an artifact as prepared (the native analogue of lazy
    /// compilation; sessions call this on first dispatch).
    pub(crate) fn mark_compiled(&self, file: &str) {
        self.cache
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(file.to_string());
    }

    /// Pre-prepare an artifact (so first-request latency is predictable).
    pub fn warmup(&self, file: &str) -> Result<()> {
        self.mark_compiled(file);
        Ok(())
    }

    /// Distinct artifacts dispatched ("compiled") so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_and_lists_configs() {
        let rt = Runtime::load_default().expect("builtin manifest");
        assert!(rt.manifest.configs.contains_key("tiny"));
        assert_eq!(rt.compiled_count(), 0); // lazy
        assert!(rt.artifacts_dir().ends_with("artifacts"));
    }

    #[test]
    fn warmup_populates_cache() {
        let rt = Runtime::load_default().unwrap();
        let file = rt.manifest.config("tiny").fwd.file.clone();
        rt.warmup(&file).unwrap();
        rt.warmup(&file).unwrap();
        assert_eq!(rt.compiled_count(), 1);
    }
}
