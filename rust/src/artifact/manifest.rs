//! The `ZSAR` artifact manifest: a small, length-prefixed, checksummed
//! binary index of content-addressed chunks.
//!
//! ```text
//! magic    "ZSAR"                          4 bytes
//! version  u32 LE (currently 1)            4 bytes
//! body_len u64 LE                          8 bytes
//! body     n_records u32 LE
//!          n_records × record:
//!            class      u8   (0 meta / 1 param / 2 factor-U / 3 factor-V)
//!            label_len  u16 LE
//!            label      UTF-8 bytes ("param:embed", "u:layers.0.wq", ...)
//!            id         16 bytes (ChunkId of the chunk payload)
//!            len        u64 LE  (chunk payload length in bytes)
//! hash     16 bytes: ChunkId::of(body)
//! ```
//!
//! Every field that sizes an allocation is bounds-checked against the bytes
//! actually present *before* allocating, so adversarial inputs (fuzzed in
//! `rust/tests/proptests.rs`) can neither panic nor over-allocate — they
//! return structured errors naming the offending record.  The trailing body
//! hash covers every record byte, so any single-byte corruption anywhere in
//! the file fails decoding.

use std::collections::BTreeSet;

use super::hash::ChunkId;

/// Manifest file magic.
pub const MAGIC: &[u8; 4] = b"ZSAR";

/// Current manifest format version.
pub const VERSION: u32 = 1;

/// Hard cap on records per manifest — far above any real model (one record
/// per tensor / factor half), purely an allocation bound for hostile input.
pub const MAX_RECORDS: usize = 1 << 20;

/// Hard cap on a record label's byte length.
pub const MAX_LABEL_LEN: usize = 4096;

/// What a chunk holds — determines how [`super::bundle`] interprets it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkClass {
    /// JSON metadata: model identity, engine kind, tensor/factor tables.
    Meta,
    /// Raw little-endian f32 payload of one full parameter tensor.
    Param,
    /// Raw little-endian f32 payload of one low-rank U factor (m × k).
    FactorU,
    /// Raw little-endian f32 payload of one low-rank V factor (k × n).
    FactorV,
}

impl ChunkClass {
    fn code(self) -> u8 {
        match self {
            ChunkClass::Meta => 0,
            ChunkClass::Param => 1,
            ChunkClass::FactorU => 2,
            ChunkClass::FactorV => 3,
        }
    }

    fn from_code(c: u8) -> Option<ChunkClass> {
        match c {
            0 => Some(ChunkClass::Meta),
            1 => Some(ChunkClass::Param),
            2 => Some(ChunkClass::FactorU),
            3 => Some(ChunkClass::FactorV),
            _ => None,
        }
    }
}

/// One manifest entry: a labeled, typed pointer to a content-addressed
/// chunk plus its expected byte length.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkRecord {
    /// Payload interpretation.
    pub class: ChunkClass,
    /// Human-readable label ("param:embed", "u:layers.0.wq", "meta") —
    /// what corruption errors name.
    pub label: String,
    /// Content hash of the chunk payload (also its store file name).
    pub id: ChunkId,
    /// Expected payload length in bytes.
    pub len: u64,
}

/// A decoded artifact manifest: the ordered chunk records.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct ArtifactManifest {
    /// Records in pack order (meta first by convention, then params, then
    /// factor pairs).
    pub records: Vec<ChunkRecord>,
}

impl ArtifactManifest {
    /// Look up a record by label.
    pub fn record(&self, label: &str) -> Option<&ChunkRecord> {
        self.records.iter().find(|r| r.label == label)
    }

    /// The single metadata record; error if missing or duplicated.
    pub fn meta(&self) -> Result<&ChunkRecord, String> {
        let mut metas = self.records.iter()
            .filter(|r| r.class == ChunkClass::Meta);
        let first = metas.next()
            .ok_or_else(|| "manifest has no meta chunk".to_string())?;
        if metas.next().is_some() {
            return Err("manifest has more than one meta chunk".into());
        }
        Ok(first)
    }

    /// Serialize to the `ZSAR` byte format described in the module docs.
    ///
    /// Panics if a label exceeds [`MAX_LABEL_LEN`] or the record count
    /// exceeds [`MAX_RECORDS`] — both are builder bugs, not data errors.
    pub fn encode(&self) -> Vec<u8> {
        assert!(self.records.len() <= MAX_RECORDS, "too many records");
        let mut body = Vec::new();
        body.extend_from_slice(&(self.records.len() as u32).to_le_bytes());
        for r in &self.records {
            assert!(r.label.len() <= MAX_LABEL_LEN,
                    "label `{}` too long", r.label);
            body.push(r.class.code());
            body.extend_from_slice(&(r.label.len() as u16).to_le_bytes());
            body.extend_from_slice(r.label.as_bytes());
            body.extend_from_slice(&r.id.0);
            body.extend_from_slice(&r.len.to_le_bytes());
        }
        let digest = ChunkId::of(&body);
        let mut out = Vec::with_capacity(16 + body.len() + 16);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(body.len() as u64).to_le_bytes());
        out.extend_from_slice(&body);
        out.extend_from_slice(&digest.0);
        out
    }

    /// Decode and fully validate a `ZSAR` manifest.  Never panics and never
    /// allocates more than the input could justify; every failure names
    /// what was wrong and where.
    pub fn decode(bytes: &[u8]) -> Result<ArtifactManifest, String> {
        let mut cur = Cursor { bytes, pos: 0 };
        let magic = cur.take(4, "magic")?;
        if magic != MAGIC {
            return Err(format!("bad manifest magic {magic:?} (want ZSAR)"));
        }
        let version = u32::from_le_bytes(
            cur.take(4, "version")?.try_into().expect("4 bytes"));
        if version != VERSION {
            return Err(format!(
                "unsupported manifest version {version} (want {VERSION})"));
        }
        let body_len = u64::from_le_bytes(
            cur.take(8, "body length")?.try_into().expect("8 bytes"));
        let remaining = (bytes.len() - cur.pos) as u64;
        // the body plus its trailing 16-byte hash must fit exactly
        if body_len.checked_add(16) != Some(remaining) {
            return Err(format!(
                "body length {body_len} inconsistent with file size \
                 ({remaining} bytes after header)"));
        }
        let body = cur.take(body_len as usize, "body")?;
        let stored = cur.take(16, "body hash")?;
        let computed = ChunkId::of(body);
        if stored != computed.0 {
            return Err(format!(
                "manifest body hash mismatch (stored {}, computed {computed})",
                hex16(stored)));
        }

        let mut bc = Cursor { bytes: body, pos: 0 };
        let n = u32::from_le_bytes(
            bc.take(4, "record count")?.try_into().expect("4 bytes")) as usize;
        if n > MAX_RECORDS {
            return Err(format!("record count {n} exceeds cap {MAX_RECORDS}"));
        }
        // each record is at least 1 + 2 + 0 + 16 + 8 = 27 bytes: bound the
        // allocation by what the body could actually hold
        if n > (body.len().saturating_sub(4)) / 27 + 1 {
            return Err(format!(
                "record count {n} impossible for a {}-byte body", body.len()));
        }
        let mut records = Vec::with_capacity(n);
        let mut labels: BTreeSet<String> = BTreeSet::new();
        for i in 0..n {
            let class_code = bc.take(1, "record class")?[0];
            let class = ChunkClass::from_code(class_code).ok_or_else(|| {
                format!("record {i}: unknown chunk class {class_code}")
            })?;
            let label_len = u16::from_le_bytes(
                bc.take(2, "label length")?.try_into().expect("2 bytes"))
                as usize;
            if label_len > MAX_LABEL_LEN {
                return Err(format!(
                    "record {i}: label length {label_len} exceeds cap \
                     {MAX_LABEL_LEN}"));
            }
            let label_bytes = bc.take(label_len, "label")?;
            let label = std::str::from_utf8(label_bytes)
                .map_err(|e| format!("record {i}: label not UTF-8: {e}"))?
                .to_string();
            let id_bytes: [u8; 16] = bc.take(16, "chunk id")?
                .try_into().expect("16 bytes");
            let len = u64::from_le_bytes(
                bc.take(8, "chunk length")?.try_into().expect("8 bytes"));
            if !labels.insert(label.clone()) {
                return Err(format!(
                    "record {i}: duplicate chunk label `{label}`"));
            }
            records.push(ChunkRecord { class, label, id: ChunkId(id_bytes),
                                       len });
        }
        if bc.pos != body.len() {
            return Err(format!(
                "{} trailing bytes after record {n} in manifest body",
                body.len() - bc.pos));
        }
        Ok(ArtifactManifest { records })
    }
}

fn hex16(b: &[u8]) -> String {
    b.iter().map(|x| format!("{x:02x}")).collect()
}

/// Checked byte cursor: every read is bounds-tested, so truncated or lying
/// inputs produce errors instead of panics.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            format!("{what}: length overflow at byte {}", self.pos)
        })?;
        if end > self.bytes.len() {
            return Err(format!(
                "truncated manifest: {what} needs {n} bytes at offset {} \
                 but only {} remain",
                self.pos, self.bytes.len() - self.pos));
        }
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ArtifactManifest {
        ArtifactManifest {
            records: vec![
                ChunkRecord { class: ChunkClass::Meta, label: "meta".into(),
                              id: ChunkId::of(b"{}"), len: 2 },
                ChunkRecord { class: ChunkClass::Param,
                              label: "param:embed".into(),
                              id: ChunkId::of(b"embed-bytes"), len: 11 },
                ChunkRecord { class: ChunkClass::FactorU,
                              label: "u:layers.0.wq".into(),
                              id: ChunkId::of(b"u-bytes"), len: 7 },
                ChunkRecord { class: ChunkClass::FactorV,
                              label: "v:layers.0.wq".into(),
                              id: ChunkId::of(b"v-bytes"), len: 7 },
            ],
        }
    }

    #[test]
    fn roundtrip_is_byte_identical() {
        let m = sample();
        let enc = m.encode();
        let dec = ArtifactManifest::decode(&enc).expect("decode");
        assert_eq!(dec, m);
        assert_eq!(dec.encode(), enc, "re-encode must be byte-identical");
        assert_eq!(m.meta().expect("meta").label, "meta");
        assert_eq!(m.record("u:layers.0.wq").expect("u").len, 7);
        assert!(m.record("missing").is_none());
    }

    #[test]
    fn empty_manifest_roundtrips() {
        let m = ArtifactManifest::default();
        let dec = ArtifactManifest::decode(&m.encode()).expect("decode");
        assert_eq!(dec, m);
        assert!(dec.meta().is_err());
    }

    #[test]
    fn every_truncation_errors() {
        let enc = sample().encode();
        for cut in 0..enc.len() {
            assert!(ArtifactManifest::decode(&enc[..cut]).is_err(),
                    "truncation to {cut} bytes decoded");
        }
    }

    #[test]
    fn every_single_byte_flip_errors() {
        let enc = sample().encode();
        for pos in 0..enc.len() {
            let mut bad = enc.clone();
            bad[pos] ^= 0x01;
            assert!(ArtifactManifest::decode(&bad).is_err(),
                    "flip at byte {pos} decoded");
        }
    }

    #[test]
    fn trailing_garbage_errors() {
        let mut enc = sample().encode();
        enc.push(0);
        assert!(ArtifactManifest::decode(&enc).is_err());
    }

    #[test]
    fn duplicate_labels_rejected() {
        let mut m = sample();
        let dup = m.records[1].clone();
        m.records.push(dup);
        let err = ArtifactManifest::decode(&m.encode()).unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        assert!(err.contains("param:embed"), "{err}");
    }

    #[test]
    fn hostile_record_count_is_bounded() {
        // a tiny body claiming u32::MAX records must fail the plausibility
        // check, not allocate
        let mut body = Vec::new();
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        let digest = ChunkId::of(&body);
        let mut enc = Vec::new();
        enc.extend_from_slice(MAGIC);
        enc.extend_from_slice(&VERSION.to_le_bytes());
        enc.extend_from_slice(&(body.len() as u64).to_le_bytes());
        enc.extend_from_slice(&body);
        enc.extend_from_slice(&digest.0);
        let err = ArtifactManifest::decode(&enc).unwrap_err();
        assert!(err.contains("impossible") || err.contains("cap"), "{err}");
    }
}
