//! Packing a complete serving state (weights + engine + optional drafter)
//! into a chunked artifact, and loading one back with full verification.
//!
//! A bundle holds everything a server needs to come up **without any
//! training or compression work**: the full applied [`ParamStore`], the
//! [`Engine`] (dense, or low-rank factors), and optionally a speculative
//! drafter's factors.  Tensors are stored as raw little-endian f32 chunks —
//! an exact bit round-trip — so a process started on an installed artifact
//! produces logits bit-identical to the process that packed it, which is
//! what the hot-swap gate in `rust/tests/server_loopback.rs` relies on.
//!
//! Chunk labels are structured: `meta`, `param:<name>`, `u:<target>` /
//! `v:<target>` for engine factors, and `du:<target>` / `dv:<target>` for
//! drafter factors.  The labels are what corruption errors name.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Result;

use super::hash::ChunkId;
use super::manifest::{ArtifactManifest, ChunkClass, ChunkRecord};
use super::store::{read_manifest_file, ChunkStore};
use crate::model::manifest::ConfigMeta;
use crate::model::ParamStore;
use crate::serve::Engine;
use crate::tensor::{Mat, Tensor};
use crate::util::json::{self, Json};

/// Bundle meta format marker (the `format` field of the meta chunk).
pub const META_FORMAT: &str = "zs-artifact";

/// Bundle meta format version.
pub const META_VERSION: usize = 1;

/// Chunk label of a full parameter tensor.
pub fn param_label(name: &str) -> String {
    format!("param:{name}")
}

/// Chunk label of an engine U factor (`drafter = true` for the drafter's).
pub fn u_label(target: &str, drafter: bool) -> String {
    if drafter { format!("du:{target}") } else { format!("u:{target}") }
}

/// Chunk label of an engine V factor (`drafter = true` for the drafter's).
pub fn v_label(target: &str, drafter: bool) -> String {
    if drafter { format!("dv:{target}") } else { format!("v:{target}") }
}

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(label: &str, bytes: &[u8], want: usize) -> Result<Vec<f32>> {
    anyhow::ensure!(bytes.len() == want * 4,
                    "chunk `{label}`: payload is {} bytes, meta shape needs \
                     {} ({want} f32 values)", bytes.len(), want * 4);
    Ok(bytes.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn factor_table(factors: &BTreeMap<String, (Mat, Mat)>) -> Json {
    Json::arr(factors.iter().map(|(target, (u, v))| {
        Json::obj(vec![
            ("target", Json::str(target)),
            ("rank", Json::num(u.cols as f64)),
            ("m", Json::num(u.rows as f64)),
            ("n", Json::num(v.cols as f64)),
        ])
    }))
}

fn engine_meta(engine: &Engine) -> Json {
    match engine {
        Engine::Dense => Json::obj(vec![("kind", Json::str("dense"))]),
        Engine::Lowrank { tag, factors } => Json::obj(vec![
            ("kind", Json::str("lowrank")),
            ("tag", Json::str(tag)),
            ("factors", factor_table(factors)),
        ]),
    }
}

fn put_factors(store: &ChunkStore, records: &mut Vec<ChunkRecord>,
               factors: &BTreeMap<String, (Mat, Mat)>, drafter: bool)
               -> Result<()> {
    for (target, (u, v)) in factors {
        for (mat, class, label) in [
            (u, ChunkClass::FactorU, u_label(target, drafter)),
            (v, ChunkClass::FactorV, v_label(target, drafter)),
        ] {
            let bytes = f32s_to_bytes(&mat.data);
            let id = store.put(&bytes)?;
            records.push(ChunkRecord { class, label, id,
                                       len: bytes.len() as u64 });
        }
    }
    Ok(())
}

/// Pack `params` + `engine` (+ optional `drafter`) for model `cfg` into the
/// store rooted at `store_root`, committing the manifest as
/// `<name>.zsar`.  Returns the manifest path.  Identical tensors — e.g.
/// factors shared with an artifact packed earlier into the same store —
/// deduplicate to a single chunk via content addressing.
pub fn pack(cfg: &ConfigMeta, params: &ParamStore, engine: &Engine,
            drafter: Option<&Engine>, store_root: &Path, name: &str)
            -> Result<PathBuf> {
    if let Some(d) = drafter {
        anyhow::ensure!(matches!(d, Engine::Lowrank { .. }),
                        "a speculative drafter must be a low-rank engine");
    }
    let store = ChunkStore::open(store_root)?;
    let mut records = Vec::new();

    let mut meta_pairs = vec![
        ("format", Json::str(META_FORMAT)),
        ("version", Json::num(META_VERSION as f64)),
        ("model", Json::str(&cfg.name)),
        ("arch", Json::str(&cfg.arch)),
        ("vocab", Json::num(cfg.vocab as f64)),
        ("seq_len", Json::num(cfg.seq_len as f64)),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("engine", engine_meta(engine)),
        ("params", Json::arr(params.names().iter().map(|n| {
            let t = params.get(n);
            Json::obj(vec![
                ("name", Json::str(n)),
                ("shape", Json::arr(t.shape.iter()
                    .map(|&d| Json::num(d as f64)))),
            ])
        }))),
    ];
    if let Some(d) = drafter {
        meta_pairs.push(("drafter", engine_meta(d)));
    }
    let meta_bytes = Json::obj(meta_pairs).to_string().into_bytes();
    let meta_id = store.put(&meta_bytes)?;
    records.push(ChunkRecord { class: ChunkClass::Meta, label: "meta".into(),
                               id: meta_id, len: meta_bytes.len() as u64 });

    for n in params.names() {
        let bytes = f32s_to_bytes(&params.get(n).data);
        let id = store.put(&bytes)?;
        records.push(ChunkRecord { class: ChunkClass::Param,
                                   label: param_label(n), id,
                                   len: bytes.len() as u64 });
    }
    if let Engine::Lowrank { factors, .. } = engine {
        put_factors(&store, &mut records, factors, false)?;
    }
    if let Some(Engine::Lowrank { factors, .. }) = drafter {
        put_factors(&store, &mut records, factors, true)?;
    }

    store.write_manifest(name, &ArtifactManifest { records })
}

/// A fully verified, fully materialized artifact: everything the engine
/// needs to serve, plus the model identity to validate against a session.
pub struct LoadedBundle {
    /// Model config name the artifact was packed for ("tiny", ...).
    pub model: String,
    /// Architecture family recorded at pack time.
    pub arch: String,
    /// Vocabulary size recorded at pack time.
    pub vocab: usize,
    /// Sequence length recorded at pack time.
    pub seq_len: usize,
    /// The complete parameter store.
    pub params: ParamStore,
    /// The serving engine (dense or low-rank factors).
    pub engine: Engine,
    /// Optional speculative drafter engine.
    pub drafter: Option<Engine>,
}

fn chunk_of<'m>(m: &'m ArtifactManifest, label: &str, class: ChunkClass)
                -> Result<&'m ChunkRecord> {
    let rec = m.record(label).ok_or_else(|| anyhow::anyhow!(
        "meta references chunk `{label}` but the manifest has no such \
         record (dangling chunk label)"))?;
    anyhow::ensure!(rec.class == class,
                    "chunk `{label}` has class {:?}, meta expects {class:?}",
                    rec.class);
    Ok(rec)
}

fn load_engine(store: &ChunkStore, m: &ArtifactManifest, meta: &Json,
               drafter: bool) -> Result<Engine> {
    let kind = meta.str_or("kind", "");
    match kind.as_str() {
        "dense" => Ok(Engine::Dense),
        "lowrank" => {
            let tag = meta.get("tag").and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!(
                    "lowrank engine meta missing `tag`"))?
                .to_string();
            let table = meta.get("factors").and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!(
                    "lowrank engine meta missing `factors` table"))?;
            let mut factors = BTreeMap::new();
            for f in table {
                let target = f.get("target").and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!(
                        "factor entry missing `target`"))?
                    .to_string();
                let rank = f.usize_or("rank", 0);
                let rows = f.usize_or("m", 0);
                let cols = f.usize_or("n", 0);
                anyhow::ensure!(rank > 0 && rows > 0 && cols > 0,
                                "factor `{target}`: bad shape \
                                 ({rows} x {rank} x {cols})");
                let ul = u_label(&target, drafter);
                let urec = chunk_of(m, &ul, ChunkClass::FactorU)?;
                let u = Mat::from_vec(rows, rank, bytes_to_f32s(
                    &ul, &store.get_verified(urec)?, rows * rank)?);
                let vl = v_label(&target, drafter);
                let vrec = chunk_of(m, &vl, ChunkClass::FactorV)?;
                let v = Mat::from_vec(rank, cols, bytes_to_f32s(
                    &vl, &store.get_verified(vrec)?, rank * cols)?);
                factors.insert(target, (u, v));
            }
            Ok(Engine::Lowrank { tag, factors })
        }
        other => anyhow::bail!("unknown engine kind `{other}` in meta"),
    }
}

/// Load and **fully verify** the artifact at `manifest_path`: the manifest
/// structure and checksum, then every referenced chunk's length and content
/// hash, then the tensor shapes against the meta tables.  Any corruption —
/// a flipped bit, a truncated or missing chunk file, a dangling label —
/// fails here with an error naming the chunk, before anything is served.
pub fn load(manifest_path: &Path) -> Result<LoadedBundle> {
    let m = read_manifest_file(manifest_path)?;
    let root = manifest_path.parent().ok_or_else(|| anyhow::anyhow!(
        "{} has no parent", manifest_path.display()))?;
    let store = ChunkStore::open(root)?;

    let meta_rec = m.meta().map_err(|e| anyhow::anyhow!(
        "manifest {}: {e}", manifest_path.display()))?;
    let meta_bytes = store.get_verified(meta_rec)?;
    let meta_text = std::str::from_utf8(&meta_bytes)
        .map_err(|e| anyhow::anyhow!("chunk `meta` is not UTF-8: {e}"))?;
    let meta = json::parse(meta_text)
        .map_err(|e| anyhow::anyhow!("chunk `meta` is not valid JSON: {e}"))?;
    let format = meta.str_or("format", "");
    anyhow::ensure!(format == META_FORMAT,
                    "meta format `{format}` is not `{META_FORMAT}`");
    let version = meta.usize_or("version", 0);
    anyhow::ensure!(version == META_VERSION,
                    "meta version {version} unsupported (want {META_VERSION})");

    let param_table = meta.get("params").and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("meta missing `params` table"))?;
    let mut names = Vec::with_capacity(param_table.len());
    let mut tensors = Vec::with_capacity(param_table.len());
    for p in param_table {
        let name = p.get("name").and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("param entry missing `name`"))?
            .to_string();
        let shape = p.get("shape").and_then(Json::as_shape)
            .ok_or_else(|| anyhow::anyhow!(
                "param `{name}` missing `shape`"))?;
        let count: usize = shape.iter().product();
        let label = param_label(&name);
        let rec = chunk_of(&m, &label, ChunkClass::Param)?;
        let data = bytes_to_f32s(&label, &store.get_verified(rec)?, count)?;
        tensors.push((name.clone(), Tensor::from_vec(&shape, data)));
        names.push(name);
    }
    let mut params = ParamStore::new_empty(names);
    for (name, t) in tensors {
        params.set(&name, t);
    }

    let engine_doc = meta.get("engine")
        .ok_or_else(|| anyhow::anyhow!("meta missing `engine`"))?;
    let engine = load_engine(&store, &m, engine_doc, false)?;
    let drafter = match meta.get("drafter") {
        Some(d) => Some(load_engine(&store, &m, d, true)?),
        None => None,
    };

    Ok(LoadedBundle {
        model: meta.str_or("model", ""),
        arch: meta.str_or("arch", ""),
        vocab: meta.usize_or("vocab", 0),
        seq_len: meta.usize_or("seq_len", 0),
        params,
        engine,
        drafter,
    })
}

fn check_lowrank(cfg: &ConfigMeta, engine: &Engine, what: &str)
                 -> Result<()> {
    let Engine::Lowrank { tag, factors } = engine else { return Ok(()) };
    let lm = cfg.lowrank.get(tag).ok_or_else(|| anyhow::anyhow!(
        "{what} tag `{tag}` has no low-rank graph in model `{}`", cfg.name))?;
    for t in &cfg.targets {
        let (m, n) = t.shape;
        let k = lm.ranks[&t.name];
        let (u, v) = factors.get(&t.name).ok_or_else(|| anyhow::anyhow!(
            "{what}: artifact has no factors for target `{}`", t.name))?;
        anyhow::ensure!(
            (u.rows, u.cols, v.rows, v.cols) == (m, k, k, n),
            "{what}: factor shapes for `{}` are ({} x {}, {} x {}), model \
             graph `{tag}` needs ({m} x {k}, {k} x {n})",
            t.name, u.rows, u.cols, v.rows, v.cols);
    }
    anyhow::ensure!(factors.len() == cfg.targets.len(),
                    "{what}: artifact factors {} targets, model has {}",
                    factors.len(), cfg.targets.len());
    Ok(())
}

impl LoadedBundle {
    /// Validate this bundle against a live session's model config: identity
    /// (name / arch / vocab / seq_len), the full parameter spec, and — for
    /// low-rank engines — that the tag's fixed-rank graph exists and every
    /// factor matches its baked shape.  A bundle that passes can be swapped
    /// in without any further shape risk.
    pub fn validate_against(&self, cfg: &ConfigMeta) -> Result<()> {
        anyhow::ensure!(self.model == cfg.name,
                        "artifact is for model `{}`, server runs `{}`",
                        self.model, cfg.name);
        anyhow::ensure!(self.arch == cfg.arch,
                        "artifact arch `{}` != model arch `{}`",
                        self.arch, cfg.arch);
        anyhow::ensure!(self.vocab == cfg.vocab,
                        "artifact vocab {} != model vocab {}",
                        self.vocab, cfg.vocab);
        anyhow::ensure!(self.seq_len == cfg.seq_len,
                        "artifact seq_len {} != model seq_len {}",
                        self.seq_len, cfg.seq_len);
        self.params.check_matches(cfg)?;
        check_lowrank(cfg, &self.engine, "engine")?;
        if let Some(d) = &self.drafter {
            anyhow::ensure!(matches!(d, Engine::Lowrank { .. }),
                            "drafter engine must be low-rank");
            check_lowrank(cfg, d, "drafter")?;
        }
        Ok(())
    }
}

/// Pretty one-line description for logs: engine label plus drafter tag.
pub fn describe(b: &LoadedBundle) -> String {
    match &b.drafter {
        Some(d) => format!("{} (drafter {})", b.engine.label(), d.label()),
        None => b.engine.label(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp_root(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("zs_bundle_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn tiny_cfg() -> ConfigMeta {
        crate::model::manifest::Manifest::builtin().config("tiny").clone()
    }

    fn synth_state(cfg: &ConfigMeta, tag: &str)
                   -> (ParamStore, Engine, Engine) {
        let mut rng = Rng::new(0xA2);
        let params = crate::model::init::init_params(cfg, &mut rng);
        let lm = &cfg.lowrank[tag];
        let factors: BTreeMap<String, (Mat, Mat)> = cfg.targets.iter()
            .map(|t| {
                let (m, n) = t.shape;
                let k = lm.ranks[&t.name];
                (t.name.clone(),
                 (Mat::randn(&mut rng, m, k, 0.05),
                  Mat::randn(&mut rng, k, n, 0.05)))
            })
            .collect();
        let engine = Engine::Lowrank { tag: tag.into(),
                                       factors: factors.clone() };
        let drafter = Engine::Lowrank { tag: tag.into(), factors };
        (params, engine, drafter)
    }

    #[test]
    fn pack_load_bitmatch_with_drafter() {
        let cfg = tiny_cfg();
        let tag = cfg.lowrank.keys().next().expect("a tag").clone();
        let (params, engine, drafter) = synth_state(&cfg, &tag);
        let root = tmp_root("roundtrip");
        let path = pack(&cfg, &params, &engine, Some(&drafter), &root, "art")
            .expect("pack");
        let b = load(&path).expect("load");
        b.validate_against(&cfg).expect("validate");
        assert_eq!(b.model, cfg.name);
        assert_eq!(b.params.names(), params.names());
        for n in params.names() {
            assert_eq!(b.params.get(n), params.get(n), "param {n}");
        }
        let (Engine::Lowrank { factors: fa, .. },
             Engine::Lowrank { factors: fb, .. }) = (&engine, &b.engine)
        else { panic!("lowrank engines") };
        assert_eq!(fa, fb);
        assert!(b.drafter.is_some());
        assert!(describe(&b).contains("drafter"));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn shared_factors_dedup_across_artifacts() {
        let cfg = tiny_cfg();
        let tag = cfg.lowrank.keys().next().expect("a tag").clone();
        let (params, engine, _) = synth_state(&cfg, &tag);
        let root = tmp_root("dedup");
        pack(&cfg, &params, &engine, None, &root, "a").expect("pack a");
        let chunks_after_a = std::fs::read_dir(root.join("chunks"))
            .expect("dir").count();
        // same tensors under a second name: zero new chunks
        pack(&cfg, &params, &engine, None, &root, "b").expect("pack b");
        let chunks_after_b = std::fs::read_dir(root.join("chunks"))
            .expect("dir").count();
        assert_eq!(chunks_after_a, chunks_after_b,
                   "identical payloads must deduplicate");
        let a = load(&root.join("a.zsar")).expect("load a");
        let b = load(&root.join("b.zsar")).expect("load b");
        assert_eq!(a.params.get("embed"), b.params.get("embed"));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn wrong_model_rejected_by_validation() {
        let cfg = tiny_cfg();
        let (params, engine, _) = {
            let tag = cfg.lowrank.keys().next().expect("a tag").clone();
            synth_state(&cfg, &tag)
        };
        let root = tmp_root("wrongmodel");
        let path = pack(&cfg, &params, &engine, None, &root, "art")
            .expect("pack");
        let b = load(&path).expect("load");
        let mut other = cfg.clone();
        other.name = "not-tiny".into();
        let err = b.validate_against(&other).unwrap_err().to_string();
        assert!(err.contains("not-tiny"), "{err}");
        std::fs::remove_dir_all(&root).ok();
    }
}
