//! 128-bit content hash for chunk addressing and integrity.
//!
//! Two independent FNV-1a-64 lanes (different offset bases) over the same
//! bytes, each finished with a splitmix64-style avalanche and cross-mixed
//! with the input length.  This is an *integrity and dedup* hash — fast,
//! dependency-free, with a 128-bit space that makes accidental collisions
//! between distinct tensors astronomically unlikely — **not** a
//! cryptographic hash: it does not resist an adversary crafting collisions
//! on purpose.  The artifact store uses it to detect corruption (bit flips,
//! truncation, mixed-up files) and to deduplicate identical chunks, which
//! is exactly what it is good for.

use std::fmt;

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
const FNV_OFFSET_A: u64 = 0xcbf2_9ce4_8422_2325;
// second lane: the standard offset xored with the splitmix64 increment so
// the two lanes never start equal and diverge from the first byte on
const FNV_OFFSET_B: u64 = FNV_OFFSET_A ^ 0x9e37_79b9_7f4a_7c15;

/// splitmix64 finalizer: full-avalanche 64-bit mix.
fn mix64(mut z: u64) -> u64 {
    z ^= z >> 30;
    z = z.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Identity of one chunk: the 128-bit content hash of its payload bytes.
/// Doubles as the chunk's file name (32 lowercase hex chars) in a
/// [`ChunkStore`](super::store::ChunkStore).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ChunkId(pub [u8; 16]);

impl ChunkId {
    /// Hash a payload into its chunk identity.
    pub fn of(bytes: &[u8]) -> ChunkId {
        let mut a = FNV_OFFSET_A;
        let mut b = FNV_OFFSET_B;
        for &byte in bytes {
            a = (a ^ u64::from(byte)).wrapping_mul(FNV_PRIME);
            b = (b ^ u64::from(byte)).wrapping_mul(FNV_PRIME);
        }
        // cross-mix the lanes and fold in the length so a truncated payload
        // whose running state happens to match still changes the id
        let len = bytes.len() as u64;
        let lo = mix64(a ^ b.rotate_left(32) ^ len);
        let hi = mix64(b ^ a.rotate_left(17) ^ len.wrapping_mul(FNV_PRIME));
        let mut out = [0u8; 16];
        out[..8].copy_from_slice(&lo.to_le_bytes());
        out[8..].copy_from_slice(&hi.to_le_bytes());
        ChunkId(out)
    }

    /// 32-char lowercase hex rendering (the on-disk chunk file stem).
    pub fn hex(&self) -> String {
        let mut s = String::with_capacity(32);
        for b in self.0 {
            s.push_str(&format!("{b:02x}"));
        }
        s
    }

    /// Parse the [`hex`](ChunkId::hex) rendering back; `None` on anything
    /// that is not exactly 32 hex chars.
    pub fn from_hex(s: &str) -> Option<ChunkId> {
        if s.len() != 32 || !s.is_ascii() {
            return None;
        }
        let bytes = s.as_bytes();
        let mut out = [0u8; 16];
        for (i, slot) in out.iter_mut().enumerate() {
            let pair = std::str::from_utf8(&bytes[2 * i..2 * i + 2]).ok()?;
            *slot = u8::from_str_radix(pair, 16).ok()?;
        }
        Some(ChunkId(out))
    }
}

impl fmt::Display for ChunkId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.hex())
    }
}

impl fmt::Debug for ChunkId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ChunkId({})", self.hex())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_content_sensitive() {
        let a = ChunkId::of(b"hello world");
        assert_eq!(a, ChunkId::of(b"hello world"));
        assert_ne!(a, ChunkId::of(b"hello worlc"));
        assert_ne!(a, ChunkId::of(b"hello worl"));
        assert_ne!(ChunkId::of(b""), ChunkId::of(b"\0"));
    }

    #[test]
    fn single_bit_flips_change_the_id() {
        let base: Vec<u8> = (0..257u32).map(|i| (i % 251) as u8).collect();
        let id = ChunkId::of(&base);
        for pos in [0usize, 1, 128, 255, 256] {
            for bit in 0..8 {
                let mut mutated = base.clone();
                mutated[pos] ^= 1 << bit;
                assert_ne!(id, ChunkId::of(&mutated),
                           "flip at byte {pos} bit {bit} went undetected");
            }
        }
    }

    #[test]
    fn hex_roundtrip() {
        let id = ChunkId::of(b"roundtrip me");
        let h = id.hex();
        assert_eq!(h.len(), 32);
        assert!(h.chars().all(|c| c.is_ascii_hexdigit()));
        assert_eq!(ChunkId::from_hex(&h), Some(id));
        assert_eq!(ChunkId::from_hex("zz"), None);
        assert_eq!(ChunkId::from_hex(&h[..30]), None);
        let upper = h.to_uppercase();
        // parser is case-tolerant (from_str_radix accepts both)
        assert_eq!(ChunkId::from_hex(&upper), Some(id));
    }

    #[test]
    fn length_extension_of_zeros_changes_the_id() {
        // all-zero payloads of different lengths keep the FNV state moving
        // only via the multiply; the length fold must still separate them
        let mut prev = ChunkId::of(b"");
        for n in 1..64usize {
            let cur = ChunkId::of(&vec![0u8; n]);
            assert_ne!(cur, prev, "zero-run length {n}");
            prev = cur;
        }
    }
}
