//! On-disk chunk store + atomic, resumable artifact install.
//!
//! Layout under a store root:
//!
//! ```text
//! <root>/<name>.zsar        manifest (the commit point — see below)
//! <root>/chunks/<hex32>     chunk payloads, named by content hash
//! ```
//!
//! Chunks are content-addressed, so identical payloads (e.g. a U factor
//! shared by two compression ratios) are stored exactly once and several
//! manifests in one root share them freely.  Every write is
//! temp-file + atomic rename, and a manifest is only written after every
//! chunk it references has been verified on disk — so a crash at any point
//! leaves either the previous state or the complete new artifact, never a
//! partially-visible one.  [`install`] re-verifies every chunk at the
//! destination and skips chunks that already verify, which makes an
//! interrupted install resumable: re-running it completes the copy and the
//! result is byte-identical to a never-interrupted one.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use super::hash::ChunkId;
use super::manifest::{ArtifactManifest, ChunkRecord};

/// Name of the chunk subdirectory inside a store root.
pub const CHUNK_DIR: &str = "chunks";

/// File extension of artifact manifests.
pub const MANIFEST_EXT: &str = "zsar";

/// A directory of content-addressed chunks plus the manifests that
/// reference them.
pub struct ChunkStore {
    root: PathBuf,
}

/// Unique-enough temp-file suffix: pid + a process-wide counter, so
/// concurrent writers in one process never collide and stale temp files
/// from a crashed process are simply overwritten or ignored.
fn tmp_name(stem: &str) -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    format!(".tmp-{stem}-{}-{}", std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed))
}

/// Write `bytes` to `path` atomically: temp file in the same directory,
/// then rename over the final name.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent()
        .ok_or_else(|| anyhow::anyhow!("{} has no parent", path.display()))?;
    let stem = path.file_name().and_then(|n| n.to_str()).unwrap_or("chunk");
    let tmp = dir.join(tmp_name(stem));
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(),
                                 path.display()))?;
    Ok(())
}

impl ChunkStore {
    /// Open (creating directories as needed) the store rooted at `root`.
    pub fn open(root: &Path) -> Result<ChunkStore> {
        std::fs::create_dir_all(root.join(CHUNK_DIR))
            .with_context(|| format!("create store {}", root.display()))?;
        Ok(ChunkStore { root: root.to_path_buf() })
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// On-disk path of a chunk id.
    pub fn chunk_path(&self, id: &ChunkId) -> PathBuf {
        self.root.join(CHUNK_DIR).join(id.hex())
    }

    /// Path of the named manifest inside this store.
    pub fn manifest_path(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.{MANIFEST_EXT}"))
    }

    /// Store a payload, returning its content id.  Deduplicating: if a
    /// *valid* chunk with this content already exists it is left untouched;
    /// an existing file that fails verification (e.g. torn by an earlier
    /// crash mid-rename on a filesystem without atomic rename, or corrupted
    /// at rest) is overwritten with the good bytes.
    pub fn put(&self, bytes: &[u8]) -> Result<ChunkId> {
        let id = ChunkId::of(bytes);
        let path = self.chunk_path(&id);
        if let Ok(existing) = std::fs::read(&path) {
            if existing == bytes {
                return Ok(id);
            }
        }
        write_atomic(&path, bytes)?;
        Ok(id)
    }

    /// Whether the chunk a record references exists here and verifies
    /// (length and content hash both match).
    pub fn has_valid(&self, rec: &ChunkRecord) -> bool {
        match std::fs::read(self.chunk_path(&rec.id)) {
            Ok(bytes) => bytes.len() as u64 == rec.len
                && ChunkId::of(&bytes) == rec.id,
            Err(_) => false,
        }
    }

    /// Read and fully verify one chunk.  Every failure names the chunk's
    /// manifest label so corruption reports point at the exact tensor.
    pub fn get_verified(&self, rec: &ChunkRecord) -> Result<Vec<u8>> {
        let path = self.chunk_path(&rec.id);
        let bytes = std::fs::read(&path).with_context(|| {
            format!("chunk `{}` ({}) unreadable at {}", rec.label, rec.id,
                    path.display())
        })?;
        anyhow::ensure!(
            bytes.len() as u64 == rec.len,
            "chunk `{}` corrupt: length {} != manifest length {}",
            rec.label, bytes.len(), rec.len);
        let actual = ChunkId::of(&bytes);
        anyhow::ensure!(
            actual == rec.id,
            "chunk `{}` corrupt: content hash {actual} != manifest id {}",
            rec.label, rec.id);
        Ok(bytes)
    }

    /// Write a manifest under `name` — the *commit point* of an artifact.
    /// Call only after every referenced chunk is verified present.
    pub fn write_manifest(&self, name: &str, m: &ArtifactManifest)
                          -> Result<PathBuf> {
        let path = self.manifest_path(name);
        write_atomic(&path, &m.encode())?;
        Ok(path)
    }

    /// Read and structurally validate the named manifest (format, record
    /// table, body checksum — chunk payloads are verified separately).
    pub fn read_manifest(&self, name: &str) -> Result<ArtifactManifest> {
        read_manifest_file(&self.manifest_path(name))
    }

    /// Verify every chunk a manifest references.  Returns the labels of
    /// chunks that failed, empty when the artifact is fully intact.
    pub fn verify_all(&self, m: &ArtifactManifest) -> Vec<String> {
        m.records.iter()
            .filter(|r| !self.has_valid(r))
            .map(|r| r.label.clone())
            .collect()
    }
}

/// Read and structurally validate a manifest file by path.
pub fn read_manifest_file(path: &Path) -> Result<ArtifactManifest> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read manifest {}", path.display()))?;
    ArtifactManifest::decode(&bytes)
        .map_err(|e| anyhow::anyhow!("manifest {}: {e}", path.display()))
}

/// Install the artifact described by `src_manifest` into the store rooted
/// at `dst_root` under `name`.
///
/// Every chunk is read from the source store (the manifest's directory) and
/// **verified against its manifest id and length before being committed**;
/// a chunk already present and valid at the destination is skipped, which
/// is both the dedup path (factors shared with an artifact installed
/// earlier) and the resume path (a previous install that died partway).
/// The destination manifest — the only thing that makes the artifact
/// visible — is written last, atomically, and only after a final
/// verification pass over every destination chunk.  On any error nothing
/// becomes visible: at worst some verified chunks remain in `chunks/`,
/// where a rerun will reuse them.
pub fn install(src_manifest: &Path, dst_root: &Path, name: &str)
               -> Result<PathBuf> {
    let manifest = read_manifest_file(src_manifest)?;
    let src_root = src_manifest.parent()
        .ok_or_else(|| anyhow::anyhow!("{} has no parent",
                                       src_manifest.display()))?;
    let src = ChunkStore::open(src_root)?;
    let dst = ChunkStore::open(dst_root)?;

    for rec in &manifest.records {
        if dst.has_valid(rec) {
            continue; // resumed or deduplicated — already verified on disk
        }
        let bytes = src.get_verified(rec)?;
        let written = dst.put(&bytes)?;
        // put() hashes the bytes it wrote; a disagreement here would mean
        // the source chunk verified under a different id than recorded
        anyhow::ensure!(written == rec.id,
                        "chunk `{}` changed identity during install",
                        rec.label);
    }

    // final gate before the commit point: every chunk must verify at the
    // destination (catches e.g. a chunk torn by a concurrent writer)
    let bad = dst.verify_all(&manifest);
    anyhow::ensure!(bad.is_empty(),
                    "install verification failed for chunk(s): {}",
                    bad.join(", "));
    dst.write_manifest(name, &manifest)
}

#[cfg(test)]
mod tests {
    use super::super::manifest::ChunkClass;
    use super::*;

    fn tmp_root(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("zs_artifact_store_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn rec(class: ChunkClass, label: &str, bytes: &[u8]) -> ChunkRecord {
        ChunkRecord { class, label: label.into(), id: ChunkId::of(bytes),
                      len: bytes.len() as u64 }
    }

    #[test]
    fn put_get_roundtrip_and_dedup() {
        let root = tmp_root("putget");
        let store = ChunkStore::open(&root).expect("open");
        let payload = b"some chunk payload".to_vec();
        let id = store.put(&payload).expect("put");
        let id2 = store.put(&payload).expect("put again");
        assert_eq!(id, id2);
        let r = rec(ChunkClass::Param, "param:x", &payload);
        assert!(store.has_valid(&r));
        assert_eq!(store.get_verified(&r).expect("get"), payload);
        // exactly one file in chunks/ — dedup stored it once
        let n = std::fs::read_dir(root.join(CHUNK_DIR)).expect("dir")
            .filter(|e| e.as_ref().map(|e| {
                !e.file_name().to_string_lossy().starts_with('.')
            }).unwrap_or(false))
            .count();
        assert_eq!(n, 1);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_chunk_named_in_error() {
        let root = tmp_root("corrupt");
        let store = ChunkStore::open(&root).expect("open");
        let payload = b"factor bytes".to_vec();
        let id = store.put(&payload).expect("put");
        let r = rec(ChunkClass::FactorU, "u:layers.0.wq", &payload);
        let mut bad = payload.clone();
        bad[3] ^= 0x40;
        std::fs::write(store.chunk_path(&id), &bad).expect("corrupt");
        assert!(!store.has_valid(&r));
        let err = store.get_verified(&r).unwrap_err().to_string();
        assert!(err.contains("u:layers.0.wq"), "{err}");
        assert!(err.contains("hash"), "{err}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn install_commits_atomically_and_resumes() {
        let src_root = tmp_root("inst_src");
        let dst_root = tmp_root("inst_dst");
        let src = ChunkStore::open(&src_root).expect("open src");
        let a = b"chunk a".to_vec();
        let b = vec![7u8; 1024];
        src.put(&a).expect("put a");
        src.put(&b).expect("put b");
        let manifest = ArtifactManifest { records: vec![
            rec(ChunkClass::Meta, "meta", &a),
            rec(ChunkClass::Param, "param:w", &b),
        ]};
        let src_path = src.write_manifest("art", &manifest).expect("commit");

        // pre-seed the destination with one valid chunk: the resume path
        let dst = ChunkStore::open(&dst_root).expect("open dst");
        dst.put(&a).expect("pre-seed");
        let installed = install(&src_path, &dst_root, "art").expect("install");
        assert_eq!(read_manifest_file(&installed).expect("reread"), manifest);
        assert!(dst.verify_all(&manifest).is_empty());
        // byte-identical manifests: resumed install == clean install
        assert_eq!(std::fs::read(&installed).expect("dst bytes"),
                   std::fs::read(&src_path).expect("src bytes"));

        // a missing source chunk fails the install and the *new* manifest
        // name never appears
        std::fs::remove_file(src.chunk_path(&manifest.records[1].id))
            .expect("delete");
        std::fs::remove_file(dst.chunk_path(&manifest.records[1].id))
            .expect("delete dst");
        let err = install(&src_path, &dst_root, "art2").unwrap_err()
            .to_string();
        assert!(err.contains("param:w"), "{err}");
        assert!(!dst.manifest_path("art2").exists(),
                "failed install must not publish a manifest");
        std::fs::remove_dir_all(&src_root).ok();
        std::fs::remove_dir_all(&dst_root).ok();
    }
}
