//! Content-addressed binary artifact store for compressed serving plans.
//!
//! The production scenario ZS-SVD enables is *recompress and redeploy under
//! traffic*: compression is cheap (global zero-sum selection over cached
//! SVDs), so a fleet realistically holds several artifacts of one model at
//! different ratios and swaps between them.  This module is the on-disk
//! half of that story; `crate::decode`'s [`EngineSlot`](crate::decode::EngineSlot)
//! / swap mailbox and the server's `reload` wire request are the live half.
//!
//! # Pieces
//!
//! * [`hash`] — the 128-bit content hash that names and verifies chunks.
//! * [`manifest`] — the `ZSAR` binary manifest: a length-prefixed,
//!   checksummed index of labeled chunk records.
//! * [`store`] — the chunk directory: dedup by content address, atomic
//!   writes, and a resumable [`install`](store::install) whose commit point
//!   (the manifest rename) only happens after every chunk verifies.
//! * [`bundle`] — packing a complete serving state (the full
//!   [`ParamStore`](crate::model::ParamStore), engine factors, optional
//!   drafter) into chunks and loading it back with full verification.
//!
//! # Integrity guarantees
//!
//! * Every chunk carries its byte length and 128-bit content hash in the
//!   manifest; the manifest body itself is checksummed and length-prefixed.
//! * Any single corrupted byte — in the manifest, a factor, a parameter, or
//!   the metadata — is detected at install or load time with an error
//!   naming the chunk label (`u:layers.0.wq`, `param:embed`, ...).
//! * Nothing is ever partially visible: chunks and manifests are written
//!   temp-file + atomic-rename, and the manifest (the only entry point) is
//!   written last.  An interrupted install resumes by skipping chunks that
//!   already verify at the destination and ends byte-identical to a clean
//!   one.
//! * Tensors round-trip bit-exactly (raw little-endian f32), so a server
//!   that hot-swaps an artifact in produces logits bit-identical to a fresh
//!   process started on that artifact — gated by
//!   `rust/tests/server_loopback.rs` and `rust/tests/artifact_store.rs`.

pub mod bundle;
pub mod hash;
pub mod manifest;
pub mod store;

pub use bundle::{load, pack, LoadedBundle};
pub use hash::ChunkId;
pub use manifest::{ArtifactManifest, ChunkClass, ChunkRecord};
pub use store::{install, ChunkStore};
