//! The fleet router: a supervised multi-process front-end that speaks the
//! exact single-server wire protocol on one listening address and fans
//! requests out across N worker processes.
//!
//! Thread anatomy (all plain `std::thread`, joined before [`run_fleet`]
//! returns):
//!
//! * **supervisor** ×N — boots its worker (spawn → port-file discovery →
//!   version handshake), then watches it: child exit, demux-reported
//!   stream trouble, and heartbeat staleness all tear the incarnation
//!   down, fail its in-flight requests with structured `worker_failed`
//!   errors, and respawn from the *same verified artifact* under bounded
//!   exponential back-off.
//! * **demux** ×N (one per live worker incarnation) — reads the worker's
//!   event stream, stamps heartbeat freshness, translates fleet-assigned
//!   request ids back to client ids, and pushes lines into the owning
//!   connection's paced outbox.
//! * **dispatcher** ×1 — pops the router-level FIFO and places each
//!   request on a healthy worker with spare depth (session affinity
//!   first, then least-loaded), inserting the route *before* the bytes go
//!   out so no reply can beat its bookkeeping.
//! * **per-connection reader/writer** — the reader parses requests and
//!   answers control traffic inline; the writer drains the paced outbox.
//!
//! Request ids are rewritten: the router assigns every admitted generate a
//! fleet-unique id on the worker wire and restores the client's id on the
//! way back, so concurrent connections can reuse ids freely (exactly like
//! the single server, where ids only need to be unique per connection).

use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::server::admission::{self, BoundedQueue, PopState, PushError};
use crate::server::metrics::Metrics;
use crate::server::protocol::{self, event_line, Event, GenerateReq, Request,
                              ERR_BAD_REQUEST, ERR_OVERLOADED,
                              ERR_RELOAD_FAILED, ERR_SHUTTING_DOWN,
                              ERR_WORKER_FAILED, PROTO_VERSION};
use crate::util::json::Json;

use super::flow::{ConnOutbox, PushOutcome};
use super::health::{self, BackoffPolicy};
use super::worker::{handshake, spawn_worker, WorkerShared, WorkerSpec};

/// Everything the router needs to run a fleet.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// listen address (e.g. `127.0.0.1:0`)
    pub addr: String,
    /// worker binary; empty = this process's own executable
    pub program: PathBuf,
    /// number of worker processes to supervise (≥ 1)
    pub workers: usize,
    /// artifact manifest per worker: one entry shared by all workers, or
    /// exactly `workers` entries for per-worker stores
    pub artifacts: Vec<String>,
    /// extra `serve` flags passed to every worker verbatim
    pub worker_args: Vec<String>,
    /// router-level admission FIFO depth (level 1 of two-level admission)
    pub router_depth: usize,
    /// per-worker in-flight cap (level 2); keep at or below each worker's
    /// own `--queue-depth` so workers never reject routed traffic
    pub worker_depth: usize,
    /// per-connection outbox cap, in wire lines
    pub outbox_lines: usize,
    /// how long a full outbox paces a producer before the connection is
    /// shed as a slow reader, ms
    pub write_stall_ms: u64,
    /// heartbeat ping interval per worker, ms
    pub heartbeat_ms: u64,
    /// silence + an unanswered ping for this long ⇒ the worker is hung, ms
    pub health_timeout_ms: u64,
    /// how long a booting worker may take to publish its port, ms
    pub boot_timeout_ms: u64,
    /// restart back-off for crash-looping workers
    pub restart: BackoffPolicy,
    /// a worker healthy this long resets its back-off counter, ms
    pub stable_ms: u64,
}

impl RouterConfig {
    /// Config with production defaults for `workers` workers booting
    /// `artifacts` (one shared path or one per worker) behind `addr`.
    pub fn new(addr: &str, workers: usize, artifacts: Vec<String>)
               -> RouterConfig {
        RouterConfig {
            addr: addr.to_string(),
            program: PathBuf::new(),
            workers,
            artifacts,
            worker_args: Vec::new(),
            router_depth: 128,
            worker_depth: 32,
            outbox_lines: 16_384,
            write_stall_ms: 30_000,
            heartbeat_ms: 250,
            health_timeout_ms: 3_000,
            boot_timeout_ms: 60_000,
            restart: BackoffPolicy::default(),
            stable_ms: 10_000,
        }
    }
}

/// What the fleet did over its lifetime; returned by [`run_fleet`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetStats {
    /// client connections accepted
    pub connections: u64,
    /// generate requests successfully placed on a worker
    pub requests_routed: u64,
    /// worker respawns after the initial boot
    pub worker_restarts: u64,
    /// worker failures detected (crash, hang, boot trouble)
    pub worker_failures: u64,
    /// connections shed for not reading their token stream
    pub slow_reader_closes: u64,
}

/// One admitted generate waiting for (or holding) a worker.
struct Job {
    fleet_id: u64,
    client_id: u64,
    conn: Arc<RouterConn>,
    req: GenerateReq,
}

/// An in-flight request: fleet id → where its replies go.
struct Route {
    conn: Arc<RouterConn>,
    client_id: u64,
    worker: usize,
    started: Instant,
}

/// Router-side connection state.
struct RouterConn {
    outbox: ConnOutbox,
    inflight: AtomicUsize,
    /// 1 + index of the last worker this connection's requests landed on
    /// (0 = none yet) — session affinity keeps a connection's prompts on
    /// one worker so its prefix cache stays warm
    affinity: AtomicUsize,
}

/// Edge wakeup channel for the dispatcher (and anything else napping on
/// fleet state): `notify` after any event that could unblock a dispatch —
/// capacity freed, worker healthy, work queued.
struct Notify {
    seq: Mutex<u64>,
    cv: Condvar,
}

impl Notify {
    fn new() -> Notify {
        Notify { seq: Mutex::new(0), cv: Condvar::new() }
    }
    fn notify(&self) {
        let mut g = self.seq.lock().unwrap_or_else(|e| e.into_inner());
        *g = g.wrapping_add(1);
        self.cv.notify_all();
    }
    fn wait_timeout(&self, d: Duration) {
        let g = self.seq.lock().unwrap_or_else(|e| e.into_inner());
        let _ = self.cv.wait_timeout(g, d);
    }
}

struct FleetShared {
    cfg: RouterConfig,
    fifo: BoundedQueue<Job>,
    workers: Vec<Arc<WorkerShared>>,
    routes: Mutex<HashMap<u64, Route>>,
    conns: Mutex<HashMap<u64, Arc<RouterConn>>>,
    next_fleet_id: AtomicU64,
    next_conn_id: AtomicU64,
    heartbeat_nonce: AtomicU64,
    shutdown: AtomicBool,
    workers_stop: AtomicBool,
    epoch: Instant,
    metrics: Metrics,
    wake: Notify,
}

fn now_ms(epoch: Instant) -> u64 {
    epoch.elapsed().as_millis() as u64
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn push_to_conn(sh: &FleetShared, conn: &RouterConn, line: String) {
    if conn.outbox.push(line) == PushOutcome::Shed {
        sh.metrics.inc("fleet.slow_reader_closes", 1);
    }
}

/// Run a supervised fleet: boot `cfg.workers` workers, serve the wire
/// protocol on `cfg.addr`, and keep serving through worker crashes until a
/// client sends `shutdown`.  `on_ready` fires once with the bound address
/// (port-file writing, test rendezvous).
///
/// Returns lifetime totals once the fleet has drained and every worker
/// process has been stopped.
pub fn run_fleet(cfg: RouterConfig, on_ready: impl FnOnce(SocketAddr))
                 -> io::Result<FleetStats> {
    if cfg.workers == 0 {
        return Err(io::Error::new(io::ErrorKind::InvalidInput,
                                  "fleet needs at least one worker"));
    }
    if cfg.artifacts.len() != 1 && cfg.artifacts.len() != cfg.workers {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("need 1 artifact or one per worker ({} workers, {} \
                     artifacts)", cfg.workers, cfg.artifacts.len())));
    }
    let program = if cfg.program.as_os_str().is_empty() {
        std::env::current_exe()?
    } else {
        cfg.program.clone()
    };

    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    let workers: Vec<Arc<WorkerShared>> = (0..cfg.workers)
        .map(|i| {
            let art = if cfg.artifacts.len() == 1 { &cfg.artifacts[0] }
                      else { &cfg.artifacts[i] };
            Arc::new(WorkerShared::new(i, art.clone()))
        })
        .collect();
    let router_depth = cfg.router_depth.max(1);
    let boot_timeout = Duration::from_millis(cfg.boot_timeout_ms.max(1));
    let worker_args = cfg.worker_args.clone();
    let sh = Arc::new(FleetShared {
        cfg,
        fifo: BoundedQueue::new(router_depth),
        workers,
        routes: Mutex::new(HashMap::new()),
        conns: Mutex::new(HashMap::new()),
        next_fleet_id: AtomicU64::new(1),
        next_conn_id: AtomicU64::new(1),
        heartbeat_nonce: AtomicU64::new(1),
        shutdown: AtomicBool::new(false),
        workers_stop: AtomicBool::new(false),
        epoch: Instant::now(),
        metrics: Metrics::new(),
        wake: Notify::new(),
    });

    let mut sup_handles = Vec::new();
    for w in &sh.workers {
        let spec = WorkerSpec {
            program: program.clone(),
            artifact: w.artifact.clone(),
            extra_args: worker_args.clone(),
            boot_timeout,
        };
        let (sh, w) = (Arc::clone(&sh), Arc::clone(w));
        sup_handles.push(thread::spawn(move || supervisor(sh, w, spec)));
    }
    let dispatcher_handle = {
        let sh = Arc::clone(&sh);
        thread::spawn(move || dispatcher(&sh))
    };

    on_ready(addr);

    let mut conn_handles = Vec::new();
    let mut drain_deadline: Option<Instant> = None;
    loop {
        match listener.accept() {
            Ok((stream, _)) if !sh.shutdown.load(Ordering::SeqCst) => {
                stream.set_nodelay(true).ok();
                let sh = Arc::clone(&sh);
                conn_handles.push(thread::spawn(move || {
                    handle_conn(&sh, stream);
                }));
            }
            Ok(_) => {} // shutting down: refuse by dropping the socket
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(25));
            }
            Err(_) => thread::sleep(Duration::from_millis(25)),
        }
        if sh.shutdown.load(Ordering::SeqCst) {
            let deadline = *drain_deadline.get_or_insert_with(|| {
                Instant::now() + Duration::from_secs(60)
            });
            let drained = sh.fifo.is_empty() && lock(&sh.routes).is_empty();
            if drained || Instant::now() > deadline {
                break;
            }
        }
    }

    // drained (or drain deadline): stop the workers, then the plumbing
    sh.workers_stop.store(true, Ordering::SeqCst);
    sh.wake.notify();
    for h in sup_handles {
        let _ = h.join();
    }
    let _ = dispatcher_handle.join();
    for c in lock(&sh.conns).values() {
        c.outbox.close();
    }
    for h in conn_handles {
        let _ = h.join();
    }

    Ok(FleetStats {
        connections: sh.metrics.counter("connections"),
        requests_routed: sh.metrics.counter("fleet.requests_routed"),
        worker_restarts: sh.metrics.counter("fleet.worker_restarts"),
        worker_failures: sh.metrics.counter("fleet.worker_failures"),
        slow_reader_closes: sh.metrics.counter("fleet.slow_reader_closes"),
    })
}

// ---------------------------------------------------------------- workers

/// Boot → watch → tear down → back off → respawn, forever, for one worker
/// slot.  Runs on its own thread until the fleet stops.
fn supervisor(sh: Arc<FleetShared>, w: Arc<WorkerShared>, spec: WorkerSpec) {
    let mut incarnation: u64 = 0;
    let mut consecutive_failures: u32 = 0;
    loop {
        if sh.workers_stop.load(Ordering::SeqCst) {
            return;
        }
        // bounded exponential back-off before a re-attempt, napped in
        // small slices so shutdown stays responsive
        let mut delay = sh.cfg.restart.delay_ms(consecutive_failures);
        while delay > 0 {
            if sh.workers_stop.load(Ordering::SeqCst) {
                return;
            }
            let slice = delay.min(50);
            thread::sleep(Duration::from_millis(slice));
            delay -= slice;
        }

        // boot: spawn, discover the port, handshake versions
        let boot = spawn_worker(&spec, w.index, incarnation)
            .and_then(|(mut child, addr)| {
                match handshake(addr, spec.boot_timeout) {
                    Ok((stream, engine)) => Ok((child, addr, stream, engine)),
                    Err(e) => {
                        let _ = child.kill();
                        let _ = child.wait();
                        Err(e)
                    }
                }
            });
        let (mut child, addr, stream, engine) = match boot {
            Ok(x) => x,
            Err(e) => {
                eprintln!("router: worker {} boot attempt failed: {e}",
                          w.index);
                w.failures.fetch_add(1, Ordering::SeqCst);
                sh.metrics.inc("fleet.worker_failures", 1);
                consecutive_failures = consecutive_failures.saturating_add(1);
                incarnation += 1;
                continue;
            }
        };
        let read_half = match stream.try_clone() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("router: worker {}: socket clone failed: {e}",
                          w.index);
                let _ = child.kill();
                let _ = child.wait();
                w.failures.fetch_add(1, Ordering::SeqCst);
                sh.metrics.inc("fleet.worker_failures", 1);
                consecutive_failures = consecutive_failures.saturating_add(1);
                incarnation += 1;
                continue;
            }
        };

        // install the incarnation and open it for traffic
        *lock(&w.addr) = Some(addr);
        *lock(&w.engine) = engine;
        *lock(&w.writer) = Some(stream);
        w.pid.store(child.id() as u64, Ordering::SeqCst);
        w.last_recv_ms.store(now_ms(sh.epoch), Ordering::SeqCst);
        w.pings_outstanding.store(0, Ordering::SeqCst);
        w.suspect.store(false, Ordering::SeqCst);
        w.healthy.store(true, Ordering::SeqCst);
        if incarnation > 0 {
            w.restarts.fetch_add(1, Ordering::SeqCst);
            sh.metrics.inc("fleet.worker_restarts", 1);
        }
        sh.wake.notify();
        eprintln!("router: worker {} up (pid {}, {addr}, incarnation {})",
                  w.index, child.id(), incarnation);

        let demux_handle = {
            let (sh, w) = (Arc::clone(&sh), Arc::clone(&w));
            thread::spawn(move || demux(&sh, &w, read_half))
        };

        // watch the incarnation until it dies or the fleet stops
        let healthy_since = Instant::now();
        let mut last_ping = Instant::now();
        let mut graceful = false;
        loop {
            if sh.workers_stop.load(Ordering::SeqCst) {
                graceful = true;
                break;
            }
            match child.try_wait() {
                Ok(Some(status)) => {
                    eprintln!("router: worker {} (pid {}) exited: {status}",
                              w.index, w.pid.load(Ordering::SeqCst));
                    break;
                }
                Ok(None) => {}
                Err(_) => break,
            }
            if w.suspect.load(Ordering::SeqCst) {
                eprintln!("router: worker {} stream trouble — recycling",
                          w.index);
                break;
            }
            let since = now_ms(sh.epoch)
                .saturating_sub(w.last_recv_ms.load(Ordering::SeqCst));
            if health::is_stale(since,
                                w.pings_outstanding.load(Ordering::SeqCst),
                                sh.cfg.health_timeout_ms) {
                eprintln!("router: worker {} unresponsive for {since}ms — \
                           declaring it hung", w.index);
                break;
            }
            if last_ping.elapsed()
                >= Duration::from_millis(sh.cfg.heartbeat_ms.max(1))
            {
                let nonce =
                    sh.heartbeat_nonce.fetch_add(1, Ordering::SeqCst);
                w.pings_outstanding.fetch_add(1, Ordering::SeqCst);
                if w.send(&Request::Ping { nonce }).is_err() {
                    break;
                }
                last_ping = Instant::now();
            }
            thread::sleep(Duration::from_millis(
                sh.cfg.heartbeat_ms.clamp(5, 100)));
        }

        // tear the incarnation down
        w.healthy.store(false, Ordering::SeqCst);
        if graceful {
            // fleet shutdown: ask nicely, then insist
            let _ = w.send(&Request::Shutdown);
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                if matches!(child.try_wait(), Ok(Some(_))) {
                    break;
                }
                if Instant::now() > deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    break;
                }
                thread::sleep(Duration::from_millis(20));
            }
        } else {
            let _ = child.kill();
            let _ = child.wait();
        }
        w.close_writer();
        let _ = demux_handle.join();
        w.pid.store(0, Ordering::SeqCst);
        *lock(&w.addr) = None;
        if graceful {
            return;
        }

        // crash path: requests routed there get structured errors NOW,
        // not a silent hang; the slot respawns from the same artifact
        w.failures.fetch_add(1, Ordering::SeqCst);
        sh.metrics.inc("fleet.worker_failures", 1);
        fail_inflight(&sh, &w);
        consecutive_failures = if healthy_since.elapsed()
            >= Duration::from_millis(sh.cfg.stable_ms)
        {
            0 // it ran fine for a while: restart immediately
        } else {
            consecutive_failures.saturating_add(1)
        };
        incarnation += 1;
    }
}

/// Every in-flight request routed to `w` gets a structured `worker_failed`
/// error on its owning connection; routes and in-flight counts are
/// released so the dispatcher can use the freed capacity elsewhere.
fn fail_inflight(sh: &FleetShared, w: &WorkerShared) {
    let dead: Vec<Route> = {
        let mut routes = lock(&sh.routes);
        let ids: Vec<u64> = routes
            .iter()
            .filter(|(_, r)| r.worker == w.index)
            .map(|(id, _)| *id)
            .collect();
        ids.iter().filter_map(|id| routes.remove(id)).collect()
    };
    for r in &dead {
        push_to_conn(sh, &r.conn, event_line(&Event::error(
            Some(r.client_id), ERR_WORKER_FAILED,
            format!("worker {} died mid-request; the request was not \
                     completed — safe to retry", w.index))));
        w.inflight.fetch_sub(1, Ordering::SeqCst);
        r.conn.inflight.fetch_sub(1, Ordering::SeqCst);
    }
    if !dead.is_empty() {
        eprintln!("router: failed {} in-flight request(s) from worker {}",
                  dead.len(), w.index);
    }
    sh.wake.notify();
}

/// Read one worker incarnation's event stream: stamp heartbeat freshness,
/// translate fleet ids back to client ids, and fan lines into connection
/// outboxes.  Exits on EOF/garble, flagging the worker suspect.
fn demux(sh: &FleetShared, w: &WorkerShared, stream: TcpStream) {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        w.last_recv_ms.store(now_ms(sh.epoch), Ordering::SeqCst);
        w.pings_outstanding.store(0, Ordering::SeqCst);
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            continue;
        }
        let ev = match protocol::parse_event(trimmed) {
            Ok(ev) => ev,
            Err(e) => {
                // a garbled stream means framing is lost: recycle the worker
                eprintln!("router: worker {} sent garbage ({e}) — recycling",
                          w.index);
                break;
            }
        };
        match ev {
            Event::Pong { .. } => {} // freshness already stamped
            Event::Token { id, index, token } => {
                let target = lock(&sh.routes)
                    .get(&id)
                    .map(|r| (Arc::clone(&r.conn), r.client_id));
                if let Some((conn, client_id)) = target {
                    push_to_conn(sh, &conn, event_line(&Event::Token {
                        id: client_id, index, token }));
                }
            }
            Event::Done { id, tokens, prompt_len, queue_ms, prefill_ms,
                          decode_ms, ttft_ms, latency_ms, truncated,
                          cached_prompt_tokens } => {
                if let Some(r) = lock(&sh.routes).remove(&id) {
                    sh.metrics.record_ms(
                        "fleet.e2e_ms",
                        r.started.elapsed().as_secs_f64() * 1e3);
                    push_to_conn(sh, &r.conn, event_line(&Event::Done {
                        id: r.client_id, tokens, prompt_len, queue_ms,
                        prefill_ms, decode_ms, ttft_ms, latency_ms,
                        truncated, cached_prompt_tokens }));
                    w.inflight.fetch_sub(1, Ordering::SeqCst);
                    r.conn.inflight.fetch_sub(1, Ordering::SeqCst);
                    sh.wake.notify();
                }
            }
            Event::Error { id: Some(id), code, message, queue_depth,
                           retry_after_ms } => {
                if let Some(r) = lock(&sh.routes).remove(&id) {
                    push_to_conn(sh, &r.conn, event_line(&Event::Error {
                        id: Some(r.client_id), code, message, queue_depth,
                        retry_after_ms }));
                    w.inflight.fetch_sub(1, Ordering::SeqCst);
                    r.conn.inflight.fetch_sub(1, Ordering::SeqCst);
                    sh.wake.notify();
                }
            }
            // request-anonymous worker messages (shutdown acks, global
            // errors) have no route to follow; the supervisor's health
            // machinery owns worker-level trouble
            _ => {}
        }
    }
    w.suspect.store(true, Ordering::SeqCst);
    sh.wake.notify();
}

// ------------------------------------------------------------- dispatcher

/// Pop the router FIFO and place each request on a worker.  Exits when the
/// FIFO is closed and fully drained.
fn dispatcher(sh: &FleetShared) {
    loop {
        match sh.fifo.pop_or_state() {
            PopState::Drained => return,
            PopState::Empty => {
                sh.fifo.wait_nonempty(Duration::from_millis(50));
            }
            PopState::Item(job) => dispatch_one(sh, job),
        }
    }
}

/// Choose a worker for `conn`: its affinity worker when healthy and under
/// the per-worker depth, otherwise the least-loaded healthy worker.
fn pick_worker(sh: &FleetShared, conn: &RouterConn) -> Option<usize> {
    let depth = sh.cfg.worker_depth.max(1);
    let usable = |w: &WorkerShared| {
        w.healthy.load(Ordering::SeqCst)
            && !w.suspect.load(Ordering::SeqCst)
            && w.inflight.load(Ordering::SeqCst) < depth
    };
    let aff = conn.affinity.load(Ordering::SeqCst);
    if aff > 0 && usable(&sh.workers[aff - 1]) {
        return Some(aff - 1);
    }
    sh.workers
        .iter()
        .filter(|w| usable(w))
        .min_by_key(|w| (w.inflight.load(Ordering::SeqCst),
                         w.routed_total.load(Ordering::SeqCst),
                         w.index))
        .map(|w| w.index)
}

fn dispatch_one(sh: &FleetShared, job: Job) {
    loop {
        if job.conn.outbox.is_closed() {
            // client already gone (EOF or shed): don't spend a worker on it
            job.conn.inflight.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        let Some(widx) = pick_worker(sh, &job.conn) else {
            let any_healthy = sh.workers.iter()
                .any(|w| w.healthy.load(Ordering::SeqCst));
            if !any_healthy
                && (sh.shutdown.load(Ordering::SeqCst)
                    || sh.workers_stop.load(Ordering::SeqCst))
            {
                // nothing will ever serve this request
                push_to_conn(sh, &job.conn, event_line(&Event::error(
                    Some(job.client_id), ERR_SHUTTING_DOWN,
                    "fleet is shutting down".into())));
                job.conn.inflight.fetch_sub(1, Ordering::SeqCst);
                return;
            }
            // all workers busy or restarting: requests stay queued (that
            // is what graceful degradation to N−1 … 1 workers looks like)
            sh.wake.wait_timeout(Duration::from_millis(50));
            continue;
        };
        let w = &sh.workers[widx];

        // route first, then write: the reply cannot beat the bookkeeping
        lock(&sh.routes).insert(job.fleet_id, Route {
            conn: Arc::clone(&job.conn),
            client_id: job.client_id,
            worker: widx,
            started: Instant::now(),
        });
        w.inflight.fetch_add(1, Ordering::SeqCst);
        let wire = Request::Generate(GenerateReq {
            id: job.fleet_id,
            prompt: job.req.prompt.clone(),
            max_new_tokens: job.req.max_new_tokens,
            temperature: job.req.temperature,
            seed: job.req.seed,
        });
        match w.send(&wire) {
            Ok(()) => {
                w.routed_total.fetch_add(1, Ordering::SeqCst);
                sh.metrics.inc("fleet.requests_routed", 1);
                job.conn.affinity.store(widx + 1, Ordering::SeqCst);
                return;
            }
            Err(_) => {
                // the worker link died under us: undo, flag the worker
                // for the supervisor, and re-pick
                lock(&sh.routes).remove(&job.fleet_id);
                w.inflight.fetch_sub(1, Ordering::SeqCst);
                w.suspect.store(true, Ordering::SeqCst);
                w.healthy.store(false, Ordering::SeqCst);
                sh.wake.notify();
            }
        }
    }
}

// ------------------------------------------------------------ connections

/// Serve one client connection: reader on this thread, writer draining the
/// paced outbox on a helper thread.
fn handle_conn(sh: &Arc<FleetShared>, stream: TcpStream) {
    sh.metrics.inc("connections", 1);
    let conn_id = sh.next_conn_id.fetch_add(1, Ordering::SeqCst);
    let conn = Arc::new(RouterConn {
        outbox: ConnOutbox::new(
            sh.cfg.outbox_lines,
            Duration::from_millis(sh.cfg.write_stall_ms)),
        inflight: AtomicUsize::new(0),
        affinity: AtomicUsize::new(0),
    });
    lock(&sh.conns).insert(conn_id, Arc::clone(&conn));

    let writer_handle = match stream.try_clone() {
        Ok(out) => {
            let conn = Arc::clone(&conn);
            Some(thread::spawn(move || {
                let mut out = out;
                while let Some(mut l) = conn.outbox.pop() {
                    l.push('\n');
                    if out.write_all(l.as_bytes()).is_err() {
                        conn.outbox.close();
                        break;
                    }
                }
                // unblock the reader so the connection fully closes
                let _ = out.shutdown(std::net::Shutdown::Both);
            }))
        }
        Err(_) => None,
    };

    if writer_handle.is_some() {
        reader_loop(sh, &conn, &stream);
    }
    conn.outbox.close();
    if let Some(h) = writer_handle {
        let _ = h.join();
    }
    lock(&sh.conns).remove(&conn_id);
}

fn reader_loop(sh: &Arc<FleetShared>, conn: &Arc<RouterConn>,
               stream: &TcpStream) {
    let mut reader = match stream.try_clone() {
        Ok(r) => BufReader::new(r),
        Err(_) => return,
    };
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            continue;
        }
        match protocol::parse_request(trimmed) {
            Err(e) => push_to_conn(sh, conn, event_line(&Event::error(
                None, ERR_BAD_REQUEST, e))),
            Ok(req) => handle_request(sh, conn, req),
        }
        if conn.outbox.is_closed() {
            return; // shed while we were handling — stop reading
        }
    }
}

fn handle_request(sh: &Arc<FleetShared>, conn: &Arc<RouterConn>,
                  req: Request) {
    match req {
        Request::Hello { proto } => {
            if proto == PROTO_VERSION {
                push_to_conn(sh, conn, event_line(&Event::Hello {
                    proto: PROTO_VERSION,
                    version: env!("CARGO_PKG_VERSION").into(),
                    engine: fleet_engine_label(sh),
                }));
            } else {
                push_to_conn(sh, conn, event_line(&Event::error(
                    None, ERR_BAD_REQUEST,
                    format!("unsupported proto {proto} (this router speaks \
                             {PROTO_VERSION})"))));
            }
        }
        Request::Ping { nonce } => {
            push_to_conn(sh, conn, event_line(&Event::Pong { nonce }));
        }
        Request::Metrics => {
            let snap = fleet_snapshot(sh);
            push_to_conn(sh, conn, event_line(&Event::Metrics(snap)));
        }
        Request::Trace => {
            push_to_conn(sh, conn, event_line(&Event::Trace(
                crate::obs::snapshot_json(256))));
        }
        Request::Reload { artifact } => handle_reload(sh, conn, &artifact),
        Request::Generate(g) => handle_generate(sh, conn, g),
        Request::Shutdown => {
            push_to_conn(sh, conn, event_line(&Event::ShuttingDown));
            sh.shutdown.store(true, Ordering::SeqCst);
            sh.fifo.close();
            sh.wake.notify();
        }
    }
}

fn handle_generate(sh: &Arc<FleetShared>, conn: &Arc<RouterConn>,
                   g: GenerateReq) {
    if g.prompt.is_empty() {
        push_to_conn(sh, conn, event_line(&Event::error(
            Some(g.id), ERR_BAD_REQUEST, "empty prompt".into())));
        return;
    }
    if sh.shutdown.load(Ordering::SeqCst) {
        push_to_conn(sh, conn, event_line(&Event::error(
            Some(g.id), ERR_SHUTTING_DOWN,
            "fleet is shutting down".into())));
        return;
    }
    let fleet_id = sh.next_fleet_id.fetch_add(1, Ordering::SeqCst);
    conn.inflight.fetch_add(1, Ordering::SeqCst);
    let job = Job { fleet_id, client_id: g.id, conn: Arc::clone(conn),
                    req: g };
    match sh.fifo.try_push(job) {
        Ok(()) => sh.wake.notify(),
        Err(PushError::Full(job)) => {
            conn.inflight.fetch_sub(1, Ordering::SeqCst);
            let queued = sh.fifo.len();
            push_to_conn(sh, conn, event_line(&Event::Error {
                id: Some(job.client_id),
                code: ERR_OVERLOADED.into(),
                message: format!("router queue full ({queued} queued)"),
                queue_depth: Some(queued),
                retry_after_ms: Some(admission::retry_after_hint_ms(
                    queued, sh.fifo.depth())),
            }));
        }
        Err(PushError::Closed(job)) => {
            conn.inflight.fetch_sub(1, Ordering::SeqCst);
            push_to_conn(sh, conn, event_line(&Event::error(
                Some(job.client_id), ERR_SHUTTING_DOWN,
                "fleet is shutting down".into())));
        }
    }
}

// ------------------------------------------------------- control plane

/// Joined engine label across workers, e.g. `fleet[2 x lowrank-r60]`, or
/// `fleet[dense|lowrank-r60]` while mixed mid-reload.
fn fleet_engine_label(sh: &FleetShared) -> String {
    let mut labels: Vec<String> = Vec::new();
    for w in &sh.workers {
        let l = lock(&w.engine).clone();
        if !l.is_empty() && !labels.contains(&l) {
            labels.push(l);
        }
    }
    match labels.len() {
        0 => "fleet[booting]".to_string(),
        1 => format!("fleet[{} x {}]", sh.workers.len(), labels[0]),
        _ => format!("fleet[{}]", labels.join("|")),
    }
}

/// One short-lived request/reply exchange with a worker on a *fresh*
/// connection (control traffic never rides the routed stream, so a slow
/// snapshot cannot stall token demux).
fn worker_call(addr: SocketAddr, req: &Request, timeout: Duration)
               -> io::Result<Event> {
    let stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut line = protocol::request_line(req);
    line.push('\n');
    (&stream).write_all(line.as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    if reader.read_line(&mut reply)? == 0 {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof,
                                  "worker closed during control call"));
    }
    protocol::parse_event(reply.trim_end())
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
}

/// Fleet-wide reload fan-out.  `spec` is either one manifest path (all
/// workers) or exactly N comma-separated paths (per-worker stores).
/// Workers reload sequentially; a worker that fails verification keeps
/// serving its current plan, and the reply names exactly which workers
/// swapped and which did not.
fn handle_reload(sh: &Arc<FleetShared>, conn: &Arc<RouterConn>,
                 spec: &str) {
    let parts: Vec<&str> = spec.split(',').map(str::trim).collect();
    if parts.len() != 1 && parts.len() != sh.workers.len() {
        push_to_conn(sh, conn, event_line(&Event::error(
            None, ERR_BAD_REQUEST,
            format!("reload wants 1 path or one per worker ({} workers, \
                     {} paths)", sh.workers.len(), parts.len()))));
        return;
    }
    let mut swapped: Vec<String> = Vec::new();
    let mut failed: Vec<String> = Vec::new();
    for (i, w) in sh.workers.iter().enumerate() {
        let path = if parts.len() == 1 { parts[0] } else { parts[i] };
        let addr = match *lock(&w.addr) {
            Some(a) if w.healthy.load(Ordering::SeqCst) => a,
            _ => {
                failed.push(format!("worker {i}: down"));
                continue;
            }
        };
        match worker_call(addr,
                          &Request::Reload { artifact: path.to_string() },
                          Duration::from_secs(60)) {
            Ok(Event::Reloaded { engine, .. }) => {
                *lock(&w.engine) = engine;
                swapped.push(format!("worker {i}"));
            }
            Ok(Event::Error { code, message, .. }) => {
                failed.push(format!("worker {i}: {code}: {message}"));
            }
            Ok(other) => {
                failed.push(format!("worker {i}: unexpected reply \
                                     {other:?}"));
            }
            Err(e) => failed.push(format!("worker {i}: {e}")),
        }
    }
    if failed.is_empty() {
        sh.metrics.inc("fleet.reloads", 1);
        push_to_conn(sh, conn, event_line(&Event::Reloaded {
            artifact: spec.to_string(),
            engine: fleet_engine_label(sh),
        }));
    } else {
        // partial swap: precise blast-radius report, nothing hidden
        push_to_conn(sh, conn, event_line(&Event::error(
            None, ERR_RELOAD_FAILED,
            format!("swapped [{}]; failed [{}] — unswapped workers keep \
                     serving their current plan",
                    swapped.join(", "), failed.join("; ")))));
    }
}

/// The fleet metrics snapshot: the router's own registry (connections,
/// routing counters, e2e latency) plus a `workers` array of per-worker
/// health/state and `worker_counters` summing each live worker's own
/// counters, fetched over fresh control connections.
fn fleet_snapshot(sh: &Arc<FleetShared>) -> Json {
    use std::collections::BTreeMap;
    for w in &sh.workers {
        crate::obs::gauge_set(
            &format!("fleet.worker{}.healthy", w.index),
            if w.healthy.load(Ordering::SeqCst) { 1.0 } else { 0.0 });
        crate::obs::gauge_set(
            &format!("fleet.worker{}.inflight", w.index),
            w.inflight.load(Ordering::SeqCst) as f64);
    }
    let mut entries: Vec<Json> = Vec::new();
    let mut summed: BTreeMap<String, f64> = BTreeMap::new();
    for w in &sh.workers {
        let addr = *lock(&w.addr);
        let healthy = w.healthy.load(Ordering::SeqCst);
        let mut fields: Vec<(&str, Json)> = vec![
            ("index", Json::num(w.index as f64)),
            ("healthy", Json::Bool(healthy)),
            ("pid", Json::num(w.pid.load(Ordering::SeqCst) as f64)),
            ("addr", Json::str(&addr.map(|a| a.to_string())
                                    .unwrap_or_default())),
            ("artifact", Json::str(&w.artifact)),
            ("engine", Json::str(&lock(&w.engine))),
            ("inflight",
             Json::num(w.inflight.load(Ordering::SeqCst) as f64)),
            ("routed_total",
             Json::num(w.routed_total.load(Ordering::SeqCst) as f64)),
            ("restarts",
             Json::num(w.restarts.load(Ordering::SeqCst) as f64)),
            ("failures",
             Json::num(w.failures.load(Ordering::SeqCst) as f64)),
        ];
        if let (true, Some(a)) = (healthy, addr) {
            if let Ok(Event::Metrics(m)) =
                worker_call(a, &Request::Metrics, Duration::from_secs(2))
            {
                if let Some(counters) =
                    m.get("counters").and_then(Json::as_obj)
                {
                    for (k, v) in counters {
                        if let Some(n) = v.as_f64() {
                            *summed.entry(k.clone()).or_insert(0.0) += n;
                        }
                    }
                }
                if let Some(tps) = m.get("uptime_tok_per_sec")
                    .and_then(Json::as_f64)
                {
                    fields.push(("uptime_tok_per_sec", Json::num(tps)));
                }
            }
        }
        entries.push(Json::obj(fields));
    }
    let mut snap = sh.metrics.snapshot(sh.fifo.len());
    if let Json::Obj(m) = &mut snap {
        m.insert("fleet".into(), Json::Bool(true));
        m.insert("workers".into(), Json::Arr(entries));
        m.insert("worker_counters".into(),
                 Json::Obj(summed.into_iter()
                           .map(|(k, v)| (k, Json::num(v)))
                           .collect()));
    }
    snap
}
