//! Sharded multi-worker fleet: a supervising router in front of N worker
//! processes, each running today's full serving engine booted from a
//! packed artifact (`crate::artifact`).
//!
//! One listening address speaks the exact single-server wire protocol
//! (`crate::server::protocol`); behind it the router spawns, health-checks,
//! restarts, and load-balances worker processes.  Because generations
//! depend only on (weights, prompt, temperature, seed), a fleet response
//! **bit-matches** a single-process run of the same request — sharding is
//! a pure availability/throughput move, never a correctness one
//! (`rust/tests/fleet.rs` gates this over workers × threads ×
//! speculation, including across a worker kill).
//!
//! # Fault model
//!
//! What the router defends against, and how:
//!
//! * **Worker crash** (process exits, e.g. OOM-kill or `kill -9`): the
//!   supervisor notices via `try_wait`, every in-flight request routed to
//!   that worker receives a structured `worker_failed` error immediately —
//!   never a silent hang — and the worker respawns from the *same verified
//!   artifact*.  Traffic keeps flowing on the surviving workers
//!   (graceful degradation N−1, …, 1).
//! * **Worker hang** (process alive, engine wedged): heartbeat `ping`s go
//!   out on the routed connection every `heartbeat_ms`; silence past
//!   `health_timeout_ms` with an unanswered ping declares the worker hung,
//!   and it is killed and restarted like a crash.
//! * **Crash loop** (bad node, corrupt store): respawns back off
//!   exponentially (`restart.base_ms · 2ⁿ⁻¹`, capped at `restart.max_ms`);
//!   a worker that stays up `stable_ms` resets the counter.
//! * **Overload**: two-level admission.  The router FIFO
//!   (`router_depth`) rejects with a structured `overloaded` error
//!   carrying `queue_depth` + `retry_after_ms` hints; per-worker depth
//!   (`worker_depth`) bounds what any one worker holds, so one slow
//!   worker cannot absorb the whole queue.
//! * **Slow readers**: each connection's outbox is capped
//!   (`outbox_lines`); a full outbox *paces* producers (bounded wait for
//!   the client to read) and, after `write_stall_ms` without progress,
//!   sheds the connection with a structured `slow_reader` error close —
//!   one stalled client can neither block other streams nor grow router
//!   memory without bound.
//! * **Version skew**: the router handshakes `hello {proto}` with every
//!   booting worker and refuses mismatches, so a stale binary fails
//!   loudly at boot, not with garbled frames mid-stream.
//! * **Partial reload**: a fleet-wide `reload` fans out sequentially; a
//!   worker that fails artifact verification keeps serving its current
//!   plan, and the reply names exactly which workers swapped.
//!
//! Out of scope: the router itself is a single process (its failure takes
//! the listening address down — run it under an init/systemd-style
//! restarter), and workers are trusted local processes (no wire auth).
//!
//! # Quick start
//!
//! ```text
//! zs-svd pack --out store --name tiny --ratio 0.6        # once
//! zs-svd router --workers 4 --artifact store/tiny.zsar --listen 127.0.0.1:7000
//! zs-svd client --connect 127.0.0.1:7000 --requests 32 --retries 3
//! ```
//!
//! From Rust, [`run_fleet`] with a [`RouterConfig`] does the same; it
//! returns [`FleetStats`] after a client-initiated `shutdown` drains the
//! fleet.

pub mod flow;
pub mod health;
pub mod router;
pub mod worker;

pub use flow::{ConnOutbox, PushOutcome};
pub use health::BackoffPolicy;
pub use router::{run_fleet, FleetStats, RouterConfig};
pub use worker::{WorkerShared, WorkerSpec};
