//! Worker health policy: restart back-off and heartbeat staleness.
//!
//! Pure arithmetic, no clocks of its own — the router's supervisor loop
//! feeds it observations (consecutive boot failures, milliseconds since
//! the last byte from a worker) and acts on the answers.  Keeping the
//! policy separate from the supervision machinery makes it unit-testable
//! without processes.

/// Bounded exponential restart back-off.
///
/// A worker that keeps dying on boot must not be respawned in a hot loop:
/// the `n`-th consecutive failure waits `min(base · 2^(n−1), max)` before
/// the next attempt.  A worker that stays healthy for the router's
/// stability window resets the counter, so a one-off crash restarts fast.
#[derive(Clone, Copy, Debug)]
pub struct BackoffPolicy {
    /// delay after the first consecutive failure, in milliseconds
    pub base_ms: u64,
    /// ceiling for the delay, in milliseconds
    pub max_ms: u64,
}

impl BackoffPolicy {
    /// Delay before the next restart attempt, given how many restarts in a
    /// row have failed (or died before stabilising).  Zero failures — the
    /// first boot, or a restart after a long-healthy worker finally died —
    /// waits nothing.
    pub fn delay_ms(&self, consecutive_failures: u32) -> u64 {
        if consecutive_failures == 0 {
            return 0;
        }
        let ceiling = self.max_ms.max(1);
        let floor = self.base_ms.max(1).min(ceiling);
        let shift = (consecutive_failures - 1).min(32);
        self.base_ms
            .max(1)
            .saturating_mul(1u64 << shift)
            .clamp(floor, ceiling)
    }
}

impl Default for BackoffPolicy {
    fn default() -> BackoffPolicy {
        BackoffPolicy { base_ms: 200, max_ms: 5_000 }
    }
}

/// Heartbeat verdict: is a worker that last spoke `since_last_recv_ms`
/// milliseconds ago — with `pings_outstanding` unanswered pings — stale?
///
/// A worker is only declared stale when it has been silent past the
/// timeout *and* at least one ping went unanswered; silence alone is
/// normal for an idle worker between heartbeat ticks.
pub fn is_stale(since_last_recv_ms: u64, pings_outstanding: u64,
                health_timeout_ms: u64) -> bool {
    pings_outstanding > 0 && since_last_recv_ms >= health_timeout_ms
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_then_clamps() {
        let p = BackoffPolicy { base_ms: 200, max_ms: 5_000 };
        assert_eq!(p.delay_ms(0), 0, "first boot waits nothing");
        assert_eq!(p.delay_ms(1), 200);
        assert_eq!(p.delay_ms(2), 400);
        assert_eq!(p.delay_ms(3), 800);
        assert_eq!(p.delay_ms(5), 3_200);
        assert_eq!(p.delay_ms(6), 5_000, "clamped at max");
        assert_eq!(p.delay_ms(60), 5_000, "huge counts do not overflow");
        assert_eq!(p.delay_ms(u32::MAX), 5_000);
    }

    #[test]
    fn backoff_tolerates_degenerate_configs() {
        // base above max: every failure waits exactly max
        let p = BackoffPolicy { base_ms: 9_000, max_ms: 1_000 };
        assert_eq!(p.delay_ms(1), 1_000);
        assert_eq!(p.delay_ms(7), 1_000);
        // zeros never panic and never divide-by-zero the clamp
        let p = BackoffPolicy { base_ms: 0, max_ms: 0 };
        assert_eq!(p.delay_ms(0), 0);
        assert_eq!(p.delay_ms(1), 1);
        assert_eq!(p.delay_ms(40), 1);
    }

    #[test]
    fn staleness_needs_both_silence_and_an_unanswered_ping() {
        // silent but never pinged (or every ping answered): idle, not stale
        assert!(!is_stale(10_000, 0, 3_000));
        // pinged and silent past the timeout: stale
        assert!(is_stale(3_000, 1, 3_000));
        assert!(is_stale(60_000, 4, 3_000));
        // pinged but recently heard from: healthy
        assert!(!is_stale(100, 1, 3_000));
    }
}
