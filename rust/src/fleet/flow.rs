//! Per-connection streaming flow control: a bounded outbox that *paces*
//! producers against the consumer instead of buffering without bound, and
//! sheds the connection with a structured `slow_reader` error when pacing
//! runs out of patience.
//!
//! The server's plain outbox (PR 3) silently drops a connection at its
//! line cap.  The router version here is gentler and louder: a push into a
//! full outbox first *waits* up to the pace window for the writer to drain
//! a slot (back-pressure propagates to the producing worker stream), and
//! only then declares the client dead — dropping the backlog, queueing one
//! structured [`ERR_SLOW_READER`] error line, and closing.  Memory stays
//! bounded by `cap + 1` lines per connection in every outcome.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::server::protocol::{event_line, Event, ERR_SLOW_READER};

/// What happened to a pushed line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// queued for the writer
    Queued,
    /// outbox already closed (client gone or previously shed) — dropped
    Dropped,
    /// this push hit the cap, waited out the pace window, and shed the
    /// connection: backlog dropped, `slow_reader` error queued, closed
    Shed,
}

struct Inner {
    lines: VecDeque<String>,
    closed: bool,
    shed: bool,
}

/// Bounded paced outbox: the fleet router's per-connection line queue.
///
/// Producers (worker demux threads, the connection's own reader) push wire
/// lines; the connection's writer thread pops them.  `cap` bounds queued
/// lines; `pace` bounds how long a producer will wait for the writer to
/// free a slot before the connection is declared a slow reader and shed.
pub struct ConnOutbox {
    cap: usize,
    pace: Duration,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl ConnOutbox {
    /// Outbox holding at most `cap` lines (≥ 1); a push into a full outbox
    /// waits up to `pace` for drain before shedding.
    pub fn new(cap: usize, pace: Duration) -> ConnOutbox {
        ConnOutbox {
            cap: cap.max(1),
            pace,
            inner: Mutex::new(Inner { lines: VecDeque::new(), closed: false,
                                      shed: false }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Push one wire line, pacing against the writer when full.  At the
    /// cap the caller blocks up to the pace window for a free slot; if the
    /// writer still hasn't drained one, the connection is shed: the
    /// backlog is dropped, one structured `slow_reader` error is queued
    /// for a best-effort goodbye, and the outbox closes.  Pushes after
    /// close return [`PushOutcome::Dropped`] immediately, so a dead
    /// connection costs each producer at most one pace window ever.
    pub fn push(&self, line: String) -> PushOutcome {
        let mut g = self.lock();
        if g.closed {
            return PushOutcome::Dropped;
        }
        if g.lines.len() >= self.cap {
            // pace: wait for the writer to free a slot, bounded
            let deadline = Instant::now() + self.pace;
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = self
                    .cv
                    .wait_timeout(g, deadline - now)
                    .unwrap_or_else(|e| e.into_inner());
                g = guard;
                if g.closed {
                    return PushOutcome::Dropped;
                }
                if g.lines.len() < self.cap {
                    g.lines.push_back(line);
                    self.cv.notify_all();
                    return PushOutcome::Queued;
                }
            }
            // the client has not read for a full pace window at cap:
            // declare it dead LOUDLY — drop the backlog (bounded memory),
            // leave one structured goodbye, and close
            g.lines.clear();
            g.lines.push_back(event_line(&Event::error(
                None, ERR_SLOW_READER,
                format!("connection shed: outbox held {} unread lines for \
                         {:?}", self.cap, self.pace))));
            g.shed = true;
            g.closed = true;
            self.cv.notify_all();
            return PushOutcome::Shed;
        }
        g.lines.push_back(line);
        self.cv.notify_all();
        PushOutcome::Queued
    }

    /// Blocking pop for the writer thread; `None` once closed and drained.
    pub fn pop(&self) -> Option<String> {
        let mut g = self.lock();
        loop {
            if let Some(l) = g.lines.pop_front() {
                self.cv.notify_all(); // a slot freed: wake paced producers
                return Some(l);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Close for new lines; queued lines still drain through [`pop`].
    ///
    /// [`pop`]: ConnOutbox::pop
    pub fn close(&self) {
        let mut g = self.lock();
        g.closed = true;
        self.cv.notify_all();
    }

    /// True once closed (shed, client EOF, or shutdown).
    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    /// True iff this connection was shed as a slow reader.
    pub fn was_shed(&self) -> bool {
        self.lock().shed
    }

    /// Lines currently queued (test/diagnostic view).
    pub fn len(&self) -> usize {
        self.lock().lines.len()
    }

    /// True when nothing is queued right now.
    pub fn is_empty(&self) -> bool {
        self.lock().lines.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::protocol::parse_event;

    fn line(i: usize) -> String {
        event_line(&Event::Token { id: 1, index: i, token: 7 })
    }

    #[test]
    fn shed_at_cap_bounds_memory_and_says_goodbye() {
        // no consumer at all and zero patience: the cap-breaching push
        // sheds immediately
        let o = ConnOutbox::new(4, Duration::from_millis(0));
        for i in 0..4 {
            assert_eq!(o.push(line(i)), PushOutcome::Queued);
        }
        assert_eq!(o.len(), 4);
        assert_eq!(o.push(line(4)), PushOutcome::Shed);
        assert!(o.was_shed());
        assert!(o.is_closed());
        // memory bound: backlog dropped, exactly the goodbye remains
        assert_eq!(o.len(), 1);
        // and that goodbye is the structured slow_reader error
        let goodbye = o.pop().expect("goodbye line");
        match parse_event(&goodbye).unwrap() {
            Event::Error { code, id, .. } => {
                assert_eq!(code, ERR_SLOW_READER);
                assert_eq!(id, None);
            }
            other => panic!("expected slow_reader error, got {other:?}"),
        }
        assert_eq!(o.pop(), None);
        // the shed connection is free for producers: drop, don't wait
        assert_eq!(o.push(line(9)), PushOutcome::Dropped);
    }

    #[test]
    fn pacing_waits_for_the_writer_instead_of_shedding() {
        use std::sync::Arc;
        let o = Arc::new(ConnOutbox::new(2, Duration::from_secs(10)));
        let consumer = {
            let o = Arc::clone(&o);
            std::thread::spawn(move || {
                let mut got = 0;
                while o.pop().is_some() {
                    got += 1;
                    // a deliberately slow reader that IS reading
                    std::thread::sleep(Duration::from_millis(2));
                }
                got
            })
        };
        // 10 lines through a depth-2 outbox: pushes past the cap must pace
        // (block briefly) rather than shed
        for i in 0..10 {
            assert_eq!(o.push(line(i)), PushOutcome::Queued, "line {i}");
        }
        o.close();
        assert_eq!(consumer.join().unwrap(), 10, "nothing lost");
        assert!(!o.was_shed());
    }

    #[test]
    fn close_drains_then_reports_none() {
        let o = ConnOutbox::new(8, Duration::from_millis(0));
        o.push("a".into());
        o.push("b".into());
        o.close();
        assert_eq!(o.pop().as_deref(), Some("a"));
        assert_eq!(o.pop().as_deref(), Some("b"));
        assert_eq!(o.pop(), None);
        assert_eq!(o.push("c".into()), PushOutcome::Dropped);
        assert!(!o.was_shed(), "a plain close is not a shed");
    }
}
