//! Worker lifecycle: spawning a serving subprocess, discovering its bound
//! address through a port file, and handshaking versions before any
//! traffic is routed to it.
//!
//! A *worker* is today's full single-process engine (`zs-svd serve`)
//! booted from a packed artifact; the router owns N of them.  Everything
//! mutable that the router's threads need to observe about a worker lives
//! in [`WorkerShared`] as lock-free atomics (plus two rarely-touched
//! mutexes), so the supervisor, dispatcher, and demux threads never
//! contend on a worker-wide lock in the streaming hot path.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::server::protocol::{self, Event, Request, PROTO_VERSION};

/// How to boot one worker process.
#[derive(Clone, Debug)]
pub struct WorkerSpec {
    /// binary to exec (the router's own executable in production; the
    /// `CARGO_BIN_EXE_zs-svd` path under test)
    pub program: PathBuf,
    /// packed artifact manifest this worker serves (`--artifact`)
    pub artifact: String,
    /// extra `serve` flags passed through verbatim (`--threads`,
    /// `--speculate-k`, `--queue-depth`, ...)
    pub extra_args: Vec<String>,
    /// how long a booting worker may take to write its port file
    pub boot_timeout: Duration,
}

/// Router-side view of one worker slot, shared across supervisor,
/// dispatcher, and demux threads.
///
/// The slot persists across restarts — a new incarnation of the process
/// updates `pid`/`addr`/`engine` in place, so routing state (counters,
/// health) has one home per *slot*, not per process.
pub struct WorkerShared {
    /// stable worker index (0-based) — names the slot in metrics and logs
    pub index: usize,
    /// artifact manifest this slot (re)boots from
    pub artifact: String,
    /// true while the incarnation is handshaken and believed live; the
    /// dispatcher only routes to healthy workers
    pub healthy: AtomicBool,
    /// set by the demux thread on stream EOF / garble so the supervisor
    /// tears the incarnation down even if the process still technically runs
    pub suspect: AtomicBool,
    /// requests currently routed to this worker and not yet completed
    pub inflight: AtomicUsize,
    /// requests ever routed to this slot (all incarnations)
    pub routed_total: AtomicU64,
    /// times the supervisor respawned this slot after the initial boot
    pub restarts: AtomicU64,
    /// detected failures (crash, hang, handshake refusal) for this slot
    pub failures: AtomicU64,
    /// OS pid of the live incarnation (0 when down)
    pub pid: AtomicU64,
    /// milliseconds (vs the router epoch) when the demux thread last read
    /// any byte from this worker — heartbeat freshness
    pub last_recv_ms: AtomicU64,
    /// pings sent since the last byte was received (reset on receive);
    /// staleness requires silence *and* an unanswered ping
    pub pings_outstanding: AtomicU64,
    /// bound address of the live incarnation
    pub addr: Mutex<Option<SocketAddr>>,
    /// engine label reported by the incarnation's hello handshake
    pub engine: Mutex<String>,
    /// routing-side write half of the worker connection; demux owns the
    /// read half.  `None` while the worker is down
    pub writer: Mutex<Option<TcpStream>>,
}

impl WorkerShared {
    /// Fresh slot state for worker `index` serving `artifact`.
    pub fn new(index: usize, artifact: String) -> WorkerShared {
        WorkerShared {
            index,
            artifact,
            healthy: AtomicBool::new(false),
            suspect: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            routed_total: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            pid: AtomicU64::new(0),
            last_recv_ms: AtomicU64::new(0),
            pings_outstanding: AtomicU64::new(0),
            addr: Mutex::new(None),
            engine: Mutex::new(String::new()),
            writer: Mutex::new(None),
        }
    }

    /// Send one request line on this worker's connection.  An `Err` means
    /// the connection is gone — the caller marks the worker suspect.
    pub fn send(&self, r: &Request) -> io::Result<()> {
        let mut g = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        match g.as_mut() {
            Some(s) => {
                let mut line = protocol::request_line(r);
                line.push('\n');
                s.write_all(line.as_bytes())
            }
            None => Err(io::Error::new(io::ErrorKind::NotConnected,
                                       "worker connection down")),
        }
    }

    /// Drop the write half (the demux read half sees EOF soon after).
    pub fn close_writer(&self) {
        let mut g = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(s) = g.take() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Spawn one worker process and wait for it to publish its bound address.
///
/// The worker listens on an ephemeral port (`--listen 127.0.0.1:0`) and
/// writes the real address to a unique temp port file; we poll that file
/// against three outcomes: address published (success), child exited
/// (boot crash), boot timeout (hang — the child is killed).  stdout is
/// discarded (the worker's own banner would interleave with the router's);
/// stderr is inherited so worker panics stay visible.
pub fn spawn_worker(spec: &WorkerSpec, index: usize, incarnation: u64)
                    -> io::Result<(Child, SocketAddr)> {
    let port_file = std::env::temp_dir().join(format!(
        "zs-svd-fleet-{}-w{index}-i{incarnation}.port",
        std::process::id()));
    let _ = std::fs::remove_file(&port_file);

    let mut cmd = Command::new(&spec.program);
    cmd.arg("serve")
        .arg("--listen").arg("127.0.0.1:0")
        .arg("--artifact").arg(&spec.artifact)
        .arg("--port-file").arg(&port_file)
        .args(&spec.extra_args)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::inherit());
    let mut child = cmd.spawn()?;

    let started = Instant::now();
    loop {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            if let Ok(addr) = s.trim().parse::<SocketAddr>() {
                let _ = std::fs::remove_file(&port_file);
                return Ok((child, addr));
            }
        }
        if let Some(status) = child.try_wait()? {
            let _ = std::fs::remove_file(&port_file);
            return Err(io::Error::new(
                io::ErrorKind::Other,
                format!("worker {index} exited during boot ({status})")));
        }
        if started.elapsed() > spec.boot_timeout {
            let _ = child.kill();
            let _ = child.wait();
            let _ = std::fs::remove_file(&port_file);
            return Err(io::Error::new(
                io::ErrorKind::TimedOut,
                format!("worker {index} did not publish a port within {:?}",
                        spec.boot_timeout)));
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Connect to a freshly booted worker and handshake versions.
///
/// Returns the connected stream (read timeout cleared, ready for the
/// demux thread) and the worker's engine label.  A proto mismatch or a
/// non-hello reply is an error — the supervisor treats it as a boot
/// failure, so version skew between router and worker binaries fails
/// loudly before any request is routed.
pub fn handshake(addr: SocketAddr, timeout: Duration)
                 -> io::Result<(TcpStream, String)> {
    let stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_nodelay(true).ok();

    let mut line = protocol::request_line(
        &Request::Hello { proto: PROTO_VERSION });
    line.push('\n');
    (&stream).write_all(line.as_bytes())?;

    let mut reader = BufReader::new(stream.try_clone()?);
    let mut reply = String::new();
    if reader.read_line(&mut reply)? == 0 {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof,
                                  "worker closed during handshake"));
    }
    let bad = |m: String| io::Error::new(io::ErrorKind::InvalidData, m);
    match protocol::parse_event(reply.trim_end()) {
        Ok(Event::Hello { proto, engine, .. }) if proto == PROTO_VERSION => {
            stream.set_read_timeout(None)?;
            Ok((stream, engine))
        }
        Ok(Event::Hello { proto, .. }) => Err(bad(format!(
            "worker speaks proto {proto}, router speaks {PROTO_VERSION}"))),
        Ok(Event::Error { code, message, .. }) => Err(bad(format!(
            "worker refused handshake: {code}: {message}"))),
        Ok(other) => Err(bad(format!(
            "unexpected handshake reply: {other:?}"))),
        Err(e) => Err(bad(format!("garbled handshake reply: {e}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_reports_a_boot_crash_not_a_timeout() {
        // `false` exits immediately without writing a port file: the spawn
        // must report the exit, well before the (long) boot timeout
        let spec = WorkerSpec {
            program: PathBuf::from("/bin/false"),
            artifact: "unused.zsar".into(),
            extra_args: vec![],
            boot_timeout: Duration::from_secs(30),
        };
        let started = Instant::now();
        let err = spawn_worker(&spec, 0, 0).expect_err("must fail");
        assert!(started.elapsed() < Duration::from_secs(10));
        assert!(err.to_string().contains("exited during boot"),
                "got: {err}");
    }

    #[cfg(unix)]
    #[test]
    fn spawn_times_out_on_a_silent_worker() {
        use std::os::unix::fs::PermissionsExt;
        // a "worker" that accepts any args, never writes a port file, and
        // never exits: the spawn must give up at the boot timeout and kill it
        let script = std::env::temp_dir().join(format!(
            "zs-svd-test-silent-{}.sh", std::process::id()));
        std::fs::write(&script, "#!/bin/sh\nexec sleep 60\n").unwrap();
        let mut perm = std::fs::metadata(&script).unwrap().permissions();
        perm.set_mode(0o755);
        std::fs::set_permissions(&script, perm).unwrap();

        let spec = WorkerSpec {
            program: script.clone(),
            artifact: "unused.zsar".into(),
            extra_args: vec![],
            boot_timeout: Duration::from_millis(300),
        };
        let started = Instant::now();
        let err = spawn_worker(&spec, 1, 0).expect_err("silent worker");
        assert_eq!(err.kind(), io::ErrorKind::TimedOut, "got: {err}");
        assert!(started.elapsed() >= Duration::from_millis(300));
        let _ = std::fs::remove_file(&script);
    }

    #[test]
    fn worker_shared_send_without_connection_is_not_connected() {
        let w = WorkerShared::new(3, "a.zsar".into());
        let err = w.send(&Request::Ping { nonce: 1 }).expect_err("down");
        assert_eq!(err.kind(), io::ErrorKind::NotConnected);
        // closing an absent writer is a no-op
        w.close_writer();
    }
}
