//! Batched serving loop + latency/throughput/memory accounting (Table 7).
//!
//! A closed-loop load generator enqueues prefill requests (one full sequence
//! each) with randomized arrival offsets; the engine drains the queue in
//! batches through either the dense fwd graph or the low-rank fused path
//! with a compression plan's factors.  Latency includes queue wait, so
//! batching pressure is visible in p95.
//!
//! With `ServeConfig::workers > 1` the drain runs multi-worker: admission
//! stays a shared clock-driven queue while several scoped threads pull
//! batches and execute them concurrently, overlapping batch execution with
//! queue admission.  Latency accounting is unchanged — each request's
//! latency spans arrival → completion of the batch that served it, so
//! queue-wait remains visible in p95 under either drain mode.
//!
//! This module covers the *prefill* workload (one full forward per
//! request).  Token-by-token generation — KV-cached decoding under a
//! slot-based continuous-batching scheduler — lives in `crate::decode` and
//! reuses [`Engine`] for dense vs low-rank dispatch.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::compress::CompressionPlan;
use crate::model::ParamStore;
use crate::runtime::session::Session;
use crate::tensor::{IntTensor, Mat};
use crate::util::rng::Rng;
use crate::util::stats::LatencySummary;

/// Which executable serves the requests.  `Clone` is deliberate: the
/// hot-swap path (`crate::decode::EngineSlot`, `crate::artifact`) packs and
/// installs owned engines while a borrowed original keeps serving.
#[derive(Clone)]
pub enum Engine {
    /// the uncompressed weights through the dense graphs
    Dense,
    /// low-rank factors through the fused graphs
    Lowrank {
        /// artifact tag ("60", "40", "60_b1", ...)
        tag: String,
        /// per-target `(Wu, Wv)` factors
        factors: BTreeMap<String, (Mat, Mat)>,
    },
}

impl Engine {
    /// Low-rank engine straight from a plan's factors (ranks must already
    /// fit the artifact).
    pub fn from_plan(tag: &str, plan: &CompressionPlan) -> Engine {
        Engine::Lowrank { tag: tag.to_string(), factors: plan.factors() }
    }

    /// Build a low-rank engine whose factors fit the fixed-shape artifact:
    /// heterogeneous ranks are zero-padded up to the artifact's uniform rank
    /// (exact) or capped down to it (dropping the smallest kept components —
    /// quality is measured on the dense-eval path; this path measures speed).
    pub fn from_plan_capped(tag: &str, plan: &CompressionPlan,
                            ranks: &BTreeMap<String, usize>) -> Engine {
        let mut factors = plan.factors();
        for (name, (wu, wv)) in factors.iter_mut() {
            let k_art = ranks[name];
            if wu.cols == k_art {
                continue;
            }
            // kept components are the first `kc` columns of Wu / rows of
            // Wv: capping drops the smallest-σ tail, padding appends zero
            // components that contribute exactly 0.0 to every accumulation
            let kc = wu.cols.min(k_art);
            let mut nu = Mat::zeros(wu.rows, k_art);
            for r in 0..wu.rows {
                nu.row_mut(r)[..kc].copy_from_slice(&wu.row(r)[..kc]);
            }
            let mut nv = Mat::zeros(k_art, wv.cols);
            for r in 0..kc {
                nv.set_row(r, wv.row(r));
            }
            *wu = nu;
            *wv = nv;
        }
        Engine::Lowrank { tag: tag.to_string(), factors }
    }

    /// Table-row label (`dense` / `lowrank-r<tag>`).
    pub fn label(&self) -> String {
        match self {
            Engine::Dense => "dense".into(),
            Engine::Lowrank { tag, .. } => format!("lowrank-r{tag}"),
        }
    }
}

/// Shape of one prefill-serving benchmark run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// requests in the closed-loop workload
    pub n_requests: usize,
    /// largest batch the drain assembles
    pub max_batch: usize,
    /// mean inter-arrival gap in units of one batch-forward; < 1 saturates
    pub arrival_factor: f64,
    /// arrival-jitter seed
    pub seed: u64,
    /// drain workers; 1 = the classic serial loop, >1 overlaps batch
    /// execution with admission on scoped threads
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { n_requests: 48, max_batch: 8, arrival_factor: 0.5,
                      seed: 1, workers: 1 }
    }
}

/// Aggregate result of one prefill-serving benchmark run.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// engine label
    pub engine: String,
    /// requests served
    pub requests: usize,
    /// prompt tokens processed
    pub tokens: usize,
    /// whole-run wall time, seconds
    pub wall_seconds: f64,
    /// tokens over the full wall clock
    pub tokens_per_sec: f64,
    /// request latency summary (arrival → completion), ms
    pub latency: LatencySummary,
    /// peak RSS of the process (VmHWM), bytes
    pub peak_mem_bytes: usize,
    /// analytic activation memory of one max batch, bytes
    pub act_mem_bytes: usize,
    /// analytic weight memory, bytes (fp16-equivalent)
    pub weight_mem_bytes: f64,
}

/// Peak resident set size from /proc (linux).
pub fn peak_rss_bytes() -> usize {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: usize = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0);
                return kb * 1024;
            }
        }
    }
    0
}

/// Analytic activation memory for one forward batch (f32): residual stream
/// + attention scores + MLP activations + logits, per layer peak.
pub fn activation_bytes(batch: usize, seq: usize, d_model: usize, d_ff: usize,
                        n_heads: usize, vocab: usize) -> usize {
    let resid = batch * seq * d_model;
    let scores = batch * n_heads * seq * seq;
    let mlp = batch * seq * d_ff * 2;
    let logits = batch * seq * vocab;
    (resid * 4 + scores + mlp + logits) * 4
}

/// Run the closed-loop serving benchmark.
pub fn run_serving(sess: &Session, params: &ParamStore, engine: &Engine,
                   cfg: &ServeConfig, weight_mem_bytes: f64) -> Result<ServeStats> {
    let seq = sess.cfg.seq_len;
    let span = seq + 1;
    let mut rng = Rng::new(cfg.seed);

    // pre-generate request token rows (random corpus-free bytes are fine for
    // throughput: compute cost is content-independent)
    let rows: Vec<Vec<i32>> = (0..cfg.n_requests)
        .map(|_| (0..span).map(|_| rng.range(1, 256) as i32).collect())
        .collect();

    // warm up twice: the first dispatch may lazily compile the artifact;
    // only the second measures steady-state batch time for arrival pacing
    let warm = assemble(&rows[..cfg.max_batch.min(rows.len())], cfg.max_batch, span);
    dispatch(sess, params, engine, &warm)?;
    let t_warm = Instant::now();
    dispatch(sess, params, engine, &warm)?;
    let batch_time = t_warm.elapsed().as_secs_f64();
    let gap = batch_time * cfg.arrival_factor / cfg.max_batch as f64;

    let start = Instant::now();
    let arrivals: Vec<f64> = (0..cfg.n_requests)
        .map(|i| i as f64 * gap * (0.5 + rng.uniform()))
        .collect();

    // shared admission queue: `next` is the first un-admitted request
    let queue = Mutex::new(0usize);
    let lat_sink: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(cfg.n_requests));

    let drain = || -> Result<()> {
        loop {
            // admit everything that has "arrived"; take up to max_batch
            let (lo, take) = {
                let mut next = queue.lock().unwrap_or_else(|e| e.into_inner());
                if *next >= cfg.n_requests {
                    return Ok(());
                }
                let now = start.elapsed().as_secs_f64();
                let mut take = 0usize;
                while *next + take < cfg.n_requests
                    && arrivals[*next + take] <= now.max(arrivals[*next])
                    && take < cfg.max_batch
                {
                    take += 1;
                }
                let take = take.max(1).min(cfg.n_requests - *next);
                let lo = *next;
                *next += take;
                (lo, take)
            };
            let toks = assemble(&rows[lo..lo + take], cfg.max_batch, span);
            dispatch(sess, params, engine, &toks)?;
            let done = start.elapsed().as_secs_f64();
            let mut sink = lat_sink.lock().unwrap_or_else(|e| e.into_inner());
            for i in 0..take {
                let lat = done - arrivals[lo + i].min(done);
                sink.push(lat * 1e3);
            }
        }
    };

    if cfg.workers <= 1 {
        drain()?;
    } else {
        // each drain worker runs with the exec worker flag set: its
        // dispatches stay serial inside, so concurrency = `workers`, not
        // workers × matmul threads
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..cfg.workers)
                .map(|_| s.spawn(|| crate::exec::with_worker_flag(&drain)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
                .collect()
        });
        for r in results {
            r?;
        }
    }
    let latencies = lat_sink.into_inner().unwrap_or_else(|e| e.into_inner());

    let wall = start.elapsed().as_secs_f64();
    let tokens = cfg.n_requests * seq;
    Ok(ServeStats {
        engine: engine.label(),
        requests: cfg.n_requests,
        tokens,
        wall_seconds: wall,
        tokens_per_sec: tokens as f64 / wall,
        latency: LatencySummary::from_samples(&latencies),
        peak_mem_bytes: peak_rss_bytes(),
        act_mem_bytes: activation_bytes(cfg.max_batch, seq, sess.cfg.d_model,
                                        sess.cfg.d_ff, sess.cfg.n_heads,
                                        sess.cfg.vocab),
        weight_mem_bytes,
    })
}

fn assemble(rows: &[Vec<i32>], batch: usize, span: usize) -> IntTensor {
    let mut data = Vec::with_capacity(batch * span);
    for r in rows {
        data.extend_from_slice(r);
    }
    for _ in rows.len()..batch {
        data.extend_from_slice(&rows[0]);
    }
    IntTensor::from_vec(&[batch, span], data)
}

fn dispatch(sess: &Session, params: &ParamStore, engine: &Engine,
            toks: &IntTensor) -> Result<()> {
    match engine {
        Engine::Dense => {
            sess.fwd(params, toks)?;
        }
        Engine::Lowrank { tag, factors } => {
            sess.lowrank_fwd(tag, params, factors, toks)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_readable() {
        let r = peak_rss_bytes();
        assert!(r > 1024 * 1024, "VmHWM {r}");
    }

    #[test]
    fn activation_accounting_scales() {
        let small = activation_bytes(1, 128, 128, 352, 4, 256);
        let big = activation_bytes(8, 128, 128, 352, 4, 256);
        assert!(big > 7 * small && big < 9 * small);
    }

    #[test]
    fn assemble_pads() {
        let rows = vec![vec![1i32; 5], vec![2i32; 5]];
        let t = assemble(&rows, 4, 5);
        assert_eq!(t.shape, vec![4, 5]);
        assert_eq!(&t.data[15..20], &[1i32; 5]); // padded with row 0
    }

    fn plan_with_rank(k: usize) -> (CompressionPlan, Mat) {
        use crate::compress::plan::{factored_params, TargetPlan};
        let mut rng = Rng::new(5);
        let wu = Mat::randn(&mut rng, 6, k, 0.5);
        let wv = Mat::randn(&mut rng, k, 4, 0.5);
        let product = crate::linalg::matmul(&wu, &wv);
        let plan = CompressionPlan {
            method: "test".into(),
            ratio: 0.5,
            seconds: 0.0,
            targets: vec![TargetPlan {
                name: "t".into(), m: 6, n: 4, rank: k, dense: false,
                replacement: product.clone(), factors: Some((wu, wv)),
                stored_params: factored_params(6, 4, k),
            }],
        };
        (plan, product)
    }

    fn capped_factors(plan: &CompressionPlan, k_art: usize) -> (Mat, Mat) {
        let ranks: BTreeMap<String, usize> =
            [("t".to_string(), k_art)].into_iter().collect();
        match Engine::from_plan_capped("60", plan, &ranks) {
            Engine::Lowrank { factors, .. } => factors["t"].clone(),
            Engine::Dense => unreachable!(),
        }
    }

    #[test]
    fn capped_engine_pads_heterogeneous_ranks_up() {
        let (plan, product) = plan_with_rank(2);
        let (wu, wv) = capped_factors(&plan, 4); // pad 2 -> 4
        assert_eq!((wu.rows, wu.cols), (6, 4));
        assert_eq!((wv.rows, wv.cols), (4, 4));
        // zero components contribute exactly nothing: product unchanged
        let padded = crate::linalg::matmul(&wu, &wv);
        assert_eq!(padded, product);
        // the appended components are all-zero
        for r in 0..wu.rows {
            assert_eq!(&wu.row(r)[2..], &[0.0, 0.0]);
        }
        assert!(wv.row(2).iter().chain(wv.row(3)).all(|&z| z == 0.0));
    }

    #[test]
    fn capped_engine_caps_ranks_down() {
        let (plan, _) = plan_with_rank(4);
        let (orig_u, orig_v) = plan.factors()["t"].clone();
        let (wu, wv) = capped_factors(&plan, 2); // cap 4 -> 2
        assert_eq!((wu.rows, wu.cols), (6, 2));
        assert_eq!((wv.rows, wv.cols), (2, 4));
        // the two kept components are the leading ones
        for r in 0..wu.rows {
            assert_eq!(wu.row(r), &orig_u.row(r)[..2]);
        }
        assert_eq!(wv.row(0), orig_v.row(0));
        assert_eq!(wv.row(1), orig_v.row(1));
    }

    #[test]
    fn capped_engine_exact_rank_untouched() {
        let (plan, _) = plan_with_rank(3);
        let before = plan.factors()["t"].clone();
        let after = capped_factors(&plan, 3);
        assert_eq!(after.0, before.0);
        assert_eq!(after.1, before.1);
    }

    #[test]
    fn multi_worker_drain_serves_every_request() {
        use crate::model::init::init_params;
        use crate::runtime::{session::Session, Runtime};

        let rt = Runtime::load_default().unwrap();
        let sess = Session::new(&rt, "tiny");
        let mut rng = Rng::new(9);
        let params = init_params(&sess.cfg, &mut rng);
        // b1 batches so admission outpaces execution and workers overlap
        let cfg = ServeConfig { n_requests: 3, max_batch: 1, arrival_factor: 0.25,
                                seed: 2, workers: 2 };
        let stats = run_serving(&sess, &params, &Engine::Dense, &cfg, 0.0).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.tokens, 3 * sess.cfg.seq_len);
        assert!(stats.latency.p95 >= stats.latency.p50);
        assert!(stats.latency.p99 >= stats.latency.p95);
        assert!(stats.tokens_per_sec > 0.0);
    }
}
