//! # zs-svd — Zero-Sum SVD for low-rank LLM compression
//!
//! Production-style reproduction of *"Zero Sum SVD: Balancing Loss
//! Sensitivity for Low Rank LLM Compression"* as a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`), AOT-lowered.
//! * **L2** — JAX model graphs (`python/compile/model.py`), AOT-lowered.
//! * **L3** — this crate: the compression engine (whitening, sensitivity
//!   scoring, zero-sum selection, correction), all baselines, the PJRT
//!   runtime that executes the AOT artifacts, the trainer, evaluation,
//!   serving, and the experiment harnesses for every table/figure.
//!
//! Python never runs at request time: after `make artifacts`, the `zs-svd`
//! binary is self-contained.
//!
//! See the top-level `README.md` for the crate layout, quickstart, and the
//! determinism guarantees every subsystem upholds.

// Public API documentation is part of the CI gate: ci.sh runs
// `cargo doc --no-deps` with RUSTDOCFLAGS="-D warnings", so an
// undocumented public item or a broken intra-doc link fails the build.
#![warn(missing_docs)]

pub mod util;
pub mod obs;
pub mod artifact;
pub mod exec;
pub mod tensor;
pub mod linalg;
pub mod data;
pub mod model;
pub mod runtime;
pub mod trainer;
pub mod compress;
pub mod decode;
pub mod eval;
pub mod serve;
pub mod server;
pub mod fleet;
pub mod coordinator;
pub mod config;
pub mod report;
