//! # zs-svd — Zero-Sum SVD for low-rank LLM compression
//!
//! Production-style reproduction of *"Zero Sum SVD: Balancing Loss
//! Sensitivity for Low Rank LLM Compression"* as a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`), AOT-lowered.
//! * **L2** — JAX model graphs (`python/compile/model.py`), AOT-lowered.
//! * **L3** — this crate: the compression engine (whitening, sensitivity
//!   scoring, zero-sum selection, correction), all baselines, the PJRT
//!   runtime that executes the AOT artifacts, the trainer, evaluation,
//!   serving, and the experiment harnesses for every table/figure.
//!
//! Python never runs at request time: after `make artifacts`, the `zs-svd`
//! binary is self-contained.

pub mod util;
pub mod exec;
pub mod tensor;
pub mod linalg;
pub mod data;
pub mod model;
pub mod runtime;
pub mod trainer;
pub mod compress;
pub mod decode;
pub mod eval;
pub mod serve;
pub mod server;
pub mod coordinator;
pub mod config;
pub mod report;
