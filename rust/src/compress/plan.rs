//! Compression plans: the materialized result of any method (ZS-SVD or a
//! baseline) — per-target replacements, factors, and storage accounting.
//!
//! Evaluation always goes through the dense recomposition (one dense fwd
//! artifact serves every method); serving benchmarks use `factors()` with
//! the fixed-rank Pallas artifacts (zero-padded, numerically exact).

use std::collections::BTreeMap;

use crate::model::{ConfigMeta, ParamStore};
use crate::tensor::{Mat, Tensor};

/// Materialized decision for one target matrix.
#[derive(Clone, Debug)]
pub struct TargetPlan {
    /// parameter name of the target
    pub name: String,
    /// rows (output dim)
    pub m: usize,
    /// cols (input dim)
    pub n: usize,
    /// final rank (kept components); == min(m,n) when dense
    pub rank: usize,
    /// keep the original dense matrix (factorization not worthwhile)
    pub dense: bool,
    /// dense W′ to splice into the parameter store
    pub replacement: Mat,
    /// low-rank factors (absent when dense)
    pub factors: Option<(Mat, Mat)>,
    /// fp16-equivalent parameter count this target stores
    pub stored_params: f64,
}

/// A complete compression decision: one [`TargetPlan`] per target plus
/// run metadata, ready to splice into a parameter store or serve as
/// low-rank factors.
#[derive(Clone, Debug)]
pub struct CompressionPlan {
    /// method label that produced the plan
    pub method: String,
    /// requested kept-parameter ratio
    pub ratio: f64,
    /// per-target decisions, in manifest target order
    pub targets: Vec<TargetPlan>,
    /// wall-clock seconds the compression took (Table 8)
    pub seconds: f64,
}

impl CompressionPlan {
    /// Splice replacements into a copy of the parameter store.
    pub fn apply(&self, params: &ParamStore) -> ParamStore {
        let mut out = params.clone();
        for t in &self.targets {
            if !t.dense {
                out.set(&t.name, Tensor::from_mat(&t.replacement));
            }
        }
        out
    }

    /// Factors for the low-rank serving artifacts.  Dense-kept targets fall
    /// back to an exact factorization only if `force` — otherwise they are
    /// reported as unservable via the fixed-rank artifact.
    pub fn factors(&self) -> BTreeMap<String, (Mat, Mat)> {
        self.targets
            .iter()
            .filter_map(|t| t.factors.clone().map(|f| (t.name.clone(), f)))
            .collect()
    }

    /// Look a target's plan up by name; panics on a miss.
    pub fn target(&self, name: &str) -> &TargetPlan {
        self.targets
            .iter()
            .find(|t| t.name == name)
            .unwrap_or_else(|| panic!("no target plan for {name}"))
    }

    /// fp16-equivalent parameters stored across all targets.
    pub fn stored_params(&self) -> f64 {
        self.targets.iter().map(|t| t.stored_params).sum()
    }

    /// Dense parameter count of the targets (the denominator of the ratio).
    pub fn dense_params(&self) -> f64 {
        self.targets.iter().map(|t| (t.m * t.n) as f64).sum()
    }

    /// Achieved storage ratio over the target matrices.
    pub fn achieved_ratio(&self) -> f64 {
        self.stored_params() / self.dense_params()
    }

    /// Whole-model fp16 bytes (targets at compressed size + everything else
    /// dense) — Table 7's weight-memory column.
    pub fn model_bytes(&self, cfg: &ConfigMeta) -> f64 {
        let non_target: usize = cfg.param_count() - cfg.target_param_count();
        (non_target as f64 + self.stored_params()) * 2.0
    }

    /// Heterogeneous rank histogram (diagnostics + Fig-3-style reporting).
    pub fn ranks(&self) -> BTreeMap<String, usize> {
        self.targets
            .iter()
            .map(|t| (t.name.clone(), if t.dense { t.m.min(t.n) } else { t.rank }))
            .collect()
    }
}

/// Storage cost of a rank-k factorization under standard accounting.
pub fn factored_params(m: usize, n: usize, k: usize) -> f64 {
    (k * (m + n)) as f64
}

/// Storage under Dobi-style remapping (Sec. 4.4): k·max(m,n) fp16-equivalent.
pub fn remap_params(m: usize, n: usize, k: usize) -> f64 {
    (k * m.max(n)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dummy_plan() -> CompressionPlan {
        let mut rng = Rng::new(1);
        let rep = Mat::randn(&mut rng, 8, 8, 0.1);
        let wu = Mat::randn(&mut rng, 8, 2, 0.1);
        let wv = Mat::randn(&mut rng, 2, 8, 0.1);
        CompressionPlan {
            method: "test".into(),
            ratio: 0.5,
            seconds: 0.0,
            targets: vec![
                TargetPlan {
                    name: "a".into(), m: 8, n: 8, rank: 2, dense: false,
                    replacement: rep.clone(), factors: Some((wu, wv)),
                    stored_params: factored_params(8, 8, 2),
                },
                TargetPlan {
                    name: "b".into(), m: 8, n: 8, rank: 8, dense: true,
                    replacement: rep, factors: None,
                    stored_params: 64.0,
                },
            ],
        }
    }

    #[test]
    fn storage_accounting() {
        let p = dummy_plan();
        assert_eq!(p.stored_params(), 32.0 + 64.0);
        assert_eq!(p.dense_params(), 128.0);
        assert!((p.achieved_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(factored_params(128, 352, 10), 4800.0);
        assert_eq!(remap_params(128, 352, 10), 3520.0);
    }

    #[test]
    fn factors_skip_dense() {
        let p = dummy_plan();
        let f = p.factors();
        assert!(f.contains_key("a"));
        assert!(!f.contains_key("b"));
    }

    #[test]
    fn ranks_report() {
        let p = dummy_plan();
        let r = p.ranks();
        assert_eq!(r["a"], 2);
        assert_eq!(r["b"], 8); // dense reports full rank
    }
}
