//! Baseline compression methods the paper compares against.
//!
//! SVD family (Tables 1/2/5/8): plain SVD, FWSVD (Fisher-weighted), ASVD
//! (activation channel scaling), SVD-LLM (whitened, homogeneous ranks), and
//! a Dobi-SVD cost simulator (per-layer rank-allocation optimization driven
//! by measured calibration loss — deliberately expensive, Table 8).
//!
//! Structured pruning family (Tables 3/4): magnitude (LLM-Pruner analog),
//! Wanda-sp, FLAP-like fluctuation pruning, and SliceGPT-like PCA slicing.
//! Pruning is emulated by structured masking with analytic storage
//! accounting; evaluation shares the dense fwd artifact (DESIGN.md §2).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use super::pipeline::Calibration;
use super::plan::{factored_params, CompressionPlan, TargetPlan};
use super::whiten::{factorize, whitened_svd};
use crate::linalg::{matmul, svd};
use crate::model::ParamStore;
use crate::runtime::session::Session;
use crate::tensor::{Mat, Tensor};

/// Homogeneous per-matrix rank at parameter ratio ρ: k = ⌊ρ·mn/(m+n)⌋.
pub fn homogeneous_rank(ratio: f64, m: usize, n: usize) -> usize {
    ((ratio * (m * n) as f64 / (m + n) as f64) as usize).max(1)
}

fn lowrank_plan_target(name: &str, wu: Mat, wv: Mat) -> TargetPlan {
    let (m, k) = (wu.rows, wu.cols);
    let n = wv.cols;
    let replacement = matmul(&wu, &wv);
    TargetPlan { name: name.to_string(), m, n, rank: k, dense: false,
                 replacement, factors: Some((wu, wv)),
                 stored_params: factored_params(m, n, k) }
}

// ---------------------------------------------------------------------------
// SVD family
// ---------------------------------------------------------------------------

/// Vanilla truncated SVD of the raw weights, homogeneous ranks.
pub fn svd_plain(sess: &Session, params: &ParamStore, ratio: f64) -> CompressionPlan {
    let t0 = Instant::now();
    let targets = sess.cfg.targets.iter().map(|t| {
        let w = params.get(&t.name).to_mat();
        let k = homogeneous_rank(ratio, w.rows, w.cols);
        let s = svd(&w);
        let (wu, wv) = crate::linalg::factor(&s, k);
        lowrank_plan_target(&t.name, wu, wv)
    }).collect();
    CompressionPlan { method: "svd".into(), ratio, targets,
                      seconds: t0.elapsed().as_secs_f64() }
}

/// FWSVD (Hsu et al. 2022): rows weighted by √(row-sum of the Fisher diag)
/// before SVD, unweighted after.
pub fn fwsvd(sess: &Session, params: &ParamStore, calib: &Calibration,
             ratio: f64) -> CompressionPlan {
    let t0 = Instant::now();
    let targets = sess.cfg.targets.iter().map(|t| {
        let w = params.get(&t.name).to_mat();
        let fisher = &calib.fisher[&t.name];
        let (m, n) = (w.rows, w.cols);
        // row importance I_r = Σ_c fisher[r,c]
        let mut d = vec![0.0f32; m];
        for r in 0..m {
            let s: f64 = fisher.row(r).iter().map(|&v| v as f64).sum();
            d[r] = (s.max(1e-12)).sqrt() as f32;
        }
        let mut dw = w.clone();
        for r in 0..m {
            let dr = d[r];
            for v in dw.row_mut(r) {
                *v *= dr;
            }
        }
        let k = homogeneous_rank(ratio, m, n);
        let s = svd(&dw);
        let (mut wu, wv) = crate::linalg::factor(&s, k);
        // unweight the left factor: W' = D^{-1} (DW)_k
        for r in 0..m {
            let inv = 1.0 / d[r];
            for v in wu.row_mut(r) {
                *v *= inv;
            }
        }
        lowrank_plan_target(&t.name, wu, wv)
    }).collect();
    CompressionPlan { method: "fwsvd".into(), ratio, targets,
                      seconds: t0.elapsed().as_secs_f64() }
}

/// ASVD (Yuan et al. 2025): per-channel scaling by mean |activation|^α.
pub fn asvd(sess: &Session, params: &ParamStore, calib: &Calibration,
            ratio: f64, alpha: f32) -> CompressionPlan {
    let t0 = Instant::now();
    let targets = sess.cfg.targets.iter().map(|t| {
        let w = params.get(&t.name).to_mat();
        let (m, n) = (w.rows, w.cols);
        let abssum = &calib.site_abssum[&t.site];
        let cnt = calib.token_count.max(1) as f32;
        let d: Vec<f32> = abssum.iter()
            .map(|&a| ((a / cnt).max(1e-6)).powf(alpha))
            .collect();
        // A = W·diag(d)
        let mut a = w.clone();
        for r in 0..m {
            for (c, v) in a.row_mut(r).iter_mut().enumerate() {
                *v *= d[c];
            }
        }
        let k = homogeneous_rank(ratio, m, n);
        let s = svd(&a);
        let (wu, mut wv) = crate::linalg::factor(&s, k);
        // W' = A_k·diag(1/d)
        for r in 0..wv.rows {
            for (c, v) in wv.row_mut(r).iter_mut().enumerate() {
                *v /= d[c];
            }
        }
        lowrank_plan_target(&t.name, wu, wv)
    }).collect();
    CompressionPlan { method: "asvd".into(), ratio, targets,
                      seconds: t0.elapsed().as_secs_f64() }
}

/// SVD-LLM (Wang et al. 2025b): truncation-aware whitening with the
/// closed-form homogeneous rank rule.
pub fn svdllm(sess: &Session, params: &ParamStore, calib: &Calibration,
              ratio: f64) -> CompressionPlan {
    let t0 = Instant::now();
    let targets = sess.cfg.targets.iter().map(|t| {
        let w = params.get(&t.name).to_mat();
        let c = &calib.site_xx[&t.site];
        let (s_factor, lambda, sv) = whitened_svd(&w, c);
        let k = homogeneous_rank(ratio, w.rows, w.cols);
        let kept: Vec<usize> = (0..k.min(sv.sigma.len())).collect();
        let d = super::whiten::TargetDecomp {
            name: t.name.clone(), m: w.rows, n: w.cols,
            s: s_factor, lambda, svd: sv, dl: vec![],
        };
        let (wu, wv) = factorize(&d, &kept);
        lowrank_plan_target(&t.name, wu, wv)
    }).collect();
    CompressionPlan { method: "svd-llm".into(), ratio, targets,
                      seconds: t0.elapsed().as_secs_f64() }
}

/// Dobi-SVD cost simulator: whitened SVD + iterative per-layer rank
/// allocation optimized against *measured* calibration loss.  Each proposal
/// re-materializes weights and runs forward passes — reproducing the
/// optimization-heavy cost profile of Table 8.
pub fn dobi_sim(sess: &Session, params: &ParamStore, calib: &Calibration,
                ratio: f64, sweeps: usize) -> Result<CompressionPlan> {
    let t0 = Instant::now();
    // whitened decompositions (no gradients — Dobi is loss-driven by search)
    let decomps: Vec<super::whiten::TargetDecomp> = sess.cfg.targets.iter()
        .map(|t| {
            let w = params.get(&t.name).to_mat();
            let (s_factor, lambda, sv) = whitened_svd(&w, &calib.site_xx[&t.site]);
            super::whiten::TargetDecomp {
                name: t.name.clone(), m: w.rows, n: w.cols,
                s: s_factor, lambda, svd: sv, dl: vec![],
            }
        })
        .collect();

    let mut ranks: Vec<usize> = decomps.iter()
        .map(|d| homogeneous_rank(ratio, d.m, d.n))
        .collect();

    let eval_loss = |ranks: &[usize]| -> Result<f32> {
        let mut p = params.clone();
        for (d, &k) in decomps.iter().zip(ranks) {
            let kept: Vec<usize> = (0..k.min(d.svd.sigma.len())).collect();
            let (wu, wv) = factorize(d, &kept);
            p.set(&d.name, Tensor::from_mat(&matmul(&wu, &wv)));
        }
        let (l, _) = sess.fwd(&p, &calib.batches[0])?;
        Ok(l)
    };

    let mut best = eval_loss(&ranks)?;
    // pairwise rank transfers keeping the parameter budget fixed
    for sweep in 0..sweeps {
        for i in 0..ranks.len() {
            let j = (i + 1 + sweep) % ranks.len();
            if i == j {
                continue;
            }
            let (ci, cj) = (decomps[i].m + decomps[i].n, decomps[j].m + decomps[j].n);
            // donate one unit from i, give ⌊ci/cj⌋ (≥1) to j — budget-neutral
            let gain = (ci / cj).max(1);
            if ranks[i] <= 2 {
                continue;
            }
            let mut cand = ranks.clone();
            cand[i] -= 1;
            cand[j] = (cand[j] + gain).min(decomps[j].svd.sigma.len());
            let l = eval_loss(&cand)?;
            if l < best {
                best = l;
                ranks = cand;
            }
        }
    }

    let targets = decomps.iter().zip(&ranks).map(|(d, &k)| {
        let kept: Vec<usize> = (0..k.min(d.svd.sigma.len())).collect();
        let (wu, wv) = factorize(d, &kept);
        lowrank_plan_target(&d.name, wu, wv)
    }).collect();
    Ok(CompressionPlan { method: "dobi-sim".into(), ratio, targets,
                         seconds: t0.elapsed().as_secs_f64() })
}

// ---------------------------------------------------------------------------
// structured pruning family
// ---------------------------------------------------------------------------

/// Scoring rule for the structured-pruning baselines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PruneScore {
    /// weight-magnitude (LLM-Pruner analog)
    Magnitude,
    /// |W|·‖x‖ activation-aware (Wanda-sp analog)
    WandaSp,
    /// input-fluctuation weighted (FLAP analog)
    Flap,
}

/// Structured MLP-neuron pruning: removes hidden neurons of every MLP
/// (rows of gate/up|win, columns of down|wout) until the *target-matrix*
/// parameter ratio hits ρ.  Attention is left dense (the usual structured-
/// pruning protocol for these baselines).
pub fn prune_structured(sess: &Session, params: &ParamStore,
                        calib: &Calibration, ratio: f64, score: PruneScore)
                        -> CompressionPlan {
    let t0 = Instant::now();
    let cfg = &sess.cfg;
    let total: f64 = cfg.targets.iter().map(|t| (t.shape.0 * t.shape.1) as f64).sum();
    let mlp_names: Vec<&str> = if cfg.arch == "llama" {
        vec!["wgate", "wup", "wdown"]
    } else {
        vec!["win", "wout"]
    };
    let mlp_total: f64 = cfg.targets.iter()
        .filter(|t| mlp_names.iter().any(|m| t.name.ends_with(m)))
        .map(|t| (t.shape.0 * t.shape.1) as f64)
        .sum();
    // (1-p)·mlp + (total-mlp) = ρ·total  =>  p = (1-ρ)·total / mlp
    let p = ((1.0 - ratio) * total / mlp_total).clamp(0.0, 0.97);
    let d_ff = cfg.d_ff;
    let keep = ((1.0 - p) * d_ff as f64).round().max(1.0) as usize;

    let mut targets = Vec::new();
    for layer in 0..cfg.n_layers {
        let prefix = format!("layers.{layer}.");
        // neuron scores over the ff dimension
        let mut scores = vec![0.0f64; d_ff];
        for t in cfg.targets.iter().filter(|t| t.name.starts_with(&prefix)) {
            let short = t.name.rsplit('.').next().unwrap();
            if !mlp_names.contains(&short) {
                continue;
            }
            let w = params.get(&t.name).to_mat();
            let up_like = w.rows == d_ff; // gate/up/win: neuron = row
            let site = &t.site;
            let diag_c = calib.site_xx[site].diag();
            let sum = &calib.site_sum[site];
            let cnt = calib.token_count.max(1) as f64;
            for j in 0..d_ff {
                let mut s = 0.0f64;
                match score {
                    PruneScore::Magnitude => {
                        if up_like {
                            s = w.row(j).iter().map(|&v| (v as f64).powi(2)).sum();
                        } else {
                            for r in 0..w.rows {
                                s += (w.at(r, j) as f64).powi(2);
                            }
                        }
                    }
                    PruneScore::WandaSp => {
                        if up_like {
                            // input channel norms of this site
                            let w_row: f64 = w.row(j).iter().enumerate()
                                .map(|(c, &v)| v.abs() as f64
                                     * (diag_c[c] as f64 / cnt).max(0.0).sqrt())
                                .sum();
                            s = w_row;
                        } else {
                            let xnorm = (diag_c[j] as f64 / cnt).max(0.0).sqrt();
                            for r in 0..w.rows {
                                s += w.at(r, j).abs() as f64 * xnorm;
                            }
                        }
                    }
                    PruneScore::Flap => {
                        if up_like {
                            s = w.row(j).iter().enumerate()
                                .map(|(c, &v)| {
                                    let mean = sum[c] as f64 / cnt;
                                    let var = (diag_c[c] as f64 / cnt - mean * mean).max(0.0);
                                    (v as f64).powi(2) * var
                                })
                                .sum();
                        } else {
                            let mean = sum[j] as f64 / cnt;
                            let var = (diag_c[j] as f64 / cnt - mean * mean).max(0.0);
                            for r in 0..w.rows {
                                s += (w.at(r, j) as f64).powi(2) * var;
                            }
                        }
                    }
                }
                scores[j] += s;
            }
        }
        let mut order: Vec<usize> = (0..d_ff).collect();
        order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
        let kept: std::collections::BTreeSet<usize> =
            order[..keep].iter().copied().collect();

        for t in cfg.targets.iter().filter(|t| t.name.starts_with(&prefix)) {
            let short = t.name.rsplit('.').next().unwrap();
            let w = params.get(&t.name).to_mat();
            let (m, n) = (w.rows, w.cols);
            if !mlp_names.contains(&short) {
                // attention stays dense
                targets.push(TargetPlan { name: t.name.clone(), m, n,
                                          rank: m.min(n), dense: true,
                                          replacement: w, factors: None,
                                          stored_params: (m * n) as f64 });
                continue;
            }
            let mut rep = w.clone();
            let up_like = m == d_ff;
            if up_like {
                for j in 0..d_ff {
                    if !kept.contains(&j) {
                        rep.row_mut(j).fill(0.0);
                    }
                }
            } else {
                for r in 0..m {
                    for j in 0..d_ff {
                        if !kept.contains(&j) {
                            *rep.at_mut(r, j) = 0.0;
                        }
                    }
                }
            }
            let stored = if up_like { (keep * n) as f64 } else { (m * keep) as f64 };
            targets.push(TargetPlan { name: t.name.clone(), m, n, rank: keep,
                                      dense: false, replacement: rep,
                                      factors: None, stored_params: stored });
        }
    }

    let label = match score {
        PruneScore::Magnitude => "llm-pruner",
        PruneScore::WandaSp => "wanda-sp",
        PruneScore::Flap => "flap",
    };
    CompressionPlan { method: label.into(), ratio, targets,
                      seconds: t0.elapsed().as_secs_f64() }
}

/// SliceGPT-like PCA slicing: project every target's input onto the top-q
/// principal directions of its site covariance (W′ = W·P·Pᵀ, storage m·q).
pub fn slicegpt_like(sess: &Session, params: &ParamStore, calib: &Calibration,
                     ratio: f64) -> CompressionPlan {
    let t0 = Instant::now();
    let mut site_proj: BTreeMap<String, Mat> = BTreeMap::new();
    let targets = sess.cfg.targets.iter().map(|t| {
        let w = params.get(&t.name).to_mat();
        let (m, n) = (w.rows, w.cols);
        let q = ((ratio * n as f64) as usize).clamp(1, n);
        let p = site_proj.entry(t.site.clone()).or_insert_with(|| {
            // eigenvectors of the symmetric PSD moment via SVD
            let c = &calib.site_xx[&t.site];
            let sv = svd(c);
            sv.u // n×n, columns = principal directions
        });
        // P_q·P_qᵀ projection
        let mut pq = Mat::zeros(n, q);
        for r in 0..n {
            for cidx in 0..q {
                pq.data[r * q + cidx] = p.data[r * p.cols + cidx];
            }
        }
        let wp = matmul(&w, &pq); // m×q
        let rep = matmul(&wp, &pq.transpose());
        TargetPlan { name: t.name.clone(), m, n, rank: q, dense: false,
                     replacement: rep, factors: None,
                     stored_params: (m * q) as f64 }
    }).collect();
    CompressionPlan { method: "slicegpt".into(), ratio, targets,
                      seconds: t0.elapsed().as_secs_f64() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::selection::k_threshold;

    #[test]
    fn homogeneous_rank_formula() {
        assert_eq!(homogeneous_rank(1.0, 128, 128), 64);
        assert_eq!(homogeneous_rank(0.5, 128, 128), 32);
        assert_eq!(homogeneous_rank(0.001, 128, 128), 1);
        // below the k_thr threshold for every rho < 1
        for &rho in &[0.2, 0.4, 0.6, 0.8] {
            let k = homogeneous_rank(rho, 352, 128);
            assert!(k <= k_threshold(352, 128));
        }
    }
}
