//! The ZS-SVD compression pipeline (paper Sec. 4): calibration →
//! whitened decomposition + sensitivity → global zero-sum selection →
//! truncation → optional truncate–correct–re-truncate iterations.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use super::correction::{correct, CorrectionKind};
use super::plan::{factored_params, remap_params, CompressionPlan, TargetPlan};
use super::selection::{select, Costing, SelectionResult, Strategy};
use super::whiten::{decompose_target, factorize, truncate_with_s,
                    TargetDecomp};
use crate::data::Corpus;
use crate::linalg::{gram, matmul};
use crate::model::quant::quant_dequant_int8;
use crate::model::{ConfigMeta, ParamStore};
use crate::obs;
use crate::runtime::session::Session;
use crate::util::json::Json;
use crate::tensor::{IntTensor, Mat};
use crate::util::rng::Rng;

/// Calibration statistics shared by every method: activation moments per
/// whitening site plus mean gradients / Fisher diagonals per target.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// the calibration token batches themselves (reused by correction)
    pub batches: Vec<IntTensor>,
    /// per-site Σ X Xᵀ activation second moments
    pub site_xx: BTreeMap<String, Mat>,
    /// per-site Σ x activation sums
    pub site_sum: BTreeMap<String, Vec<f32>>,
    /// per-site Σ |x| absolute activation sums
    pub site_abssum: BTreeMap<String, Vec<f32>>,
    /// tokens the site statistics were accumulated over
    pub token_count: usize,
    /// per-target mean calibration gradients
    pub grads: BTreeMap<String, Mat>,
    /// per-target Fisher diagonals (mean g²)
    pub fisher: BTreeMap<String, Mat>,
    /// mean calibration loss of the dense model
    pub base_loss: f32,
    /// seconds spent on the moments pass (whitening-statistics cost)
    pub moments_seconds: f64,
    /// seconds spent on the gradient pass (only loss-aware methods pay this)
    pub grads_seconds: f64,
}

impl Calibration {
    /// Deterministic synthetic calibration: random SPD site moments and
    /// random target gradients (zero Fisher).  Enough to drive the
    /// decomposition/selection machinery — used by the thread-scaling
    /// bench and the serial-vs-parallel equivalence tests, where real
    /// calibration forward passes would only add noise.  Pass at least one
    /// batch if correction iterations will run.
    pub fn synthetic(cfg: &ConfigMeta, seed: u64, batches: Vec<IntTensor>)
                     -> Calibration {
        let mut rng = Rng::new(seed);
        let mut site_xx = BTreeMap::new();
        let mut site_sum = BTreeMap::new();
        let mut site_abssum = BTreeMap::new();
        for s in &cfg.sites {
            let x = Mat::randn(&mut rng, 3 * s.dim, s.dim, 1.0);
            site_xx.insert(s.name.clone(), gram(&x));
            site_sum.insert(s.name.clone(), vec![0.0f32; s.dim]);
            site_abssum.insert(s.name.clone(), vec![1.0f32; s.dim]);
        }
        let mut grads = BTreeMap::new();
        let mut fisher = BTreeMap::new();
        for t in &cfg.targets {
            grads.insert(t.name.clone(),
                         Mat::randn(&mut rng, t.shape.0, t.shape.1, 0.05));
            fisher.insert(t.name.clone(), Mat::zeros(t.shape.0, t.shape.1));
        }
        Calibration {
            batches,
            site_xx,
            site_sum,
            site_abssum,
            token_count: 3 * cfg.d_model,
            grads,
            fisher,
            base_loss: 0.0,
            moments_seconds: 0.0,
            grads_seconds: 0.0,
        }
    }
}

/// Run the calibration passes.  The paper uses 256 × 2048-token sequences;
/// scaled to this testbed we default to `n_batches` of (batch × seq) each.
pub fn calibrate(sess: &Session, params: &ParamStore, corpus: &Corpus,
                 n_batches: usize, seed: u64) -> Result<Calibration> {
    let mut rng = Rng::new(seed);
    let batches: Vec<IntTensor> = (0..n_batches.max(1))
        .map(|_| corpus.calibration_batch(&mut rng, sess.cfg.batch, sess.cfg.seq_len))
        .collect();

    let t0 = Instant::now();
    let moments = sess.accumulate_moments(params, &batches)?;
    let moments_seconds = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let (base_loss, grads, fisher) = sess.mean_grads(params, &batches)?;
    let grads_seconds = t1.elapsed().as_secs_f64();

    let mut site_xx = BTreeMap::new();
    let mut site_sum = BTreeMap::new();
    let mut site_abssum = BTreeMap::new();
    let mut token_count = 0;
    for sm in moments {
        token_count = sm.count;
        site_xx.insert(sm.site.clone(), sm.xx);
        site_sum.insert(sm.site.clone(), sm.sum);
        site_abssum.insert(sm.site, sm.abssum);
    }

    Ok(Calibration { batches, site_xx, site_sum, site_abssum, token_count,
                     grads, fisher, base_loss, moments_seconds, grads_seconds })
}

/// Knobs of one ZS-SVD run (the paper's method variants).
#[derive(Clone, Debug)]
pub struct ZsOpts {
    /// kept-parameter ratio of the global budget
    pub ratio: f64,
    /// storage accounting (standard factored vs remap)
    pub costing: Costing,
    /// component-selection strategy (zero-sum vs the ablations)
    pub strategy: Strategy,
    /// truncate–correct–re-truncate iterations (0 = plain ZS-SVD)
    pub correction_iters: usize,
    /// which correction operator the iterations apply
    pub correction_kind: CorrectionKind,
    /// HQ: prune to half the footprint reduction, int8-quantize the rest
    pub hq: bool,
}

impl ZsOpts {
    /// The paper's default settings at one ratio.
    pub fn new(ratio: f64) -> ZsOpts {
        ZsOpts { ratio, costing: Costing::Standard, strategy: Strategy::ZeroSum,
                 correction_iters: 0, correction_kind: CorrectionKind::ProjGrad,
                 hq: false }
    }

    /// Table-row label for this variant.
    pub fn label(&self) -> String {
        let mut s = String::from("zs-svd");
        match self.costing {
            Costing::Remap => s.push('*'),
            Costing::Standard if self.hq => s.push('†'),
            _ => {}
        }
        if self.correction_iters > 0 {
            s.push_str(&format!(" {}x", self.correction_iters));
        }
        s
    }
}

/// Decompose every target in the whitened space with loss sensitivities.
///
/// Targets are independent, so the per-target work (Cholesky whitening +
/// Jacobi SVD + sensitivity) fans out across the `exec` worker pool.
/// Outputs land at their target's index, so the result is bit-identical to
/// the serial pass for any thread count (see `rust/tests/parallel_equiv.rs`).
pub fn decompose_all(sess: &Session, params: &ParamStore, calib: &Calibration)
                     -> Vec<TargetDecomp> {
    crate::exec::par_map(&sess.cfg.targets, |_, t| {
        let w = params.get(&t.name).to_mat();
        let c = &calib.site_xx[&t.site];
        let g = &calib.grads[&t.name];
        decompose_target(&t.name, &w, c, g)
    })
}

/// Full ZS-SVD compression.  `plan.seconds` covers decomposition +
/// selection + build + corrections (the truncation-time of Table 8, minus
/// the shared calibration passes which the caller times separately).
pub fn compress_zs(sess: &Session, params: &ParamStore, calib: &Calibration,
                   opts: &ZsOpts) -> Result<CompressionPlan> {
    let t0 = Instant::now();
    // HQ: halve the pruning depth, quantize everything that remains
    let sel_ratio = if opts.hq { (2.0 * opts.ratio).min(1.0) } else { opts.ratio };
    let quantize = opts.hq;

    // phase timing is always measured (one Instant pair per phase) so the
    // compress report works without tracing; the chrome-trace spans for the
    // same phases are emitted only when tracing is on
    let t_dec = Instant::now();
    let decomps = decompose_all(sess, params, calib);
    let decompose_s = t_dec.elapsed().as_secs_f64();
    phase_span("compress.decompose", t_dec, decompose_s, decomps.len());

    let t_sel = Instant::now();
    let selection = select(&decomps, sel_ratio, opts.costing, opts.strategy);
    let select_s = t_sel.elapsed().as_secs_f64();
    phase_span("compress.select", t_sel, select_s, selection.removed);

    // materialization (factorize + recomposition matmuls) is per-target
    // independent — fan out, order-preserving
    let t_build = Instant::now();
    let targets = crate::exec::par_map(&decomps, |_, d| {
        let kept = selection.kept[&d.name].clone();
        let dense = selection.keep_dense[&d.name];
        build_target(d, &kept, dense, opts.costing, quantize, params)
    });
    let build_s = t_build.elapsed().as_secs_f64();
    phase_span("compress.build", t_build, build_s, targets.len());

    let mut plan = CompressionPlan {
        method: opts.label(),
        ratio: opts.ratio,
        targets,
        seconds: 0.0,
    };

    let t_corr = Instant::now();
    for _ in 0..opts.correction_iters {
        apply_correction_iter(sess, params, calib, &mut plan, &decomps,
                              opts.correction_kind, quantize)?;
    }
    let correct_s = t_corr.elapsed().as_secs_f64();
    if opts.correction_iters > 0 {
        phase_span("compress.correct", t_corr, correct_s,
                   opts.correction_iters);
    }

    plan.seconds = t0.elapsed().as_secs_f64();
    stash_report(opts, &selection, calib,
                 [decompose_s, select_s, build_s, correct_s, plan.seconds]);
    Ok(plan)
}

/// Emit one compress-phase span onto the engine track (no-op when tracing
/// is off; the always-on report carries the same timing either way).
fn phase_span(name: &'static str, start: Instant, secs: f64, items: usize) {
    obs::emit_span(name, "compress", obs::us_of(start), (secs * 1e6) as u64,
                   obs::PID_ENGINE, obs::tid(),
                   vec![("items", Json::num(items as f64))]);
}

/// Assemble the per-matrix compress report and stash it in the obs layer.
/// Always on: `compress --report FILE` fetches it via `obs::report`, and
/// the cost is one small JSON tree per compression run.
fn stash_report(opts: &ZsOpts, sel: &SelectionResult, calib: &Calibration,
                [decompose_s, select_s, build_s, correct_s, total_s]: [f64; 5]) {
    let targets: Vec<Json> = sel.per_target.iter().map(|t| {
        Json::obj(vec![
            ("name", Json::str(&t.name)),
            ("m", Json::num(t.m as f64)),
            ("n", Json::num(t.n as f64)),
            ("rank", Json::num(t.rank as f64)),
            ("removed", Json::num(t.removed as f64)),
            ("dl_removed", Json::num(t.dl_removed)),
            ("keep_dense", Json::Bool(t.keep_dense)),
        ])
    }).collect();
    // the removal trajectory names targets via the per_target records,
    // which are in decomps order — same order the trajectory indexes
    let trajectory: Vec<Json> = sel.trajectory.iter().map(|p| {
        Json::obj(vec![
            ("target", Json::str(&sel.per_target[p.layer].name)),
            ("comp", Json::num(p.comp as f64)),
            ("dl", Json::num(p.dl as f64)),
            ("s", Json::num(p.s)),
        ])
    }).collect();
    let report = Json::obj(vec![
        ("type", Json::str("compress_report")),
        ("method", Json::str(&opts.label())),
        ("ratio", Json::num(opts.ratio)),
        ("selection", Json::obj(vec![
            ("final_s", Json::num(sel.final_s)),
            ("max_abs_s", Json::num(sel.max_abs_s)),
            ("saved_params", Json::num(sel.saved_params)),
            ("removed", Json::num(sel.removed as f64)),
            ("forced_pops", Json::num(sel.forced_pops as f64)),
        ])),
        ("timing_s", Json::obj(vec![
            // calibration passes are shared across methods and timed by the
            // caller; reported here so one file tells the whole cost story
            ("whitening_moments", Json::num(calib.moments_seconds)),
            ("calibration_grads", Json::num(calib.grads_seconds)),
            ("decompose", Json::num(decompose_s)),
            ("select", Json::num(select_s)),
            ("build", Json::num(build_s)),
            ("correct", Json::num(correct_s)),
            ("total", Json::num(total_s)),
        ])),
        ("targets", Json::Arr(targets)),
        ("trajectory", Json::Arr(trajectory)),
        ("trajectory_dropped", Json::num(sel.trajectory_dropped as f64)),
    ]);
    obs::set_report("compress", report);
}

fn build_target(d: &TargetDecomp, kept: &[usize], dense: bool,
                costing: Costing, quantize: bool, params: &ParamStore)
                -> TargetPlan {
    let (m, n) = (d.m, d.n);
    if dense {
        let w = params.get(&d.name).to_mat();
        let (replacement, stored) = if quantize {
            (quant_dequant_int8(&w), (m * n) as f64 * 0.5)
        } else {
            (w, (m * n) as f64)
        };
        return TargetPlan { name: d.name.clone(), m, n, rank: m.min(n),
                            dense: true, replacement, factors: None,
                            stored_params: stored };
    }
    let k = kept.len();
    let (mut wu, mut wv) = factorize(d, kept);
    if quantize {
        wu = quant_dequant_int8(&wu);
        wv = quant_dequant_int8(&wv);
    }
    let replacement = matmul(&wu, &wv);
    let mut stored = match costing {
        Costing::Standard => factored_params(m, n, k),
        Costing::Remap => remap_params(m, n, k),
    };
    if quantize {
        stored *= 0.5;
    }
    TargetPlan { name: d.name.clone(), m, n, rank: k, dense: false,
                 replacement, factors: Some((wu, wv)), stored_params: stored }
}

/// One truncate–correct–re-truncate iteration over every factored target.
/// The per-target correct + re-truncate (an SVD each) runs on the worker
/// pool; results are applied in order afterwards.
fn apply_correction_iter(sess: &Session, orig: &ParamStore, calib: &Calibration,
                         plan: &mut CompressionPlan, decomps: &[TargetDecomp],
                         kind: CorrectionKind, quantize: bool) -> Result<()> {
    // gradients at the *compressed* weights, small minibatch (paper: 4 seqs)
    anyhow::ensure!(!calib.batches.is_empty(),
                    "correction needs at least one calibration batch");
    let compressed = plan.apply(orig);
    let (_, grads, _) = sess.mean_grads(&compressed, &calib.batches[..1])?;

    let targets_ref = &plan.targets;
    let updates = crate::exec::par_map(decomps, |i, d| {
        let tp = &targets_ref[i];
        if tp.dense {
            return None;
        }
        let w_orig = orig.get(&tp.name).to_mat();
        let g = &grads[&tp.name];
        let w_plus = correct(kind, &w_orig, &tp.replacement, g);
        let (mut rep, (mut wu, mut wv)) = truncate_with_s(&w_plus, &d.s, tp.rank);
        if quantize {
            wu = quant_dequant_int8(&wu);
            wv = quant_dequant_int8(&wv);
            rep = matmul(&wu, &wv);
        }
        Some((rep, wu, wv))
    });
    for (tp, upd) in plan.targets.iter_mut().zip(updates) {
        if let Some((rep, wu, wv)) = upd {
            tp.replacement = rep;
            tp.factors = Some((wu, wv));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zs_label_variants() {
        let mut o = ZsOpts::new(0.6);
        assert_eq!(o.label(), "zs-svd");
        o.correction_iters = 5;
        assert_eq!(o.label(), "zs-svd 5x");
        o.costing = Costing::Remap;
        assert_eq!(o.label(), "zs-svd* 5x");
        o.costing = Costing::Standard;
        o.hq = true;
        assert_eq!(o.label(), "zs-svd† 5x");
    }

    #[test]
    fn hq_selection_ratio_doubles_retention() {
        let o = ZsOpts { hq: true, ..ZsOpts::new(0.4) };
        let sel = if o.hq { (2.0 * o.ratio).min(1.0) } else { o.ratio };
        assert!((sel - 0.8).abs() < 1e-12);
    }
}
