//! The compression engine — the paper's contribution (DESIGN.md §3, L3).
//!
//! * `whiten` — truncation-aware whitening + σ sensitivity (Sec. 3.3, 4.1)
//! * `selection` — zero-sum global budgeted truncation (Sec. 4.2, Alg. 1–2)
//! * `correction` — truncate–correct–re-truncate variants (Sec. 4.3, App. B.1)
//! * `plan` — materialized plans + storage accounting (Sec. 4.4 remap / HQ)
//! * `pipeline` — calibration + the end-to-end ZS-SVD flow
//! * `baselines` — ASVD/FWSVD/SVD-LLM/Dobi-sim + structured pruning
//!
//! # Determinism contract
//!
//! Every parallel path in here is **bit-identical to its serial
//! equivalent for any thread count**: per-target decomposition, plan
//! building, and the correction loop fan out with `exec::par_map` (results
//! land at their input index, so scheduling cannot reorder them), and the
//! calibration sums reduce through `exec::tree_reduce`'s fixed pairwise
//! tree, whose association order depends only on the batch count — never
//! on workers.  `rust/tests/parallel_equiv.rs` gates a full `compress_zs`
//! at threads {1, 2, 4}.  The same fixed-order-reduction discipline is what
//! the serving-side batched kernels uphold (see `crate::decode`), so a
//! compressed plan serves identically however it is scheduled.

pub mod baselines;
pub mod correction;
pub mod pipeline;
pub mod plan;
pub mod selection;
pub mod whiten;

pub use correction::CorrectionKind;
pub use pipeline::{calibrate, compress_zs, Calibration, ZsOpts};
pub use plan::CompressionPlan;
pub use selection::{Costing, Strategy};
