//! The light correction step (paper Sec. 4.3) and the ablation variants of
//! Table 9 / Appendix B.1.
//!
//! After truncation to W′_k, a single update briefly leaves the low-rank
//! manifold to recover first-order calibration loss, then re-truncation
//! returns to rank k.  The paper's variant (*Proj. Grad*) projects the
//! truncation residual ΔW = W − W′_k onto the gradient direction:
//!     ΔW′ = (⟨g, ΔW⟩ / ⟨g, g⟩) · g            (Eq. 13)
//! Because gradients near pretrained solutions are low effective rank
//! (Fig. 3/4), rank(W′_k + ΔW′) ≤ k + rank(g) stays near k and the
//! re-projection error is small (Lemma 4.1).

use crate::tensor::Mat;

/// Which correction operator a truncate–correct–re-truncate iteration
/// applies (the paper's Eq. 13 default plus the App. B.1 ablations).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CorrectionKind {
    /// the paper's one-step correction: project ΔW onto g (Eq. 13/27)
    ProjGrad,
    /// project g onto ΔW (Eq. 26) — ablation
    ProjDelta,
    /// Wα = (1−α)·W′_k + α·W (Eq. 23) — ablation
    AlphaBlend(f32),
    /// plain gradient step W⁺ = W′_k − η·g (Eq. 24) — ablation
    GradStep(f32),
}

impl CorrectionKind {
    /// Table-row label.
    pub fn label(&self) -> String {
        match self {
            CorrectionKind::ProjGrad => "proj-grad".into(),
            CorrectionKind::ProjDelta => "proj-delta".into(),
            CorrectionKind::AlphaBlend(a) => format!("alpha-{a}"),
            CorrectionKind::GradStep(eta) => format!("gd-{eta:.0e}"),
        }
    }
}

/// One correction update: W⁺ from (original W, truncated W′_k, gradient g at
/// W′_k).  The caller re-truncates W⁺ back to rank k afterwards.
pub fn correct(kind: CorrectionKind, w_orig: &Mat, w_trunc: &Mat, grad: &Mat) -> Mat {
    match kind {
        CorrectionKind::ProjGrad => {
            let delta = w_orig.sub(w_trunc);
            let gg = grad.dot(grad);
            if gg <= 1e-30 {
                return w_trunc.clone();
            }
            let coef = (grad.dot(&delta) / gg) as f32;
            w_trunc.add(&grad.scaled(coef))
        }
        CorrectionKind::ProjDelta => {
            let delta = w_orig.sub(w_trunc);
            let dd = delta.dot(&delta);
            if dd <= 1e-30 {
                return w_trunc.clone();
            }
            let coef = (grad.dot(&delta) / dd) as f32;
            w_trunc.add(&delta.scaled(coef))
        }
        CorrectionKind::AlphaBlend(alpha) => {
            w_trunc.scaled(1.0 - alpha).add(&w_orig.scaled(alpha))
        }
        CorrectionKind::GradStep(eta) => w_trunc.sub(&grad.scaled(eta)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mats(seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(&mut rng, 6, 8, 1.0);
        let wt = Mat::randn(&mut rng, 6, 8, 1.0);
        let g = Mat::randn(&mut rng, 6, 8, 0.3);
        (w, wt, g)
    }

    #[test]
    fn proj_grad_matches_first_order_identity() {
        // by construction ⟨g, ΔW′⟩ == ⟨g, ΔW⟩
        let (w, wt, g) = mats(1);
        let wplus = correct(CorrectionKind::ProjGrad, &w, &wt, &g);
        let dw_prime = wplus.sub(&wt);
        let dw = w.sub(&wt);
        assert!((g.dot(&dw_prime) - g.dot(&dw)).abs() < 1e-3 * g.dot(&dw).abs().max(1.0));
        // and ΔW′ is rank-1 in g: ΔW′ ∝ g
        let coef = dw_prime.data[0] / g.data[0];
        for (d, gv) in dw_prime.data.iter().zip(&g.data) {
            assert!((d - coef * gv).abs() < 1e-4);
        }
    }

    #[test]
    fn proj_grad_is_minimum_norm() {
        // among updates with the same ⟨g, Δ⟩, the projection has minimal
        // Frobenius norm — compare to ProjDelta which matches the inner
        // product only after scaling
        let (w, wt, g) = mats(2);
        let pg = correct(CorrectionKind::ProjGrad, &w, &wt, &g).sub(&wt);
        let dw = w.sub(&wt);
        let target = g.dot(&dw);
        // any other direction d with <g,d> = target has ||d|| >= ||pg||
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let rand_dir = Mat::randn(&mut rng, 6, 8, 1.0);
            let gd = g.dot(&rand_dir);
            if gd.abs() < 1e-9 {
                continue;
            }
            let scaled = rand_dir.scaled((target / gd) as f32);
            assert!(scaled.frob_norm() >= pg.frob_norm() - 1e-6);
        }
    }

    #[test]
    fn alpha_blend_endpoints() {
        let (w, wt, g) = mats(4);
        let a0 = correct(CorrectionKind::AlphaBlend(0.0), &w, &wt, &g);
        let a1 = correct(CorrectionKind::AlphaBlend(1.0), &w, &wt, &g);
        for (x, y) in a0.data.iter().zip(&wt.data) {
            assert!((x - y).abs() < 1e-6);
        }
        for (x, y) in a1.data.iter().zip(&w.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn grad_step_direction() {
        let (w, wt, g) = mats(5);
        let out = correct(CorrectionKind::GradStep(0.1), &w, &wt, &g);
        let step = wt.sub(&out); // == η·g
        for (s, gv) in step.data.iter().zip(&g.data) {
            assert!((s - 0.1 * gv).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_grad_is_noop() {
        let (w, wt, _) = mats(6);
        let g = Mat::zeros(6, 8);
        let out = correct(CorrectionKind::ProjGrad, &w, &wt, &g);
        assert_eq!(out, wt);
    }
}
