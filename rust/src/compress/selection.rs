//! Global budgeted truncation with zero-sum selection (paper Sec. 4.2,
//! Algorithms 1–2), plus the ablation strategies of Table 6.
//!
//! Components are pruned across ALL target matrices under one parameter-
//! removal budget.  The zero-sum rule keeps the running sum of predicted
//! loss changes near zero: two min-heaps keyed by |ΔL| partitioned by sign;
//! pop from Q+ when s ≤ 0, from Q− when s > 0 (Eq. 11), falling back to the
//! non-empty heap.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap};

use super::whiten::TargetDecomp;

/// Budget accounting mode (Sec. 4.4 + Appendix B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Costing {
    /// k(m+n) factored storage: drops are free while k > k_thr = ⌈mn/(m+n)⌉,
    /// then save (m+n) each; matrices ending above k_thr stay dense.
    Standard,
    /// Dobi-style packed remapping: each drop saves max(m,n) fp16-equivalent
    /// parameters from the first component on.
    Remap,
}

/// Global σ-selection strategy (Table 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// the paper's method: per-W spectral order + sign-balanced ΔL
    ZeroSum,
    /// greedily take the most negative ΔL
    MostNegative {
        /// keep each matrix's spectral pop order
        per_w_order: bool,
    },
    /// smallest |ΔL| first
    MagnitudeDl {
        /// keep each matrix's spectral pop order
        per_w_order: bool,
    },
    /// smallest σ first (loss-blind; per-W order is implied)
    SigmaSmallest,
}

/// Longest removal trajectory kept in a [`SelectionResult`].  Removals past
/// the cap still happen — only their per-step record is dropped (counted in
/// `trajectory_dropped`), so the result stays bounded on huge sweeps.
pub const TRAJECTORY_CAP: usize = 4096;

/// Per-target outcome of one selection run — the rows of the compress
/// report (`zs-svd compress --report`).  Always collected: one small
/// struct per target, independent of whether tracing is enabled.
#[derive(Clone, Debug)]
pub struct TargetRecord {
    /// target matrix name
    pub name: String,
    /// rows
    pub m: usize,
    /// cols
    pub n: usize,
    /// components kept
    pub rank: usize,
    /// components removed from this target
    pub removed: usize,
    /// sum of predicted ΔL over this target's removed components
    pub dl_removed: f64,
    /// target ended above k_thr and stays dense (Standard costing only)
    pub keep_dense: bool,
}

/// One removal step of the global selection loop: which component was
/// popped and where the running zero-sum budget stood afterwards.
#[derive(Clone, Copy, Debug)]
pub struct TrajectoryPoint {
    /// index into the `decomps` slice passed to [`select`]
    pub layer: usize,
    /// component index within that target
    pub comp: usize,
    /// the component's predicted loss change
    pub dl: f32,
    /// running sum s after this pop (the zero-sum budget, Eq. 11)
    pub s: f64,
}

/// Outcome of one global budgeted selection run.
#[derive(Clone, Debug)]
pub struct SelectionResult {
    /// kept component indices per target (sorted ascending = descending σ)
    pub kept: BTreeMap<String, Vec<usize>>,
    /// per-target: keep the original dense matrix (k ended above k_thr)
    pub keep_dense: BTreeMap<String, bool>,
    /// final running predicted-loss sum
    pub final_s: f64,
    /// |s| never exceeded this during selection
    pub max_abs_s: f64,
    /// fp16-equivalent parameters actually saved
    pub saved_params: f64,
    /// components removed
    pub removed: usize,
    /// pops where the sign-preferred heap was empty (drift can grow by one
    /// |ΔL| per forced pop; the zero-sum bound is conditional on balance)
    pub forced_pops: usize,
    /// per-target records in `decomps` order (compress-report rows)
    pub per_target: Vec<TargetRecord>,
    /// the first [`TRAJECTORY_CAP`] removal steps with the running budget
    pub trajectory: Vec<TrajectoryPoint>,
    /// removal steps past the cap whose records were not kept
    pub trajectory_dropped: usize,
}

/// Rank above which factored storage stops paying for an m-by-n matrix.
pub fn k_threshold(m: usize, n: usize) -> usize {
    // ⌈mn/(m+n)⌉ — factored storage beats dense strictly below this
    (m * n).div_ceil(m + n)
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    key: f32,
    layer: usize,
    comp: usize,
    dl: f32,
}

impl PartialEq for Entry {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Entry {
    fn cmp(&self, o: &Self) -> Ordering {
        // BinaryHeap is a max-heap; we want a min-heap on (key, layer, comp)
        o.key
            .total_cmp(&self.key)
            .then(o.layer.cmp(&self.layer))
            .then(o.comp.cmp(&self.comp))
    }
}

struct LayerState {
    rank: usize,   // components still kept
    removed: Vec<bool>,
    /// candidate feed, next-to-remove last (ordered mode: ascending σ means
    /// we pop indices r-1, r-2, ...)
    queue: Vec<usize>,
    m: usize,
    n: usize,
    kthr: usize,
}

fn key_for(strategy: Strategy, dl: f32, sigma: f32) -> f32 {
    match strategy {
        Strategy::ZeroSum => dl.abs(),
        Strategy::MostNegative { .. } => dl,
        Strategy::MagnitudeDl { .. } => dl.abs(),
        Strategy::SigmaSmallest => sigma,
    }
}

fn per_w_order(strategy: Strategy) -> bool {
    match strategy {
        Strategy::ZeroSum | Strategy::SigmaSmallest => true,
        Strategy::MostNegative { per_w_order } => per_w_order,
        Strategy::MagnitudeDl { per_w_order } => per_w_order,
    }
}

/// Run global selection at retention `ratio` over the decomposed targets.
pub fn select(decomps: &[TargetDecomp], ratio: f64, costing: Costing,
              strategy: Strategy) -> SelectionResult {
    assert!((0.0..=1.0).contains(&ratio), "ratio {ratio}");
    let total_params: f64 = decomps.iter().map(|d| (d.m * d.n) as f64).sum();
    let budget = (1.0 - ratio) * total_params;
    let ordered = per_w_order(strategy);

    let mut layers: Vec<LayerState> = decomps
        .iter()
        .map(|d| {
            let r = d.svd.sigma.len();
            LayerState {
                rank: r,
                removed: vec![false; r],
                // ordered: pop() yields r-1 (smallest σ) first
                queue: (0..r).collect(),
                m: d.m,
                n: d.n,
                kthr: k_threshold(d.m, d.n),
            }
        })
        .collect();

    // zero-sum needs two heaps; all other strategies use q_plus only.
    let mut q_plus: BinaryHeap<Entry> = BinaryHeap::new();
    let mut q_minus: BinaryHeap<Entry> = BinaryHeap::new();
    let zero_sum = matches!(strategy, Strategy::ZeroSum);

    let push = |qp: &mut BinaryHeap<Entry>, qm: &mut BinaryHeap<Entry>,
                    layer: usize, comp: usize| {
        let d = &decomps[layer];
        let e = Entry {
            key: key_for(strategy, d.dl[comp], d.svd.sigma[comp]),
            layer,
            comp,
            dl: d.dl[comp],
        };
        if zero_sum && e.dl < 0.0 {
            qm.push(e);
        } else {
            qp.push(e);
        }
    };

    // initialize candidate pools (Algorithm 1)
    for (li, st) in layers.iter_mut().enumerate() {
        if ordered {
            if let Some(c) = st.queue.pop() {
                push(&mut q_plus, &mut q_minus, li, c);
            }
        } else {
            while let Some(c) = st.queue.pop() {
                push(&mut q_plus, &mut q_minus, li, c);
            }
        }
    }

    // selection loop (Algorithm 2)
    let mut s = 0.0f64;
    let mut max_abs_s = 0.0f64;
    let mut saved = 0.0f64;
    let mut removed = 0usize;
    let mut forced_pops = 0usize;
    let mut dl_removed = vec![0.0f64; decomps.len()];
    let mut trajectory: Vec<TrajectoryPoint> = Vec::new();
    let mut trajectory_dropped = 0usize;

    while saved < budget && (!q_plus.is_empty() || !q_minus.is_empty()) {
        let e = if zero_sum {
            // prefer the sign that pulls s back toward zero (Eq. 11)
            if s <= 0.0 {
                q_plus.pop().or_else(|| {
                    forced_pops += 1;
                    q_minus.pop()
                })
            } else {
                q_minus.pop().or_else(|| {
                    forced_pops += 1;
                    q_plus.pop()
                })
            }
        } else {
            q_plus.pop()
        };
        let Some(e) = e else { break };

        let st = &mut layers[e.layer];
        // never drain a matrix below rank 1
        if st.rank <= 1 {
            continue;
        }
        st.removed[e.comp] = true;
        st.rank -= 1;
        removed += 1;
        s += e.dl as f64;
        max_abs_s = max_abs_s.max(s.abs());
        dl_removed[e.layer] += e.dl as f64;
        if trajectory.len() < TRAJECTORY_CAP {
            trajectory.push(TrajectoryPoint { layer: e.layer, comp: e.comp,
                                              dl: e.dl, s });
        } else {
            trajectory_dropped += 1;
        }

        // budget accounting
        let cost = match costing {
            Costing::Standard => {
                if st.rank <= st.kthr { (st.m + st.n) as f64 } else { 0.0 }
            }
            Costing::Remap => st.m.max(st.n) as f64,
        };
        saved += cost;

        // feed the matrix's next candidate (ordered mode)
        if ordered && st.rank > 1 {
            if let Some(c) = st.queue.pop() {
                push(&mut q_plus, &mut q_minus, e.layer, c);
            }
        }
    }

    let mut kept = BTreeMap::new();
    let mut keep_dense = BTreeMap::new();
    let mut per_target = Vec::with_capacity(decomps.len());
    for (li, (d, st)) in decomps.iter().zip(&layers).enumerate() {
        let kept_idx: Vec<usize> = (0..st.removed.len())
            .filter(|&i| !st.removed[i])
            .collect();
        let dense = match costing {
            Costing::Standard => kept_idx.len() > st.kthr,
            Costing::Remap => false,
        };
        per_target.push(TargetRecord {
            name: d.name.clone(),
            m: st.m,
            n: st.n,
            rank: kept_idx.len(),
            removed: st.removed.len() - kept_idx.len(),
            dl_removed: dl_removed[li],
            keep_dense: dense,
        });
        keep_dense.insert(d.name.clone(), dense);
        kept.insert(d.name.clone(), kept_idx);
    }

    SelectionResult { kept, keep_dense, final_s: s, max_abs_s,
                      saved_params: saved, removed, forced_pops,
                      per_target, trajectory, trajectory_dropped }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram;
    use crate::tensor::Mat;
    use crate::util::rng::Rng;

    fn decomps(seed: u64, shapes: &[(usize, usize)]) -> Vec<TargetDecomp> {
        let mut rng = Rng::new(seed);
        shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n))| {
                let w = Mat::randn(&mut rng, m, n, 0.5);
                let x = Mat::randn(&mut rng, 4 * n, n, 1.0);
                let c = gram(&x);
                let g = Mat::randn(&mut rng, m, n, 0.05);
                super::super::whiten::decompose_target(&format!("t{i}"), &w, &c, &g)
            })
            .collect()
    }

    #[test]
    fn budget_met_at_cost_granularity() {
        let ds = decomps(1, &[(16, 16), (24, 8), (8, 24), (32, 16)]);
        for ratio in [0.8, 0.5, 0.3] {
            let r = select(&ds, ratio, Costing::Standard, Strategy::ZeroSum);
            let total: f64 = ds.iter().map(|d| (d.m * d.n) as f64).sum();
            let budget = (1.0 - ratio) * total;
            assert!(r.saved_params >= budget,
                    "ratio {ratio}: saved {} < budget {budget}", r.saved_params);
            // overshoot bounded by one max-cost step
            let maxcost = ds.iter().map(|d| d.m + d.n).max().unwrap() as f64;
            assert!(r.saved_params < budget + maxcost);
        }
    }

    #[test]
    fn per_w_spectral_order_preserved() {
        let ds = decomps(2, &[(20, 12), (12, 20)]);
        let r = select(&ds, 0.5, Costing::Standard, Strategy::ZeroSum);
        for d in &ds {
            let kept = &r.kept[&d.name];
            // kept must be a prefix {0..k} (largest σ components)
            for (i, &c) in kept.iter().enumerate() {
                assert_eq!(c, i, "{}: kept {:?} is not a σ-prefix", d.name, kept);
            }
        }
    }

    #[test]
    fn zero_sum_drift_bounded() {
        let ds = decomps(3, &[(24, 24), (32, 16), (16, 32), (24, 16)]);
        let r = select(&ds, 0.4, Costing::Standard, Strategy::ZeroSum);
        // while both heaps are populated the drift is bounded by the
        // largest single |ΔL|; each forced same-sign pop can add one more
        let max_dl = ds
            .iter()
            .flat_map(|d| d.dl.iter())
            .fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
        let bound = max_dl * (2.0 + r.forced_pops as f64) + 1e-9;
        assert!(r.max_abs_s <= bound,
                "drift {} vs bound {bound}", r.max_abs_s);
    }

    #[test]
    fn zero_sum_beats_one_sided_drift() {
        let ds = decomps(4, &[(24, 24), (32, 16), (16, 32)]);
        let zs = select(&ds, 0.4, Costing::Standard, Strategy::ZeroSum);
        let neg = select(&ds, 0.4, Costing::Standard,
                         Strategy::MostNegative { per_w_order: true });
        assert!(zs.final_s.abs() <= neg.final_s.abs() + 1e-9,
                "zs {} vs most-neg {}", zs.final_s, neg.final_s);
    }

    #[test]
    fn remap_costing_saves_from_first_drop() {
        let ds = decomps(5, &[(16, 16)]);
        let r = select(&ds, 0.95, Costing::Remap, Strategy::ZeroSum);
        assert!(r.removed >= 1);
        assert_eq!(r.saved_params, (r.removed * 16) as f64);
        assert!(!r.keep_dense["t0"]);
    }

    #[test]
    fn standard_costing_free_until_threshold() {
        // at a mild ratio the square matrix must first cross k_thr=n/2
        let ds = decomps(6, &[(16, 16)]);
        let r = select(&ds, 0.9, Costing::Standard, Strategy::ZeroSum);
        let kept = r.kept["t0"].len();
        let kthr = k_threshold(16, 16);
        // to save ~0.1*256=25.6 params at 32/drop: one saving drop below thr
        assert!(kept <= kthr, "kept {kept} vs kthr {kthr}");
        assert!(r.saved_params >= 25.6);
    }

    #[test]
    fn min_rank_one_guard() {
        let ds = decomps(7, &[(8, 8), (8, 8)]);
        let r = select(&ds, 0.0, Costing::Standard, Strategy::ZeroSum);
        for d in &ds {
            assert!(!r.kept[&d.name].is_empty(), "{} fully drained", d.name);
        }
    }

    #[test]
    fn unordered_strategies_can_skip_spectral_order() {
        let ds = decomps(8, &[(20, 20)]);
        let r = select(&ds, 0.5, Costing::Standard,
                       Strategy::MostNegative { per_w_order: false });
        let kept = &r.kept["t0"];
        let is_prefix = kept.iter().enumerate().all(|(i, &c)| c == i);
        // with loss-greedy unordered selection a strict prefix would be a
        // coincidence; accept either but require a valid subset
        assert!(kept.len() < 20);
        let _ = is_prefix;
    }

    #[test]
    fn ratio_one_removes_nothing_below_threshold_cost() {
        let ds = decomps(9, &[(16, 16)]);
        let r = select(&ds, 1.0, Costing::Standard, Strategy::ZeroSum);
        assert_eq!(r.saved_params, 0.0);
        assert!(r.keep_dense["t0"]);
    }

    #[test]
    fn per_target_records_and_trajectory_are_consistent() {
        let ds = decomps(11, &[(24, 24), (32, 16), (16, 32)]);
        let r = select(&ds, 0.4, Costing::Standard, Strategy::ZeroSum);
        // records mirror the kept/keep_dense maps in decomps order
        assert_eq!(r.per_target.len(), ds.len());
        for (d, rec) in ds.iter().zip(&r.per_target) {
            assert_eq!(rec.name, d.name);
            assert_eq!(rec.rank, r.kept[&d.name].len());
            assert_eq!(rec.rank + rec.removed, d.svd.sigma.len());
            assert_eq!(rec.keep_dense, r.keep_dense[&d.name]);
        }
        assert_eq!(r.per_target.iter().map(|t| t.removed).sum::<usize>(),
                   r.removed);
        // trajectory: bounded, one point per recorded removal, running sum
        // matches the final s, per-layer ΔL totals match the records
        assert!(r.trajectory.len() <= TRAJECTORY_CAP);
        assert_eq!(r.trajectory.len() + r.trajectory_dropped, r.removed);
        if r.trajectory_dropped == 0 {
            let last_s = r.trajectory.last().map(|p| p.s).unwrap_or(0.0);
            assert!((last_s - r.final_s).abs() < 1e-9);
            for (li, rec) in r.per_target.iter().enumerate() {
                let sum: f64 = r.trajectory.iter()
                    .filter(|p| p.layer == li)
                    .map(|p| p.dl as f64)
                    .sum();
                assert!((sum - rec.dl_removed).abs() < 1e-9,
                        "layer {li}: {} vs {}", sum, rec.dl_removed);
            }
        }
    }

    #[test]
    fn sigma_strategy_matches_smallest_sigma() {
        let ds = decomps(10, &[(12, 12), (12, 12)]);
        let r = select(&ds, 0.6, Costing::Standard, Strategy::SigmaSmallest);
        // kept prefixes, and the *global* removal order was by σ: verify the
        // smallest kept σ across matrices ≥ the largest removed σ is NOT
        // required (budget interleaves), but within each matrix prefix holds
        for d in &ds {
            for (i, &c) in r.kept[&d.name].iter().enumerate() {
                assert_eq!(c, i);
            }
        }
    }
}
