//! Truncation-aware whitening (paper Sec. 3.2–3.3) and the whitened
//! singular-value sensitivity scores (Sec. 4.1).
//!
//! For each target W (m×n) with calibration second moment C = X·Xᵀ:
//!   S = chol(C + λI),   A = W·S = U Σ Vᵀ           (whitened SVD)
//!   H = G_W · S⁻ᵀ                                   (whitened gradient)
//!   g_σ = diag(Uᵀ H V),  ΔL_i = −σ_i · g_σ,i        (Eq. 9–10)
//! Mapping back: W′ = A_k · S⁻¹ with factors (Eq. 5)
//!   W′_u = U_k √Σ_k,  W′_v = √Σ_k V_kᵀ S⁻¹.

use crate::linalg::{cholesky_ridge, matmul, right_solve_lower,
                    right_solve_lower_t, svd, Svd};
use crate::tensor::Mat;

/// Whitened decomposition of one target matrix plus its per-component
/// predicted loss changes.
#[derive(Clone, Debug)]
pub struct TargetDecomp {
    /// parameter name of the decomposed target
    pub name: String,
    /// rows (output dim)
    pub m: usize,
    /// cols (input dim)
    pub n: usize,
    /// lower-triangular whitening factor S (n×n), S·Sᵀ = C + λI
    pub s: Mat,
    /// ridge actually used
    pub lambda: f32,
    /// SVD of A = W·S
    pub svd: Svd,
    /// ΔL_i = −σ_i · g_σ,i per component (same order as svd.sigma)
    pub dl: Vec<f32>,
}

/// Scale-aware default ridge: 1e-6 · mean(diag C) + tiny absolute floor.
pub fn default_ridge(c: &Mat) -> f32 {
    let n = c.rows.max(1);
    let tr: f64 = c.diag().iter().map(|&v| v as f64).sum();
    (1e-6 * (tr / n as f64)).max(1e-8) as f32
}

/// Cholesky whitening factor of a raw second moment.
pub fn whitening_factor(c: &Mat) -> (Mat, f32) {
    cholesky_ridge(c, default_ridge(c))
}

/// Whitened SVD of W against a site moment C = Σ X Xᵀ.
pub fn whitened_svd(w: &Mat, c: &Mat) -> (Mat, f32, Svd) {
    let (s, lambda) = whitening_factor(c);
    let a = matmul(w, &s);
    (s, lambda, svd(&a))
}

/// Whitened gradient H = G · S⁻ᵀ (S lower-triangular).
pub fn whitened_gradient(g: &Mat, s: &Mat) -> Mat {
    right_solve_lower_t(g, s)
}

/// g_σ = diag(Uᵀ H V): first-order sensitivity of the loss to each singular
/// value of the whitened matrix.
pub fn sigma_sensitivity(decomp: &Svd, h: &Mat) -> Vec<f32> {
    // HV: m×r, then g_i = u_i · (HV)_i
    let hv = matmul(h, &decomp.v);
    let r = decomp.sigma.len();
    let m = decomp.u.rows;
    let mut g = vec![0.0f32; r];
    for i in 0..r {
        let mut acc = 0.0f64;
        for row in 0..m {
            acc += decomp.u.data[row * decomp.u.cols + i] as f64
                * hv.data[row * hv.cols + i] as f64;
        }
        g[i] = acc as f32;
    }
    g
}

/// Build the full decomposition for one target.
pub fn decompose_target(name: &str, w: &Mat, c: &Mat, grad: &Mat) -> TargetDecomp {
    let (s, lambda, sv) = whitened_svd(w, c);
    let h = whitened_gradient(grad, &s);
    let g_sigma = sigma_sensitivity(&sv, &h);
    let dl: Vec<f32> = sv
        .sigma
        .iter()
        .zip(&g_sigma)
        .map(|(&sig, &g)| -sig * g)
        .collect();
    TargetDecomp { name: name.to_string(), m: w.rows, n: w.cols, s, lambda, svd: sv, dl }
}

/// Recompose a dense W′ from an arbitrary kept-component subset:
/// W′ = (Σ_{i∈kept} σ_i u_i v_iᵀ) · S⁻¹.
pub fn recompose(d: &TargetDecomp, kept: &[usize]) -> Mat {
    let (m, n) = (d.m, d.n);
    let mut a = Mat::zeros(m, n);
    for &i in kept {
        let sig = d.svd.sigma[i];
        if sig == 0.0 {
            continue;
        }
        for r in 0..m {
            let us = d.svd.u.data[r * d.svd.u.cols + i] * sig;
            if us == 0.0 {
                continue;
            }
            let arow = &mut a.data[r * n..(r + 1) * n];
            for q in 0..n {
                arow[q] += us * d.svd.v.data[q * d.svd.v.cols + i];
            }
        }
    }
    right_solve_lower(&a, &d.s)
}

/// Factored form over a kept subset: W′_u (m×k), W′_v = √Σ V_kᵀ S⁻¹ (k×n),
/// with W′ = W′_u · W′_v.
pub fn factorize(d: &TargetDecomp, kept: &[usize]) -> (Mat, Mat) {
    let (m, n) = (d.m, d.n);
    let k = kept.len();
    let mut wu = Mat::zeros(m, k);
    let mut p = Mat::zeros(k, n); // √Σ V_kᵀ (whitened coords)
    for (col, &i) in kept.iter().enumerate() {
        let h = d.svd.sigma[i].max(0.0).sqrt();
        for r in 0..m {
            wu.data[r * k + col] = d.svd.u.data[r * d.svd.u.cols + i] * h;
        }
        for q in 0..n {
            p.data[col * n + q] = d.svd.v.data[q * d.svd.v.cols + i] * h;
        }
    }
    let wv = right_solve_lower(&p, &d.s);
    (wu, wv)
}

/// Rank-k truncation of `w` in the whitened coordinates of a FIXED factor S
/// (used by re-truncation after a correction step: same whitening, new W).
/// Returns (dense W′, (W′_u, W′_v)).
pub fn truncate_with_s(w: &Mat, s: &Mat, k: usize) -> (Mat, (Mat, Mat)) {
    let a = matmul(w, s);
    let sv = svd(&a);
    let k = k.min(sv.sigma.len());
    let (wu, p) = crate::linalg::factor(&sv, k);
    let wv = right_solve_lower(&p, s);
    (matmul(&wu, &wv), (wu, wv))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gram, matmul};
    use crate::util::rng::Rng;

    fn setup(m: usize, n: usize, tokens: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(&mut rng, m, n, 0.5);
        let x = Mat::randn(&mut rng, tokens, n, 1.0);
        let c = gram(&x);
        let g = Mat::randn(&mut rng, m, n, 0.1);
        (w, c, g)
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "{x} vs {y}");
        }
    }

    #[test]
    fn full_rank_recomposition_is_identity() {
        let (w, c, g) = setup(12, 9, 64, 1);
        let d = decompose_target("t", &w, &c, &g);
        let all: Vec<usize> = (0..d.svd.sigma.len()).collect();
        assert_close(&recompose(&d, &all), &w, 5e-3);
        let (wu, wv) = factorize(&d, &all);
        assert_close(&matmul(&wu, &wv), &w, 5e-3);
    }

    #[test]
    fn truncation_error_matches_theorem_3_1() {
        // ||W X − W′_k X||_F² == Σ_{i>k} σ_i²  (Theorem 3.1), checked with
        // the exact C (λ ridge makes it approximate; tolerance accounts).
        let (w, c, g) = setup(10, 8, 128, 2);
        let d = decompose_target("t", &w, &c, &g);
        let k = 4;
        let kept: Vec<usize> = (0..k).collect();
        let wk = recompose(&d, &kept);
        // tr((W−W′) C (W−W′)ᵀ)
        let diff = w.sub(&wk);
        let err = matmul(&matmul(&diff, &c), &diff.transpose())
            .diag()
            .iter()
            .map(|&v| v as f64)
            .sum::<f64>();
        let tail: f64 = d.svd.sigma[k..].iter().map(|&s| (s as f64).powi(2)).sum();
        assert!((err - tail).abs() / tail.max(1e-6) < 2e-2,
                "err {err} vs tail {tail}");
    }

    #[test]
    fn factorize_matches_recompose_on_subset() {
        let (w, c, g) = setup(9, 11, 64, 3);
        let d = decompose_target("t", &w, &c, &g);
        let kept = vec![0, 2, 5];
        let (wu, wv) = factorize(&d, &kept);
        assert_close(&matmul(&wu, &wv), &recompose(&d, &kept), 1e-3);
    }

    #[test]
    fn dl_first_order_prediction_tracks_quadratic_loss() {
        // For L(W) = ½||W X − Y||² the gradient at W is (WX−Y)Xᵀ; dropping
        // component i changes L by ΔL_i to first order.  Verify sign+scale
        // against the true loss change for small perturbations.
        let mut rng = Rng::new(4);
        let (m, n, t) = (6, 5, 200);
        let w = Mat::randn(&mut rng, m, n, 0.3);
        let x = Mat::randn(&mut rng, t, n, 1.0); // rows are tokens
        let xt = x.transpose(); // n×t
        let y = {
            let mut target = matmul(&w, &xt);
            let noise = Mat::randn(&mut rng, m, t, 0.05);
            target.add_assign(&noise);
            target
        };
        let loss = |wm: &Mat| -> f64 {
            let r = matmul(wm, &xt).sub(&y);
            0.5 * r.dot(&r)
        };
        let grad = {
            let r = matmul(&w, &xt).sub(&y);
            matmul(&r, &x)
        };
        let c = gram(&x);
        let d = decompose_target("t", &w, &c, &grad);
        let base = loss(&w);
        let r = d.svd.sigma.len();
        // For the quadratic loss the drop of component i has the EXACT
        // expansion  ΔL_actual = ΔL_first_order + ½·σ_i²  (the perturbation
        // is δ = −σ u vᵀ S⁻¹ with ‖δX‖² = σ²).  Verify the first-order term
        // our sensitivity machinery predicts against that closed form.
        for i in 0..r {
            let kept: Vec<usize> = (0..r).filter(|&j| j != i).collect();
            let w_drop = recompose(&d, &kept);
            let actual = loss(&w_drop) - base;
            let sigma2 = (d.svd.sigma[i] as f64).powi(2);
            let predicted = d.dl[i] as f64 + 0.5 * sigma2;
            assert!(
                (actual - predicted).abs()
                    <= 0.05 * actual.abs().max(predicted.abs()).max(1e-3),
                "component {i}: actual {actual} vs predicted {predicted}"
            );
        }
    }

    #[test]
    fn sensitivity_shapes() {
        let (w, c, g) = setup(7, 13, 64, 5);
        let d = decompose_target("t", &w, &c, &g);
        assert_eq!(d.dl.len(), 7.min(13));
        assert_eq!(d.svd.u.rows, 7);
        assert_eq!(d.svd.v.rows, 13);
        assert!(d.dl.iter().all(|v| v.is_finite()));
    }
}
