//! Experiment configuration: typed view over a JSON config file with
//! defaults, used by the CLI and the bench harnesses.

use std::path::{Path, PathBuf};

use crate::util::json::{parse, Json};

/// One experiment's settings: model/family, training + calibration sizes,
/// serving shape, and output locations.  Parsed from JSON with per-field
/// defaults; every CLI flag overrides one field.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// model config name from the manifest ("tiny", "small", "opt_tiny")
    pub model: String,
    /// training-corpus family ("llama", "vicuna", ...) — picks the mix
    pub family: String,
    /// pretraining steps (checkpoint-cached)
    pub train_steps: usize,
    /// peak pretraining learning rate
    pub train_lr: f64,
    /// calibration batches (the paper's 256×2048 scaled down)
    pub calib_batches: usize,
    /// eval sizes
    pub ppl_batches: usize,
    /// zero-shot instances per task family
    pub instances_per_family: usize,
    /// compression ratios to sweep
    pub ratios: Vec<f64>,
    /// experiment seed (training, calibration, serving defaults)
    pub seed: u64,
    /// worker threads for the `exec` pool (0 = auto: `PALLAS_THREADS` env
    /// var, else available parallelism)
    pub threads: usize,
    /// force the portable (non-SIMD) kernel backend — the config-file twin
    /// of the `PALLAS_NO_SIMD` environment variable and the `--no-simd`
    /// CLI flag.  The SIMD and portable backends are bit-identical
    /// (`rust/tests/kernel_equiv.rs`), so this knob can change throughput
    /// but never results; it exists for debugging and CI's dual-backend
    /// lanes.
    pub no_simd: bool,
    /// continuous-batching slots for the decode serving path
    pub decode_slots: usize,
    /// per-request generation budget for the decode serving path
    pub max_new_tokens: usize,
    /// admission-queue depth for the network server (`serve --listen`)
    pub queue_depth: usize,
    /// prompt tokens a prefilling slot ingests per scheduler iteration
    /// through the batched kernels (`serve --prefill-chunk`); 0 = the whole
    /// prompt in one iteration.  Generated tokens are identical for every
    /// chunk size — the knob trades single-iteration latency (smaller
    /// chunks let decode steps interleave with a long prompt's prefill)
    /// against peak prefill throughput (larger chunks batch more rows per
    /// GEMM).
    pub prefill_chunk: usize,
    /// draft tokens per slot per iteration for speculative self-decode
    /// (`serve --speculate-k`); 0 disables speculation.  Greedy output is
    /// bit-identical for every value — the knob only changes how many
    /// tokens commit per target verification call.
    pub speculate_k: usize,
    /// positions per paged KV block (`serve --kv-block`); 0 selects the
    /// built-in default.  Storage granularity only — generated tokens are
    /// bit-identical for every block size.
    pub kv_block: usize,
    /// prefix-sharing cache capacity in KV blocks (`serve
    /// --prefix-cache`); 0 disables it.  Repeated prompts skip prefill
    /// for their cached block-aligned prefix; outputs are bit-identical
    /// with the cache on or off.
    pub prefix_cache_blocks: usize,
    /// enable the observability layer (`rust/src/obs/`) — the config-file
    /// twin of the `PALLAS_TRACE` environment variable and the `--trace` /
    /// `--trace-out` CLI flags.  Tracing is observe-only: plans, logits,
    /// and generated tokens are bit-identical with it on or off
    /// (`rust/tests/trace_equiv.rs`), so this is a diagnostics knob, never
    /// a results knob.
    pub trace: bool,
    /// packed artifact manifest to serve from (`serve --artifact`); empty
    /// = build the engine in-process instead.  A server started on an
    /// artifact supports live `reload` hot-swap (see `crate::artifact`)
    pub artifact: String,
    /// default chunk-store root for `pack` / `install` (`--out` / `--to`
    /// override it per invocation)
    pub artifact_store: String,
    /// where checkpoints live
    pub ckpt_dir: PathBuf,
    /// where result tables are appended
    pub out_dir: PathBuf,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        ExperimentConfig {
            model: "tiny".into(),
            family: "llama".into(),
            train_steps: 300,
            train_lr: 3e-3,
            calib_batches: 8,
            ppl_batches: 6,
            instances_per_family: 48,
            ratios: vec![0.8, 0.6, 0.4],
            seed: 7,
            threads: 0,
            no_simd: false,
            decode_slots: 4,
            max_new_tokens: 32,
            queue_depth: 64,
            prefill_chunk: 16,
            speculate_k: 0,
            kv_block: 16,
            prefix_cache_blocks: 0,
            trace: false,
            artifact: String::new(),
            artifact_store: root.join("artifacts").join("store")
                .to_string_lossy().into_owned(),
            ckpt_dir: root.join("artifacts").join("ckpts"),
            out_dir: root.join("results"),
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON object, defaulting every missing field.
    pub fn from_json(j: &Json) -> ExperimentConfig {
        let d = ExperimentConfig::default();
        ExperimentConfig {
            model: j.str_or("model", &d.model),
            family: j.str_or("family", &d.family),
            train_steps: j.usize_or("train_steps", d.train_steps),
            train_lr: j.f64_or("train_lr", d.train_lr),
            calib_batches: j.usize_or("calib_batches", d.calib_batches),
            ppl_batches: j.usize_or("ppl_batches", d.ppl_batches),
            instances_per_family: j.usize_or("instances_per_family",
                                             d.instances_per_family),
            ratios: j
                .get("ratios")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or(d.ratios),
            seed: j.f64_or("seed", d.seed as f64) as u64,
            threads: j.usize_or("threads", d.threads),
            no_simd: j.bool_or("no_simd", d.no_simd),
            decode_slots: j.usize_or("decode_slots", d.decode_slots),
            max_new_tokens: j.usize_or("max_new_tokens", d.max_new_tokens),
            queue_depth: j.usize_or("queue_depth", d.queue_depth),
            prefill_chunk: j.usize_or("prefill_chunk", d.prefill_chunk),
            speculate_k: j.usize_or("speculate_k", d.speculate_k),
            kv_block: j.usize_or("kv_block", d.kv_block),
            prefix_cache_blocks: j.usize_or("prefix_cache_blocks",
                                            d.prefix_cache_blocks),
            trace: j.bool_or("trace", d.trace),
            artifact: j.str_or("artifact", &d.artifact),
            artifact_store: j.str_or("artifact_store", &d.artifact_store),
            ckpt_dir: j
                .get("ckpt_dir")
                .and_then(Json::as_str)
                .map(PathBuf::from)
                .unwrap_or(d.ckpt_dir),
            out_dir: j
                .get("out_dir")
                .and_then(Json::as_str)
                .map(PathBuf::from)
                .unwrap_or(d.out_dir),
        }
    }

    /// Read + parse a config file.
    pub fn from_file(path: &Path) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Ok(Self::from_json(&parse(&text)?))
    }

    /// Serialize (the round-trip inverse of `from_json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("family", Json::str(&self.family)),
            ("train_steps", Json::num(self.train_steps as f64)),
            ("train_lr", Json::num(self.train_lr)),
            ("calib_batches", Json::num(self.calib_batches as f64)),
            ("ppl_batches", Json::num(self.ppl_batches as f64)),
            ("instances_per_family", Json::num(self.instances_per_family as f64)),
            ("ratios", Json::arr(self.ratios.iter().map(|&r| Json::num(r)))),
            ("seed", Json::num(self.seed as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("no_simd", Json::Bool(self.no_simd)),
            ("decode_slots", Json::num(self.decode_slots as f64)),
            ("max_new_tokens", Json::num(self.max_new_tokens as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("prefill_chunk", Json::num(self.prefill_chunk as f64)),
            ("speculate_k", Json::num(self.speculate_k as f64)),
            ("kv_block", Json::num(self.kv_block as f64)),
            ("prefix_cache_blocks",
             Json::num(self.prefix_cache_blocks as f64)),
            ("trace", Json::Bool(self.trace)),
            ("artifact", Json::str(&self.artifact)),
            ("artifact_store", Json::str(&self.artifact_store)),
            ("ckpt_dir", Json::str(self.ckpt_dir.to_str().unwrap_or("."))),
            ("out_dir", Json::str(self.out_dir.to_str().unwrap_or("."))),
        ])
    }

    /// Fast-mode shrink for CI / ZS_BENCH_FAST.
    pub fn shrunk(mut self) -> Self {
        self.train_steps = self.train_steps.min(60);
        self.calib_batches = self.calib_batches.min(2);
        self.ppl_batches = self.ppl_batches.min(2);
        self.instances_per_family = self.instances_per_family.min(12);
        self.max_new_tokens = self.max_new_tokens.min(8);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let c = ExperimentConfig::default();
        let j = c.to_json();
        let back = ExperimentConfig::from_json(&j);
        assert_eq!(back.model, c.model);
        assert_eq!(back.train_steps, c.train_steps);
        assert_eq!(back.ratios, c.ratios);
        assert_eq!(back.ckpt_dir, c.ckpt_dir);
        assert_eq!(back.decode_slots, c.decode_slots);
        assert_eq!(back.max_new_tokens, c.max_new_tokens);
        assert_eq!(back.queue_depth, c.queue_depth);
        assert_eq!(back.prefill_chunk, c.prefill_chunk);
        assert_eq!(back.speculate_k, c.speculate_k);
        assert_eq!(back.kv_block, c.kv_block);
        assert_eq!(back.prefix_cache_blocks, c.prefix_cache_blocks);
        assert_eq!(back.no_simd, c.no_simd);
        assert_eq!(back.trace, c.trace);
        assert_eq!(back.artifact, c.artifact);
        assert_eq!(back.artifact_store, c.artifact_store);

        let forced = ExperimentConfig {
            no_simd: true,
            speculate_k: 3,
            kv_block: 8,
            prefix_cache_blocks: 256,
            trace: true,
            artifact: "store/tiny-zs60.zsar".into(),
            artifact_store: "/tmp/zs-store".into(),
            ..ExperimentConfig::default()
        };
        let back = ExperimentConfig::from_json(&forced.to_json());
        assert!(back.no_simd, "no_simd must survive the roundtrip");
        assert_eq!(back.speculate_k, 3);
        assert_eq!(back.kv_block, 8);
        assert_eq!(back.prefix_cache_blocks, 256);
        assert!(back.trace, "trace must survive the roundtrip");
        assert_eq!(back.artifact, "store/tiny-zs60.zsar");
        assert_eq!(back.artifact_store, "/tmp/zs-store");
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = parse(r#"{"model": "small", "ratios": [0.7]}"#).unwrap();
        let c = ExperimentConfig::from_json(&j);
        assert_eq!(c.model, "small");
        assert_eq!(c.ratios, vec![0.7]);
        assert_eq!(c.family, "llama");
        assert_eq!(c.train_steps, 300);
    }

    #[test]
    fn shrunk_bounds() {
        let c = ExperimentConfig::default().shrunk();
        assert!(c.train_steps <= 60);
        assert!(c.calib_batches <= 2);
    }
}
