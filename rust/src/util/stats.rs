//! Small numeric/statistics helpers shared by eval, serving and benches.

use crate::util::json::Json;

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Fold one sample into the running moments.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Samples folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 before any sample).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (0 with fewer than two samples).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Summary of a sample: mean/std/median/p95/p99/min/max.
#[derive(Clone, Debug)]
pub struct Summary {
    /// sample count
    pub n: usize,
    /// arithmetic mean
    pub mean: f64,
    /// sample standard deviation
    pub std: f64,
    /// 50th percentile
    pub median: f64,
    /// 95th percentile
    pub p95: f64,
    /// 99th percentile
    pub p99: f64,
    /// smallest sample
    pub min: f64,
    /// largest sample
    pub max: f64,
}

/// Full summary of a non-empty sample.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mut w = Welford::default();
    for &x in xs {
        w.push(x);
    }
    Summary {
        n: xs.len(),
        mean: w.mean(),
        std: w.std(),
        median: percentile(&sorted, 0.5),
        p95: percentile(&sorted, 0.95),
        p99: percentile(&sorted, 0.99),
        min: sorted[0],
        max: sorted[sorted.len() - 1],
    }
}

/// The one latency-summary shape every serving surface reports — prefill
/// serving, the decode scheduler, and the network server all thread this
/// through `report::latency_cells`, so tables and wire metrics agree on
/// which percentiles exist.  Values are milliseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    /// sample count
    pub n: usize,
    /// arithmetic mean, ms
    pub mean: f64,
    /// 50th percentile, ms
    pub p50: f64,
    /// 95th percentile, ms
    pub p95: f64,
    /// 99th percentile, ms
    pub p99: f64,
    /// largest sample, ms
    pub max: f64,
}

impl LatencySummary {
    /// Empty-safe summary (all zeros when there are no samples — e.g. a
    /// server queried before its first completion).
    pub fn from_samples(xs: &[f64]) -> LatencySummary {
        if xs.is_empty() {
            return LatencySummary::default();
        }
        let s = summarize(xs);
        LatencySummary {
            n: s.n,
            mean: s.mean,
            p50: s.median,
            p95: s.p95,
            p99: s.p99,
            max: s.max,
        }
    }

    /// Wire form used by the server's metrics snapshot.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
            ("max", Json::num(self.max)),
        ])
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0,1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean of positive values (used to aggregate PPLs).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 3.75).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 3.75f64).powi(2)).sum::<f64>() / 3.0;
        assert!((w.var() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 0.5), 3.0);
        assert_eq!(percentile(&s, 1.0), 5.0);
        assert!((percentile(&s, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        // p99 sits between p95 and max by construction
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn p99_orders_correctly_on_larger_samples() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert!((s.median - 500.5).abs() < 1e-9);
        assert!(s.p95 < s.p99 && s.p99 < s.max);
        assert!((s.p99 - 990.01).abs() < 0.5, "p99 {}", s.p99);
        assert!((s.mean - 500.5).abs() < 1e-9);
    }

    #[test]
    fn latency_summary_empty_safe() {
        let l = LatencySummary::from_samples(&[]);
        assert_eq!(l.n, 0);
        assert_eq!(l.p50, 0.0);
        assert_eq!(l.p99, 0.0);
        let l = LatencySummary::from_samples(&[5.0]);
        assert_eq!(l.n, 1);
        assert_eq!(l.p50, 5.0);
        assert_eq!(l.p99, 5.0);
        assert_eq!(l.max, 5.0);
    }

    #[test]
    fn latency_summary_json_roundtrips_fields() {
        let l = LatencySummary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        let j = l.to_json();
        assert_eq!(j.usize_or("n", 0), 4);
        assert!((j.f64_or("p50", 0.0) - l.p50).abs() < 1e-12);
        assert!((j.f64_or("p99", 0.0) - l.p99).abs() < 1e-12);
        assert!((j.f64_or("mean", 0.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }
}
