//! Small numeric/statistics helpers shared by eval, serving and benches.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Summary of a sample: mean/std/median/p95/min/max.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub median: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mut w = Welford::default();
    for &x in xs {
        w.push(x);
    }
    Summary {
        n: xs.len(),
        mean: w.mean(),
        std: w.std(),
        median: percentile(&sorted, 0.5),
        p95: percentile(&sorted, 0.95),
        min: sorted[0],
        max: sorted[sorted.len() - 1],
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0,1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean of positive values (used to aggregate PPLs).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 3.75).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 3.75f64).powi(2)).sum::<f64>() / 3.0;
        assert!((w.var() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 0.5), 3.0);
        assert_eq!(percentile(&s, 1.0), 5.0);
        assert!((percentile(&s, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }
}
