//! In-repo property-testing driver (no proptest offline).
//!
//! `forall` runs a generator+checker loop over deterministic seeds and, on
//! failure, reports the failing case index and seed so it can be replayed
//! with `replay`.  Used by `rust/tests/proptests.rs` for the linalg and
//! zero-sum-selection invariants.

use super::rng::Rng;

/// Default random cases per property test.
pub const DEFAULT_CASES: usize = 64;

/// Run `check(gen(rng))` for `cases` deterministic seeds; panic with the
/// seed on the first failure.
pub fn forall<T, G, C>(name: &str, cases: usize, mut gen: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with prop::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single case by seed (for debugging a forall failure).
pub fn replay<T, G, C>(seed: u64, mut gen: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    check(&input).expect("replayed case failed");
}

/// Assert helper producing `Result` for use inside checkers.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("u64-parity", 32, |r| r.next_u64(), |x| {
            if x % 2 == 0 || x % 2 == 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn reports_failure_with_seed() {
        forall("always-fails", 4, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        forall("collect", 8, |r| r.next_u64(), |x| {
            first.push(*x);
            Ok(())
        });
        let mut second = Vec::new();
        forall("collect", 8, |r| r.next_u64(), |x| {
            second.push(*x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
