//! Dependency-free substrates: RNG, JSON, CLI parsing, statistics, the
//! property-test driver and the bench harness (DESIGN.md §4).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
