//! Deterministic RNG (xoshiro256**) — the only randomness source in the repo.
//!
//! Offline builds mean no `rand` crate; this is a faithful xoshiro256**
//! implementation seeded via splitmix64, plus the distributions the library
//! needs (uniform, normal via Box–Muller, categorical, shuffle).

/// xoshiro256** PRNG. Deterministic, seedable, `Clone` for replay.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded stream (SplitMix64-initialized xoshiro-style state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for reproducible sub-tasks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt()
                * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// One normal draw (Box–Muller).
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(mean, std) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        debug_assert!(total > 0.0, "categorical over zero weights");
        let mut t = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w as f64;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(13);
        let picks = r.choose_k(20, 8);
        assert_eq!(picks.len(), 8);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
