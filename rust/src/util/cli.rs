//! Tiny CLI argument parser (no clap offline).
//!
//! Grammar: `binary <subcommand> [--key value]... [--flag]... [positional]...`
//! Flags become `true`; everything else is a string looked up with typed
//! accessors.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// first bare token (the subcommand), if any
    pub subcommand: Option<String>,
    /// bare tokens after the subcommand
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse the process arguments (argv[0] skipped).
    pub fn parse_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Parse an explicit token stream (tests, scripting).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// True when `--name` appeared with no value.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name <value>` / `--name=<value>`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// usize option, defaulting when the flag is absent (an unparsable
    /// value panics with the flag name — misuse, not a runtime condition).
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got `{v}`")))
            .unwrap_or(default)
    }

    /// u64 option, defaulting when the flag is absent (an unparsable
    /// value panics with the flag name — misuse, not a runtime condition).
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got `{v}`")))
            .unwrap_or(default)
    }

    /// f64 option, defaulting when the flag is absent (an unparsable
    /// value panics with the flag name — misuse, not a runtime condition).
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants a number, got `{v}`")))
            .unwrap_or(default)
    }

    /// Comma-separated list: `--ratios 0.8,0.6,0.4`.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad number `{x}`")))
                .collect(),
        }
    }

    /// Comma-separated string-list option with a default.
    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|x| x.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // note: a bare token right after `--flag` would be consumed as its
        // value (greedy); flags therefore go last or use `--flag=...`.
        let a = args("compress --ratio 0.6 --method zs-svd out.bin --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("compress"));
        assert_eq!(a.f64_or("ratio", 1.0), 0.6);
        assert_eq!(a.get("method"), Some("zs-svd"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.bin"]);
    }

    #[test]
    fn equals_syntax() {
        let a = args("train --steps=300 --lr=1e-3");
        assert_eq!(a.usize_or("steps", 0), 300);
        assert_eq!(a.f64_or("lr", 0.0), 1e-3);
    }

    #[test]
    fn trailing_flag() {
        let a = args("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn lists() {
        let a = args("sweep --ratios 0.8,0.6,0.4 --methods zs,svdllm");
        assert_eq!(a.f64_list_or("ratios", &[]), vec![0.8, 0.6, 0.4]);
        assert_eq!(a.str_list_or("methods", &[]), vec!["zs", "svdllm"]);
    }

    #[test]
    fn defaults() {
        let a = args("eval");
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.str_or("model", "tiny"), "tiny");
        assert_eq!(a.f64_list_or("ratios", &[0.5]), vec![0.5]);
    }

    #[test]
    fn negative_number_values() {
        let a = args("x --bias -0.5");
        assert_eq!(a.f64_or("bias", 0.0), -0.5);
    }
}
