//! In-repo micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` binary is a `harness = false` cargo bench target that
//! uses `Bench` for timed sections and `report::Table` for paper-style rows.
//! `ZS_BENCH_FAST=1` shrinks warmup/iterations so the full suite stays
//! tractable on the single-core CI box.

use std::time::Instant;

use super::stats::{summarize, Summary};

/// Warmup-then-measure micro-benchmark loop.
pub struct Bench {
    /// untimed warmup iterations
    pub warmup: usize,
    /// timed iterations
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        if fast_mode() {
            Bench { warmup: 1, iters: 3 }
        } else {
            Bench { warmup: 3, iters: 10 }
        }
    }
}

/// True when `ZS_BENCH_FAST=1` — benches shrink workloads for CI smoke.
pub fn fast_mode() -> bool {
    std::env::var("ZS_BENCH_FAST").map(|v| v != "0").unwrap_or(false)
}

impl Bench {
    /// Bench with explicit warmup/measure iteration counts.
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters }
    }

    /// Time `f` (seconds per call) after warmup; returns a Summary.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        summarize(&samples)
    }

    /// Time `f` and report throughput in `units/s` given units per call.
    pub fn throughput<F: FnMut()>(&self, units_per_call: f64, f: F) -> (Summary, f64) {
        let s = self.run(f);
        let tput = units_per_call / s.median;
        (s, tput)
    }
}

/// One-shot wall-clock measurement (for pipeline-scale timings like Table 8).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Human duration: ns/µs/ms/s with three significant digits.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_counts_iters() {
        let mut calls = 0;
        let b = Bench::new(2, 5);
        let s = b.run(|| calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.n, 5);
        assert!(s.median >= 0.0);
    }

    #[test]
    fn throughput_positive() {
        let b = Bench::new(0, 3);
        let (_, tput) = b.throughput(100.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(tput > 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(5e-6).contains("us"));
        assert!(fmt_duration(5e-2).contains("ms"));
        assert!(fmt_duration(5.0).contains("s"));
        assert!(fmt_duration(600.0).contains("min"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
