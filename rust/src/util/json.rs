//! Minimal JSON parser + writer (no serde offline; this is a substrate,
//! tested like everything else).
//!
//! Parses `artifacts/manifest.json`, experiment configs, and writes reports.
//! Supports the full JSON grammar; numbers are kept as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.  Numbers are kept as f64 (the JSON model).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object (sorted keys — deterministic serialization)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------
    /// Object field lookup; `None` for non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that panics with a useful path message — for manifest fields
    /// whose absence is a build error, not a runtime condition.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}` in {self:.0?}"))
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// String field with a default for missing/mistyped values.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Json::as_str).unwrap_or(default).to_string()
    }

    /// Numeric field with a default for missing/mistyped values.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    /// usize field with a default for missing/mistyped values.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    /// Bool field with a default for missing/mistyped values.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some(Json::Bool(b)) => *b,
            _ => default,
        }
    }

    /// Shape helper: `[128, 352]` -> `vec![128, 352]`.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // ---------------- constructors ----------------
    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array from any iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// String value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---------------- serialization ----------------
    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    /// Indented multi-line serialization.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------- parsing ----------------

/// Parse one complete JSON document (trailing data is an error).
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

/// Read and parse a JSON file, prefixing errors with the path.
pub fn parse_file(path: &std::path::Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (valid utf-8 by construction)
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\nthere\"").unwrap(),
                   Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("c".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = Json::obj(vec![
            ("name", Json::str("tiny")),
            ("dims", Json::arr([Json::num(128.0), Json::num(352.0)])),
            ("nested", Json::obj(vec![("x", Json::Bool(false))])),
            ("f", Json::num(0.25)),
        ]);
        for pretty in [false, true] {
            let text = if pretty { src.to_string_pretty() } else { src.to_string() };
            assert_eq!(parse(&text).unwrap(), src);
        }
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn shape_helper() {
        let v = parse("[128, 352]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![128, 352]);
    }

    #[test]
    fn escaped_output_reparses() {
        let s = Json::Str("line1\nline2\t\"quoted\"\\".into());
        assert_eq!(parse(&s.to_string()).unwrap(), s);
    }
}
