//! Synthetic data substrate: the shared `World`, three corpus grammars
//! (WikiText2/PTB/C4 analogs), batch sampling, and the seven zero-shot task
//! families (DESIGN.md §2, §4).

pub mod corpus;
pub mod grammar;
pub mod tasks;
pub mod world;

pub use corpus::{default_world, eval_corpora, training_corpus, Corpus};
pub use tasks::{generate_set, TaskFamily, TaskInstance, ALL_FAMILIES};
pub use world::World;
