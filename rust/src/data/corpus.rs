//! Corpora: byte-level token streams with train/eval splits and batch
//! sampling — the WikiText2/PTB/C4 stand-ins plus the calibration sampler.

use super::grammar::{c4_style, ptb_style, vicuna_style, wiki_style, Grammar,
                     GrammarStyle};
use super::world::{World, WORLD_SEED};
use crate::tensor::IntTensor;
use crate::util::rng::Rng;

/// One synthetic byte-level corpus with disjoint train/eval splits.
#[derive(Clone, Debug)]
pub struct Corpus {
    /// corpus name ("wiki-syn", ...)
    pub name: String,
    train: Vec<u8>,
    eval: Vec<u8>,
}

/// Default sizes: enough structure for a ~1M-param model to learn from while
/// keeping single-core generation instant.
pub const TRAIN_BYTES: usize = 2_000_000;
/// Default eval-split size in bytes.
pub const EVAL_BYTES: usize = 200_000;

impl Corpus {
    /// Generate a corpus of the given style and sizes from a world.
    pub fn build(style: GrammarStyle, world: &World, train_bytes: usize,
                 eval_bytes: usize) -> Corpus {
        let g = Grammar::new(world, style.clone());
        // disjoint RNG streams => disjoint train/eval text
        let mut train_rng = Rng::new(0xDA7A ^ hash_name(style.name));
        let mut eval_rng = Rng::new(0xE7A1 ^ hash_name(style.name));
        Corpus {
            name: style.name.to_string(),
            train: g.generate(&mut train_rng, train_bytes),
            eval: g.generate(&mut eval_rng, eval_bytes),
        }
    }

    /// Train-split size in bytes.
    pub fn train_len(&self) -> usize {
        self.train.len()
    }

    /// Eval-split size in bytes.
    pub fn eval_len(&self) -> usize {
        self.eval.len()
    }

    /// Random (B, T+1) training batch as i32 tokens.
    pub fn sample_batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> IntTensor {
        self.batch_from(&self.train, rng, batch, seq)
    }

    /// Random calibration batch — drawn from *train* (the paper calibrates
    /// on WikiText2 training text).
    pub fn calibration_batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> IntTensor {
        self.batch_from(&self.train, rng, batch, seq)
    }

    fn batch_from(&self, text: &[u8], rng: &mut Rng, batch: usize, seq: usize) -> IntTensor {
        let span = seq + 1;
        assert!(text.len() > span, "corpus too small");
        let mut data = Vec::with_capacity(batch * span);
        for _ in 0..batch {
            let start = rng.below(text.len() - span);
            data.extend(text[start..start + span].iter().map(|&b| b as i32));
        }
        IntTensor::from_vec(&[batch, span], data)
    }

    /// Deterministic sequence of eval batches covering the eval split.
    pub fn eval_batches(&self, batch: usize, seq: usize, max_batches: usize) -> Vec<IntTensor> {
        let span = seq + 1;
        let per_batch = batch * span;
        let n = (self.eval.len() / per_batch).min(max_batches);
        (0..n)
            .map(|i| {
                let base = i * per_batch;
                let data: Vec<i32> = self.eval[base..base + per_batch]
                    .iter()
                    .map(|&b| b as i32)
                    .collect();
                IntTensor::from_vec(&[batch, span], data)
            })
            .collect()
    }
}

fn hash_name(name: &str) -> u64 {
    name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

/// The three evaluation corpora (paper order: WikiText2, PTB, C4).
pub fn eval_corpora(world: &World) -> Vec<Corpus> {
    vec![
        Corpus::build(wiki_style(), world, TRAIN_BYTES, EVAL_BYTES),
        Corpus::build(ptb_style(), world, TRAIN_BYTES / 2, EVAL_BYTES),
        Corpus::build(c4_style(), world, TRAIN_BYTES, EVAL_BYTES),
    ]
}

/// Training mixture for a model family: "llama"/"opt" train on wiki+c4;
/// "vicuna" adds the instruction-flavoured mix.
pub fn training_corpus(family: &str, world: &World) -> Corpus {
    match family {
        "vicuna" => Corpus::build(vicuna_style(), world, TRAIN_BYTES, EVAL_BYTES),
        _ => Corpus::build(wiki_style(), world, TRAIN_BYTES, EVAL_BYTES),
    }
}

/// The fixed world every experiment shares (seeded constant).
pub fn default_world() -> World {
    World::new(WORLD_SEED)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_corpus() -> Corpus {
        Corpus::build(wiki_style(), &default_world(), 50_000, 10_000)
    }

    #[test]
    fn batch_shapes_and_range() {
        let c = small_corpus();
        let mut rng = Rng::new(1);
        let b = c.sample_batch(&mut rng, 4, 32);
        assert_eq!(b.shape, vec![4, 33]);
        assert!(b.data.iter().all(|&t| (1..256).contains(&t)));
    }

    #[test]
    fn eval_batches_cover_disjoint_text() {
        let c = small_corpus();
        let bs = c.eval_batches(2, 16, 10);
        assert_eq!(bs.len(), 10);
        assert_ne!(bs[0].data, bs[1].data);
    }

    #[test]
    fn train_eval_disjoint_streams() {
        let c = small_corpus();
        // eval text should not be a subslice of train text (different rng)
        assert_ne!(&c.train[..1000], &c.eval[..1000]);
    }

    #[test]
    fn corpora_distinct() {
        let w = default_world();
        let cs = eval_corpora(&w);
        assert_eq!(cs.len(), 3);
        assert_eq!(cs[0].name, "wiki-syn");
        assert_ne!(cs[0].train[..500], cs[2].train[..500]);
    }

    #[test]
    fn deterministic_rebuild() {
        let a = small_corpus();
        let b = small_corpus();
        assert_eq!(a.train, b.train);
    }
}
