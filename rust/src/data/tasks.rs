//! Zero-shot task families — the LM-eval-harness analog (DESIGN.md §2).
//!
//! Seven multiple-choice families over the shared `World`, scored exactly
//! like lm-eval: the model picks the option with the highest (length-
//! normalized) log-probability given the prompt.  Families are graded so
//! compression damage shows up in the same qualitative order as the paper's
//! suite (stored-knowledge tasks fall first, local-syntax tasks last).

use super::world::World;
use crate::util::rng::Rng;

/// The seven zero-shot task families (analogs of the paper's eval suite).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskFamily {
    /// stored-fact recall (OpenBookQA analog): "tup iz" -> attribute
    OpenbSyn,
    /// adjacent subject-verb agreement, easy (ARC-Easy analog)
    ArcESyn,
    /// agreement across a distractor phrase (ARC-Challenge analog)
    ArcCSyn,
    /// long-range in-context referent resolution (WinoGrande analog)
    WinogSyn,
    /// plausible continuation vs corrupted continuations (HellaSwag analog)
    HellasSyn,
    /// 2-way grammatical vs scrambled (PIQA analog)
    PiqaSyn,
    /// single-digit addition (MathQA analog)
    MathqaSyn,
}

/// Every task family, in the paper's table order.
pub const ALL_FAMILIES: [TaskFamily; 7] = [
    TaskFamily::OpenbSyn, TaskFamily::ArcESyn, TaskFamily::ArcCSyn,
    TaskFamily::WinogSyn, TaskFamily::HellasSyn, TaskFamily::PiqaSyn,
    TaskFamily::MathqaSyn,
];

impl TaskFamily {
    /// Table-row name of the family.
    pub fn name(&self) -> &'static str {
        match self {
            TaskFamily::OpenbSyn => "openb-syn",
            TaskFamily::ArcESyn => "arc_e-syn",
            TaskFamily::ArcCSyn => "arc_c-syn",
            TaskFamily::WinogSyn => "winog-syn",
            TaskFamily::HellasSyn => "hellas-syn",
            TaskFamily::PiqaSyn => "piqa-syn",
            TaskFamily::MathqaSyn => "mathqa-syn",
        }
    }
}

/// One multiple-choice instance.  Scoring consumes prompt+option token
/// streams; `correct` indexes `options`.
#[derive(Clone, Debug)]
pub struct TaskInstance {
    /// family this instance belongs to
    pub family: TaskFamily,
    /// context the model scores each option against
    pub prompt: String,
    /// candidate continuations
    pub options: Vec<String>,
    /// index of the correct option
    pub correct: usize,
}

impl TaskInstance {
    /// Number of candidate options.
    pub fn n_options(&self) -> usize {
        self.options.len()
    }
}

/// Deterministic instance generator for a family.
pub fn generate(world: &World, family: TaskFamily, rng: &mut Rng) -> TaskInstance {
    match family {
        TaskFamily::OpenbSyn => openb(world, rng),
        TaskFamily::ArcESyn => arc_easy(world, rng),
        TaskFamily::ArcCSyn => arc_challenge(world, rng),
        TaskFamily::WinogSyn => winog(world, rng),
        TaskFamily::HellasSyn => hellas(world, rng),
        TaskFamily::PiqaSyn => piqa(world, rng),
        TaskFamily::MathqaSyn => mathqa(world, rng),
    }
}

/// Generate `n` instances of one family from a family-mixed seed.
pub fn generate_set(world: &World, family: TaskFamily, n: usize, seed: u64)
                    -> Vec<TaskInstance> {
    let mut rng = Rng::new(seed ^ hash_family(family.name()));
    (0..n).map(|_| generate(world, family, &mut rng)).collect()
}

fn hash_family(name: &str) -> u64 {
    name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

/// distinct wrong options drawn from `pool`, excluding `correct_idx`
fn distractors(rng: &mut Rng, pool: usize, correct_idx: usize, n: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let d = rng.below(pool);
        if d != correct_idx && !out.contains(&d) {
            out.push(d);
        }
    }
    out
}

/// Shuffle correct + distractor strings into options, return correct slot.
fn assemble(rng: &mut Rng, correct: String, wrong: Vec<String>) -> (Vec<String>, usize) {
    let mut opts: Vec<(bool, String)> =
        std::iter::once((true, correct))
            .chain(wrong.into_iter().map(|w| (false, w)))
            .collect();
    rng.shuffle(&mut opts);
    let idx = opts.iter().position(|(c, _)| *c).unwrap();
    (opts.into_iter().map(|(_, s)| s).collect(), idx)
}

fn openb(w: &World, rng: &mut Rng) -> TaskInstance {
    let noun = rng.below(w.nouns.len());
    let attr = w.facts[noun];
    let wrong = distractors(rng, w.attrs.len(), attr, 3)
        .into_iter().map(|i| format!(" {} .", w.attrs[i])).collect();
    let (options, correct) =
        assemble(rng, format!(" {} .", w.attrs[attr]), wrong);
    TaskInstance {
        family: TaskFamily::OpenbSyn,
        prompt: format!("{} iz", w.nouns[noun]),
        options, correct,
    }
}

fn arc_easy(w: &World, rng: &mut Rng) -> TaskInstance {
    let noun = rng.below(w.nouns.len());
    let plural = rng.below(2) == 1;
    let verb = rng.below(w.verbs_sing.len());
    let subj = if plural { w.plural(noun) } else { w.nouns[noun].clone() };
    let good = if plural { &w.verbs_plur[verb] } else { &w.verbs_sing[verb] };
    let bad = if plural { &w.verbs_sing[verb] } else { &w.verbs_plur[verb] };
    let obj = w.nouns[rng.below(w.nouns.len())].clone();
    let (options, correct) = assemble(
        rng,
        format!(" {good} the {obj} ."),
        vec![format!(" {bad} the {obj} .")],
    );
    TaskInstance {
        family: TaskFamily::ArcESyn,
        prompt: format!("the {subj}"),
        options, correct,
    }
}

fn arc_challenge(w: &World, rng: &mut Rng) -> TaskInstance {
    let noun = rng.below(w.nouns.len());
    let plural = rng.below(2) == 1;
    // distractor noun with OPPOSITE number right before the verb
    let d = rng.below(w.nouns.len());
    let dn = if plural { w.nouns[d].clone() } else { w.plural(d) };
    let verb = rng.below(w.verbs_sing.len());
    let subj = if plural { w.plural(noun) } else { w.nouns[noun].clone() };
    let good = if plural { &w.verbs_plur[verb] } else { &w.verbs_sing[verb] };
    let bad = if plural { &w.verbs_sing[verb] } else { &w.verbs_plur[verb] };
    let obj = w.nouns[rng.below(w.nouns.len())].clone();
    let (options, correct) = assemble(
        rng,
        format!(" {good} the {obj} ."),
        vec![format!(" {bad} the {obj} .")],
    );
    TaskInstance {
        family: TaskFamily::ArcCSyn,
        prompt: format!("the {subj} near the {dn}"),
        options, correct,
    }
}

fn winog(w: &World, rng: &mut Rng) -> TaskInstance {
    let n1 = rng.below(w.nouns.len());
    let mut n2 = rng.below(w.nouns.len());
    while n2 == n1 || w.facts[n2] == w.facts[n1] {
        n2 = rng.below(w.nouns.len());
    }
    // context asserts two (possibly counterfactual) attributes, then asks
    // for the first referent's — pure in-context recall, robust to facts.
    let a1 = rng.below(w.attrs.len());
    let mut a2 = rng.below(w.attrs.len());
    while a2 == a1 {
        a2 = rng.below(w.attrs.len());
    }
    let (options, correct) = assemble(
        rng,
        format!(" {} .", w.attrs[a1]),
        vec![format!(" {} .", w.attrs[a2])],
    );
    TaskInstance {
        family: TaskFamily::WinogSyn,
        prompt: format!(
            "{} iz {} . {} iz {} . {} iz",
            w.nouns[n1], w.attrs[a1], w.nouns[n2], w.attrs[a2], w.nouns[n1]
        ),
        options, correct,
    }
}

fn hellas(w: &World, rng: &mut Rng) -> TaskInstance {
    let noun = rng.below(w.nouns.len());
    let plural = rng.below(2) == 1;
    let verb = rng.below(w.verbs_sing.len());
    let subj = if plural { w.plural(noun) } else { w.nouns[noun].clone() };
    let v = if plural { &w.verbs_plur[verb] } else { &w.verbs_sing[verb] };
    let obj = w.nouns[rng.below(w.nouns.len())].clone();
    let good = format!(" {v} the {obj} .");
    // corrupted continuations: word-order scrambles of the good one
    let mut wrong = Vec::new();
    wrong.push(format!(" the {v} {obj} ."));
    wrong.push(format!(" {obj} the {v} ."));
    wrong.push(format!(" the {obj} {v} the ."));
    let (options, correct) = assemble(rng, good, wrong);
    TaskInstance {
        family: TaskFamily::HellasSyn,
        prompt: format!("the {subj}"),
        options, correct,
    }
}

fn piqa(w: &World, rng: &mut Rng) -> TaskInstance {
    let noun = rng.below(w.nouns.len());
    let verb = rng.below(w.verbs_sing.len());
    let obj = w.nouns[rng.below(w.nouns.len())].clone();
    let good = format!("the {} {} the {} .", w.nouns[noun], w.verbs_sing[verb], obj);
    let bad = format!("{} the {} the {} .", w.verbs_sing[verb], obj, w.nouns[noun]);
    let (options, correct) = assemble(rng, good, vec![bad]);
    TaskInstance {
        family: TaskFamily::PiqaSyn,
        prompt: String::new(),
        options, correct,
    }
}

fn mathqa(w: &World, rng: &mut Rng) -> TaskInstance {
    let _ = w;
    let a = rng.below(10) as u32;
    let b = rng.below(10) as u32;
    let good = format!(" {} .", a + b);
    let mut wrong = Vec::new();
    let mut used = vec![a + b];
    while wrong.len() < 3 {
        let delta = 1 + rng.below(5) as i32;
        let sign = if rng.below(2) == 0 { 1 } else { -1 };
        let cand = (a + b) as i32 + sign * delta;
        if cand >= 0 && !used.contains(&(cand as u32)) {
            used.push(cand as u32);
            wrong.push(format!(" {} .", cand));
        }
    }
    let (options, correct) = assemble(rng, good, wrong);
    TaskInstance {
        family: TaskFamily::MathqaSyn,
        prompt: format!("{} + {} =", a, b),
        options, correct,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::world::{World, WORLD_SEED};

    fn world() -> World {
        World::new(WORLD_SEED)
    }

    #[test]
    fn all_families_generate() {
        let w = world();
        let mut rng = Rng::new(1);
        for fam in ALL_FAMILIES {
            for _ in 0..50 {
                let t = generate(&w, fam, &mut rng);
                assert!(t.n_options() >= 2, "{fam:?}");
                assert!(t.correct < t.n_options());
                // options distinct
                let mut o = t.options.clone();
                o.sort();
                o.dedup();
                assert_eq!(o.len(), t.n_options(), "{fam:?}: {:?}", t.options);
            }
        }
    }

    #[test]
    fn openb_correct_matches_world_fact() {
        let w = world();
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let t = openb(&w, &mut rng);
            let noun = t.prompt.split(' ').next().unwrap();
            let ni = w.nouns.iter().position(|n| n == noun).unwrap();
            assert_eq!(t.options[t.correct], format!(" {} .", w.fact_attr(ni)));
        }
    }

    #[test]
    fn mathqa_correct_sum() {
        let w = world();
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let t = mathqa(&w, &mut rng);
            let nums: Vec<u32> = t.prompt
                .split(['+', '='])
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            let want = format!(" {} .", nums[0] + nums[1]);
            assert_eq!(t.options[t.correct], want);
        }
    }

    #[test]
    fn correct_position_is_uniform_ish() {
        let w = world();
        let mut rng = Rng::new(4);
        let mut counts = [0usize; 4];
        for _ in 0..400 {
            let t = openb(&w, &mut rng);
            counts[t.correct] += 1;
        }
        for &c in &counts {
            assert!(c > 50, "position bias: {counts:?}");
        }
    }

    #[test]
    fn deterministic_sets() {
        let w = world();
        let a = generate_set(&w, TaskFamily::ArcESyn, 10, 7);
        let b = generate_set(&w, TaskFamily::ArcESyn, 10, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.options, y.options);
        }
    }

    #[test]
    fn winog_referents_disagree() {
        let w = world();
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let t = winog(&w, &mut rng);
            // the correct option is the first asserted attribute
            let first_attr = t.prompt.split(" iz ").nth(1).unwrap()
                .split(' ').next().unwrap();
            assert!(t.options[t.correct].contains(first_attr));
        }
    }
}
