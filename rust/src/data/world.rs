//! The synthetic "world": a fixed lexicon plus relational knowledge that all
//! corpora teach and all zero-shot tasks query (DESIGN.md §2).
//!
//! The world is shared across corpora (same language, same facts) while each
//! corpus renders it with a different style/mixture — exactly the split the
//! paper's evaluation needs: three PPL axes over one underlying language, and
//! task accuracy that measures *stored knowledge* surviving compression.

use crate::util::rng::Rng;

/// Deterministic lexicon + facts, derived from a world seed.
#[derive(Clone, Debug)]
pub struct World {
    /// singular noun forms; plural = +"s"
    pub nouns: Vec<String>,
    /// verb form agreeing with a singular subject
    pub verbs_sing: Vec<String>,
    /// verb form agreeing with a plural subject
    pub verbs_plur: Vec<String>,
    /// attribute words
    pub attrs: Vec<String>,
    /// `facts[i]` = index into attrs: the attribute of noun i
    /// ("`<noun> iz <attr>`")
    pub facts: Vec<usize>,
}

const ONSETS: &[&str] = &["b", "d", "f", "g", "k", "l", "m", "n", "p", "r",
                          "s", "t", "v", "z", "bl", "tr", "gr", "st"];
const VOWELS: &[&str] = &["a", "e", "i", "o", "u", "oo", "ai"];
const CODAS: &[&str] = &["b", "d", "g", "k", "l", "m", "n", "p", "r", "t", "x", "zz"];

fn make_word(rng: &mut Rng, syllables: usize) -> String {
    let mut w = String::new();
    for s in 0..syllables {
        w.push_str(ONSETS[rng.below(ONSETS.len())]);
        w.push_str(VOWELS[rng.below(VOWELS.len())]);
        if s + 1 == syllables {
            w.push_str(CODAS[rng.below(CODAS.len())]);
        }
    }
    w
}

fn make_inventory(rng: &mut Rng, count: usize, syllables: usize) -> Vec<String> {
    let mut out: Vec<String> = Vec::with_capacity(count);
    while out.len() < count {
        let w = make_word(rng, syllables);
        if !out.contains(&w) {
            out.push(w);
        }
    }
    out
}

impl World {
    /// Generate a lexicon + fact table from a seed.
    pub fn new(seed: u64) -> World {
        let mut rng = Rng::new(seed);
        let nouns = make_inventory(&mut rng, 24, 1);
        let verbs_sing = make_inventory(&mut rng, 10, 2);
        // plural verb = singular stem truncated + "en" (systematic morphology
        // the model can learn)
        let verbs_plur = verbs_sing
            .iter()
            .map(|v| format!("{}en", &v[..v.len().saturating_sub(1)]))
            .collect();
        let attrs = make_inventory(&mut rng, 12, 2);
        let facts = (0..nouns.len()).map(|_| rng.below(attrs.len())).collect();
        World { nouns, verbs_sing, verbs_plur, attrs, facts }
    }

    /// Plural surface form of a noun.
    pub fn plural(&self, noun_idx: usize) -> String {
        format!("{}s", self.nouns[noun_idx])
    }

    /// The attribute the world assigns to a noun.
    pub fn fact_attr(&self, noun_idx: usize) -> &str {
        &self.attrs[self.facts[noun_idx]]
    }

    /// The canonical fact sentence every corpus plants:
    /// `"<noun> iz <attr> ."`
    pub fn fact_sentence(&self, noun_idx: usize) -> String {
        format!("{} iz {} .", self.nouns[noun_idx], self.fact_attr(noun_idx))
    }

    /// Arithmetic sentence: `"a + b = c ."` over single digits (c may be two
    /// digits); planted so mathqa-syn is learnable.
    pub fn math_sentence(a: u32, b: u32) -> String {
        format!("{} + {} = {} .", a, b, a + b)
    }
}

/// The default world seed shared by the whole repo (corpora, tasks, tests).
pub const WORLD_SEED: u64 = 0x5EED_0001;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = World::new(1);
        let b = World::new(1);
        assert_eq!(a.nouns, b.nouns);
        assert_eq!(a.facts, b.facts);
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(World::new(1).nouns, World::new(2).nouns);
    }

    #[test]
    fn inventories_distinct_and_sized() {
        let w = World::new(WORLD_SEED);
        assert_eq!(w.nouns.len(), 24);
        assert_eq!(w.verbs_sing.len(), 10);
        assert_eq!(w.verbs_plur.len(), 10);
        assert_eq!(w.attrs.len(), 12);
        let mut all = w.nouns.clone();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 24);
    }

    #[test]
    fn verb_agreement_morphology() {
        let w = World::new(WORLD_SEED);
        for (s, p) in w.verbs_sing.iter().zip(&w.verbs_plur) {
            assert!(p.ends_with("en"));
            assert_ne!(s, p);
        }
    }

    #[test]
    fn facts_in_range_and_ascii() {
        let w = World::new(WORLD_SEED);
        for &f in &w.facts {
            assert!(f < w.attrs.len());
        }
        for n in &w.nouns {
            assert!(n.is_ascii() && !n.is_empty());
        }
        assert!(w.fact_sentence(0).contains(" iz "));
    }

    #[test]
    fn math_sentence_format() {
        assert_eq!(World::math_sentence(3, 4), "3 + 4 = 7 .");
    }
}
