//! Stochastic grammars rendering the shared `World` into byte streams.
//!
//! Three styles stand in for the paper's corpora (DESIGN.md §2):
//! * `wiki_syn` — balanced mixture, medium sentences (WikiText2 analog; also
//!   the calibration source, matching the paper's protocol).
//! * `ptb_syn`  — small effective vocabulary, short regular sentences,
//!   no noise (Penn Treebank analog).
//! * `c4_syn`   — web-ish: longer run-on sentences, URL-like junk tokens,
//!   character noise (C4 analog, highest entropy).

use super::world::World;
use crate::util::rng::Rng;

/// Style knobs distinguishing the synthetic corpora (wiki/ptb/c4 analogs).
#[derive(Clone, Debug)]
pub struct GrammarStyle {
    /// corpus name ("wiki-syn", ...)
    pub name: &'static str,
    /// mixture weights: [agreement sentence, fact sentence, math line, noise line]
    pub mix: [f32; 4],
    /// max nouns chained into one sentence ("the A near the B ...")
    pub max_chain: usize,
    /// probability a character is replaced by junk (c4-style noise)
    pub char_noise: f32,
    /// restrict lexicon to the first `vocab_frac` of each inventory (ptb)
    pub vocab_frac: f32,
}

/// Clean encyclopedic mix (WikiText-2 analog).
pub fn wiki_style() -> GrammarStyle {
    GrammarStyle { name: "wiki-syn", mix: [0.55, 0.2, 0.1, 0.15],
                   max_chain: 2, char_noise: 0.0, vocab_frac: 1.0 }
}

/// Restricted-vocabulary mix (PTB analog).
pub fn ptb_style() -> GrammarStyle {
    GrammarStyle { name: "ptb-syn", mix: [0.6, 0.25, 0.15, 0.0],
                   max_chain: 1, char_noise: 0.0, vocab_frac: 0.5 }
}

/// Noisy web-crawl mix (C4 analog).
pub fn c4_style() -> GrammarStyle {
    GrammarStyle { name: "c4-syn", mix: [0.45, 0.15, 0.1, 0.3],
                   max_chain: 3, char_noise: 0.02, vocab_frac: 1.0 }
}

/// "Vicuna" corpus mix: same world rendered with an instruction-ish flavour
/// (fact-heavy), used to train the vicuna-analog weights.
pub fn vicuna_style() -> GrammarStyle {
    GrammarStyle { name: "vicuna-syn", mix: [0.35, 0.4, 0.15, 0.1],
                   max_chain: 2, char_noise: 0.0, vocab_frac: 1.0 }
}

/// Sentence generator binding a [`GrammarStyle`] to a [`World`].
pub struct Grammar<'w> {
    /// the shared lexicon/fact world sentences draw from
    pub world: &'w World,
    /// mixture + noise knobs of this corpus flavour
    pub style: GrammarStyle,
}

impl<'w> Grammar<'w> {
    /// Bind a style to a world.
    pub fn new(world: &'w World, style: GrammarStyle) -> Self {
        Grammar { world, style }
    }

    fn n_nouns(&self) -> usize {
        ((self.world.nouns.len() as f32 * self.style.vocab_frac) as usize).max(2)
    }

    fn n_verbs(&self) -> usize {
        ((self.world.verbs_sing.len() as f32 * self.style.vocab_frac) as usize).max(2)
    }

    /// Subject-verb agreement sentence, optionally with distractor nouns
    /// between subject and verb:
    /// `"the tups near the mib kezen the dax ."`
    pub fn agreement_sentence(&self, rng: &mut Rng) -> String {
        let w = self.world;
        let subj = rng.below(self.n_nouns());
        let plural = rng.below(2) == 1;
        let mut s = String::from("the ");
        s.push_str(&if plural { w.plural(subj) } else { w.nouns[subj].clone() });
        let chain = rng.below(self.style.max_chain) ;
        for _ in 0..chain {
            let d = rng.below(self.n_nouns());
            let dp = rng.below(2) == 1;
            s.push_str(" near the ");
            s.push_str(&if dp { w.plural(d) } else { w.nouns[d].clone() });
        }
        let verb = rng.below(self.n_verbs());
        s.push(' ');
        s.push_str(if plural { &w.verbs_plur[verb] } else { &w.verbs_sing[verb] });
        let obj = rng.below(self.n_nouns());
        s.push_str(" the ");
        s.push_str(&w.nouns[obj]);
        s.push_str(" .");
        s
    }

    /// A planted world fact ("`<noun> iz <attr> .`").
    pub fn fact_sentence(&self, rng: &mut Rng) -> String {
        self.world.fact_sentence(rng.below(self.n_nouns()))
    }

    /// A single-digit arithmetic line ("a + b = c .").
    pub fn math_sentence(&self, rng: &mut Rng) -> String {
        World::math_sentence(rng.below(10) as u32, rng.below(10) as u32)
    }

    /// URL-ish noise line (c4 flavour).
    pub fn noise_line(&self, rng: &mut Rng) -> String {
        const JUNK: &[&str] = &["www", "http", "com", "org", "html", "px",
                                "id", "ref", "utm", "page"];
        let n = 2 + rng.below(4);
        let mut parts = Vec::with_capacity(n);
        for _ in 0..n {
            if rng.below(3) == 0 {
                parts.push(format!("{}", rng.below(1000)));
            } else {
                parts.push(JUNK[rng.below(JUNK.len())].to_string());
            }
        }
        parts.join("/")
    }

    /// One sentence drawn from the style's mixture (+ char noise).
    pub fn sentence(&self, rng: &mut Rng) -> String {
        let mut s = match rng.categorical(&self.style.mix) {
            0 => self.agreement_sentence(rng),
            1 => self.fact_sentence(rng),
            2 => self.math_sentence(rng),
            _ => self.noise_line(rng),
        };
        if self.style.char_noise > 0.0 {
            let bytes: Vec<u8> = s
                .bytes()
                .map(|b| {
                    if rng.uniform_f32() < self.style.char_noise {
                        b'a' + rng.below(26) as u8
                    } else {
                        b
                    }
                })
                .collect();
            s = String::from_utf8(bytes).unwrap();
        }
        s
    }

    /// Render `len` bytes of corpus text.
    pub fn generate(&self, rng: &mut Rng, len: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(len + 64);
        while out.len() < len {
            out.extend_from_slice(self.sentence(rng).as_bytes());
            out.push(b' ');
        }
        out.truncate(len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::world::{World, WORLD_SEED};

    fn world() -> World {
        World::new(WORLD_SEED)
    }

    #[test]
    fn agreement_is_consistent() {
        let w = world();
        let g = Grammar::new(&w, wiki_style());
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let s = g.agreement_sentence(&mut rng);
            let words: Vec<&str> = s.split(' ').collect();
            assert_eq!(words[0], "the");
            let subj = words[1];
            let plural = w.nouns.iter().any(|n| format!("{n}s") == subj);
            // verb is the word right before the final "the <obj> ."
            let vi = words.len() - 4;
            let verb = words[vi];
            if plural {
                assert!(w.verbs_plur.iter().any(|v| v == verb), "{s}");
            } else {
                assert!(w.verbs_sing.iter().any(|v| v == verb), "{s}");
            }
        }
    }

    #[test]
    fn generate_exact_len_and_ascii() {
        let w = world();
        let g = Grammar::new(&w, c4_style());
        let mut rng = Rng::new(2);
        let bytes = g.generate(&mut rng, 10_000);
        assert_eq!(bytes.len(), 10_000);
        assert!(bytes.iter().all(|&b| b.is_ascii() && b != 0));
    }

    #[test]
    fn styles_have_different_statistics() {
        let w = world();
        let mut rng = Rng::new(3);
        let entropy = |bytes: &[u8]| {
            let mut counts = [0f64; 256];
            for &b in bytes {
                counts[b as usize] += 1.0;
            }
            let n = bytes.len() as f64;
            counts.iter().filter(|&&c| c > 0.0)
                .map(|&c| -(c / n) * (c / n).log2())
                .sum::<f64>()
        };
        let wiki = entropy(&Grammar::new(&w, wiki_style()).generate(&mut rng, 50_000));
        let ptb = entropy(&Grammar::new(&w, ptb_style()).generate(&mut rng, 50_000));
        let c4 = entropy(&Grammar::new(&w, c4_style()).generate(&mut rng, 50_000));
        assert!(ptb < wiki, "ptb {ptb} < wiki {wiki}");
        assert!(wiki < c4, "wiki {wiki} < c4 {c4}");
    }

    #[test]
    fn ptb_restricts_vocab() {
        let w = world();
        let g = Grammar::new(&w, ptb_style());
        let mut rng = Rng::new(4);
        let text = String::from_utf8(g.generate(&mut rng, 50_000)).unwrap();
        // nouns from the second half of the inventory must not appear
        for n in &w.nouns[w.nouns.len() / 2 + 1..] {
            assert!(!text.contains(&format!(" {n} ")), "leaked {n}");
        }
    }

    #[test]
    fn deterministic_generation() {
        let w = world();
        let g = Grammar::new(&w, wiki_style());
        let a = g.generate(&mut Rng::new(9), 1000);
        let b = g.generate(&mut Rng::new(9), 1000);
        assert_eq!(a, b);
    }

    #[test]
    fn facts_are_planted() {
        let w = world();
        let g = Grammar::new(&w, wiki_style());
        let mut rng = Rng::new(5);
        let text = String::from_utf8(g.generate(&mut rng, 200_000)).unwrap();
        // at least half of the (in-vocab) facts appear verbatim
        let mut hits = 0;
        for i in 0..w.nouns.len() {
            if text.contains(&w.fact_sentence(i)) {
                hits += 1;
            }
        }
        assert!(hits >= w.nouns.len() / 2, "only {hits} facts planted");
    }
}
