//! SIMD micro-kernel layer: the innermost MAC loops of every GEMM / dot /
//! row-reduction hot path, with two interchangeable backends that are
//! **bit-identical to each other** — explicit AVX2 intrinsics behind runtime
//! feature detection, and a portable scalar fallback that executes the very
//! same lane-strided accumulation order.
//!
//! # The canonical reduction orders
//!
//! Floating-point addition is not associative, so "what order do partial
//! products combine in" is part of this repo's determinism contract (see the
//! README's determinism section).  This module pins ONE canonical order per
//! reduction and every backend implements it exactly:
//!
//! * **f32 dot product** ([`dot_f32`], [`LANES`] = 8): lane `l` accumulates
//!   the products at indices `i ≡ l (mod 8)` in ascending order, one f32
//!   rounding per step.  The eight lane sums combine as
//!   `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))` — exactly the
//!   low/high-half, move-high, scalar-add horizontal reduction an AVX2
//!   register performs — and the `len % 8` tail elements are then added one
//!   by one in ascending index order.
//! * **f64 row reductions** ([`sum_f64`], [`sum_sq_f64`],
//!   [`sum_sq_centered_f64`], [`F64_LANES`] = 4): lane `l` accumulates the
//!   terms at indices `i ≡ l (mod 4)`; lanes combine as
//!   `(l0+l2) + (l1+l3)`; the tail is appended in ascending order.  These
//!   carry the norm-layer reductions (RMSNorm mean-square, LayerNorm
//!   mean/variance) that the runtime accumulates in f64.
//! * **GEMM output elements** ([`mm_rows`], A·B): each `c[i][j]` is a single
//!   f32 accumulator over `k` in ascending order.  Register tiling and
//!   B-panel packing reorder work *across* output elements, never within
//!   one, so the tile shape cannot change bits.
//! * **A·Bᵀ output elements** ([`mm_bt_rows`]): each `c[i][j]` is one
//!   [`dot_f32`] in the canonical order above.
//! * **[`axpy_f32`]**: element-wise (`y[j] += a·x[j]`, one multiply and one
//!   add per element) — there is no reduction, so any vector width computes
//!   identical bits by construction.
//!
//! Because both backends implement the same orders, results are
//! bit-identical across backends — and therefore across ISAs whose SIMD
//! units perform IEEE-754 single-rounding mul/add, which is every target
//! this crate supports.  `rust/tests/kernel_equiv.rs` enforces the contract
//! on adversarial shapes (every remainder lane, unaligned offsets,
//! denormals, signed zeros), and ci.sh runs the whole test suite under both
//! backends.
//!
//! # Why no FMA
//!
//! A fused multiply-add rounds once where mul+add rounds twice, so an FMA
//! backend could only be bit-identical to a portable fallback that routes
//! every scalar MAC through `f32::mul_add` — a libm call on targets without
//! hardware FMA, which would make the portable lane (and the
//! `PALLAS_NO_SIMD=1` CI lane) pathologically slow.  The speedup here comes
//! from lane width and register tiling, not fusion; the AVX2 backend
//! deliberately uses `vmulps`/`vaddps` only.
//!
//! # Backend selection
//!
//! [`active_backend`] resolves, in priority order:
//!
//! 1. a [`force_backend`] override (the test hook, also wired from
//!    `ExperimentConfig::no_simd` / `--no-simd` by the coordinator);
//! 2. the `PALLAS_NO_SIMD` environment variable (any non-empty value other
//!    than `0` forces [`Backend::Portable`]);
//! 3. runtime CPU detection: AVX2 if the host reports it, else portable.
//!
//! Selection is process-global and costs one relaxed atomic load per kernel
//! call.  Forcing [`Backend::Avx2`] on a host without AVX2 resolves to
//! portable — the knob can never make the process execute illegal
//! instructions.
//!
//! # Zero-skip branches are gone
//!
//! The pre-SIMD blocked kernel skipped `a[i][k] == 0.0` rows of B.  The
//! skip is dropped from **every** backend, not just the tiled one: besides
//! defeating vectorization, a skip kept in one backend but not the other
//! would be observable — adding a `+0.0` term flips a `-0.0` accumulator to
//! `+0.0`, and `0·inf` is NaN — so it would break the exact bit-identity
//! this layer exists to provide.  The sparse-ish whitening inputs that made
//! the branch pay are now served by raw 8-wide throughput instead.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// f32 accumulator lanes in the canonical dot-product order.
pub const LANES: usize = 8;

/// f64 accumulator lanes in the canonical row-reduction order.
pub const F64_LANES: usize = 4;

// ---------------------------------------------------------------------------
// backend selection
// ---------------------------------------------------------------------------

/// One of the two interchangeable (bit-identical) kernel implementations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Lane-strided scalar code — runs everywhere, and doubles as the
    /// executable specification of the canonical accumulation orders.
    Portable,
    /// `core::arch::x86_64` AVX2 intrinsics (256-bit `vmulps`/`vaddps`),
    /// selected only when the running CPU reports AVX2 support.
    Avx2,
}

const MODE_UNSET: u8 = 0;
const MODE_PORTABLE: u8 = 1;
const MODE_AVX2: u8 = 2;

/// Resolved backend, cached after first use.  `MODE_UNSET` until then;
/// [`force_backend`] stores directly.
static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

#[cfg(target_arch = "x86_64")]
fn detect_avx2() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| is_x86_feature_detected!("avx2"))
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_avx2() -> bool {
    false
}

/// True when the running CPU supports the SIMD backend (AVX2).  Purely
/// informational — dispatch happens through [`active_backend`].
pub fn simd_available() -> bool {
    detect_avx2()
}

/// `PALLAS_NO_SIMD` semantics: set to anything non-empty except `0` to
/// force the portable backend.  Factored out so the parse is unit-testable
/// (the env read itself is cached once per process).
fn parse_no_simd(v: Option<&str>) -> bool {
    match v {
        Some(s) => {
            let t = s.trim();
            !t.is_empty() && t != "0"
        }
        None => false,
    }
}

fn env_no_simd() -> bool {
    static NO_SIMD: OnceLock<bool> = OnceLock::new();
    *NO_SIMD
        .get_or_init(|| parse_no_simd(std::env::var("PALLAS_NO_SIMD").ok().as_deref()))
}

fn resolve_auto() -> u8 {
    if !env_no_simd() && detect_avx2() {
        MODE_AVX2
    } else {
        MODE_PORTABLE
    }
}

#[inline]
fn mode() -> u8 {
    let m = MODE.load(Ordering::Relaxed);
    if m != MODE_UNSET {
        return m;
    }
    let r = resolve_auto();
    MODE.store(r, Ordering::Relaxed);
    r
}

/// The backend every kernel in this module currently dispatches to.
pub fn active_backend() -> Backend {
    if mode() == MODE_AVX2 {
        Backend::Avx2
    } else {
        Backend::Portable
    }
}

/// Lower-case label of the active backend, for observability records
/// (`obs::kernel_record` keys timing aggregates by it).
pub fn backend_label() -> &'static str {
    match active_backend() {
        Backend::Avx2 => "avx2",
        Backend::Portable => "portable",
    }
}

/// Override backend selection for this process (the `kernel_equiv` test
/// hook, and how `ExperimentConfig::no_simd` forces the portable lane).
/// `None` restores automatic resolution (`PALLAS_NO_SIMD` env, then CPU
/// detection).  Forcing [`Backend::Avx2`] on a host without AVX2 resolves
/// to [`Backend::Portable`] — results are bit-identical either way, so the
/// demotion is observable only through [`active_backend`].
pub fn force_backend(b: Option<Backend>) {
    let m = match b {
        Some(Backend::Portable) => MODE_PORTABLE,
        Some(Backend::Avx2) => {
            if detect_avx2() {
                MODE_AVX2
            } else {
                MODE_PORTABLE
            }
        }
        None => resolve_auto(),
    };
    MODE.store(m, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// public kernels (dispatchers)
// ---------------------------------------------------------------------------

/// Canonical fixed-order f32 dot product — THE accumulation every
/// projection kernel builds on, hence the unit of bit-reproducibility
/// (8-lane-strided; see the module docs for the exact combine order).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot_f32: length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 is only ever stored after runtime AVX2
            // detection succeeded (see `resolve_auto` / `force_backend`).
            return unsafe { avx2::dot(a, b) };
        }
    }
    portable::dot(a, b)
}

/// `y[j] += a · x[j]` over `y.len()` elements (`x` must be at least as
/// long).  Element-wise — no reduction — so backends agree by construction;
/// carries the attention value merges and the Gram row updates.
#[inline]
pub fn axpy_f32(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert!(x.len() >= y.len(), "axpy_f32: x shorter than y");
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 implies runtime AVX2 detection succeeded.
            unsafe { avx2::axpy(y, a, x) };
            return;
        }
    }
    portable::axpy(y, a, x);
}

/// Fixed-order f64 sum of an f32 slice (4-lane-strided) — the LayerNorm
/// mean reduction.
#[inline]
pub fn sum_f64(x: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 implies runtime AVX2 detection succeeded.
            return unsafe { avx2::sum(x) };
        }
    }
    portable::sum(x)
}

/// Fixed-order f64 sum of squares of an f32 slice (4-lane-strided) — the
/// RMSNorm mean-square reduction.
#[inline]
pub fn sum_sq_f64(x: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 implies runtime AVX2 detection succeeded.
            return unsafe { avx2::sum_sq(x) };
        }
    }
    portable::sum_sq(x)
}

/// Fixed-order f64 sum of squared f32 deviations from `mu` (the deviation
/// is rounded in f32 first, exactly as the scalar LayerNorm variance loop
/// always did; 4-lane-strided).
#[inline]
pub fn sum_sq_centered_f64(x: &[f32], mu: f32) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 implies runtime AVX2 detection succeeded.
            return unsafe { avx2::sum_sq_centered(x, mu) };
        }
    }
    portable::sum_sq_centered(x, mu)
}

/// C = A·B over the output-row band `[row0, row0 + rows)`.
///
/// `a_data` is row-major with row length `k`, `b_data` row-major
/// `k × n`, and `c_rows` the **zero-initialized** destination band
/// (`rows · n` values) — the AVX2 tile kernel overwrites it while the
/// portable path accumulates in place, which only coincide from zero.
/// Per output element the k-loop order is fixed (ascending, one f32
/// rounding per step), so any row partition of the output — and either
/// backend — accumulates identical bits.
pub fn mm_rows(a_data: &[f32], k: usize, row0: usize, rows: usize,
               b_data: &[f32], n: usize, c_rows: &mut [f32]) {
    debug_assert!(a_data.len() >= (row0 + rows) * k, "mm_rows: A too short");
    debug_assert_eq!(b_data.len(), k * n, "mm_rows: ragged B");
    debug_assert!(c_rows.len() >= rows * n, "mm_rows: C band too short");
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 implies runtime AVX2 detection succeeded.
            unsafe { avx2::mm_rows(a_data, k, row0, rows, b_data, n, c_rows) };
            return;
        }
    }
    portable::mm_rows(a_data, k, row0, rows, b_data, n, c_rows);
}

/// C = A·Bᵀ over the output-row band `[row0, row0 + rows)` — B stays
/// row-major `n × k` (rows contiguous), every output element is one
/// [`dot_f32`] in the canonical order, written (not accumulated) into
/// `c_rows`.
pub fn mm_bt_rows(a_data: &[f32], k: usize, row0: usize, rows: usize,
                  b_data: &[f32], n: usize, c_rows: &mut [f32]) {
    debug_assert!(a_data.len() >= (row0 + rows) * k, "mm_bt_rows: A too short");
    debug_assert_eq!(b_data.len(), n * k, "mm_bt_rows: ragged B");
    debug_assert!(c_rows.len() >= rows * n, "mm_bt_rows: C band too short");
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 implies runtime AVX2 detection succeeded.
            unsafe {
                avx2::mm_bt_rows(a_data, k, row0, rows, b_data, n, c_rows)
            };
            return;
        }
    }
    portable::mm_bt_rows(a_data, k, row0, rows, b_data, n, c_rows);
}

// ---------------------------------------------------------------------------
// portable backend — the executable spec of the canonical orders
// ---------------------------------------------------------------------------

mod portable {
    /// The canonical 8-lane horizontal combine: low/high halves pair up,
    /// the pairs pair up, the final two add — exactly what the AVX2 hsum
    /// sequence (extract+add, movehl+add, scalar add) computes.
    #[inline]
    pub(super) fn combine8(acc: &[f32; 8]) -> f32 {
        let t0 = acc[0] + acc[4];
        let t1 = acc[1] + acc[5];
        let t2 = acc[2] + acc[6];
        let t3 = acc[3] + acc[7];
        (t0 + t2) + (t1 + t3)
    }

    #[inline]
    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        // clamp like the AVX2 path, so a length-contract violation degrades
        // identically on both backends instead of indexing past the shorter
        let n = a.len().min(b.len());
        let mut acc = [0.0f32; 8];
        for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
            acc[0] += ca[0] * cb[0];
            acc[1] += ca[1] * cb[1];
            acc[2] += ca[2] * cb[2];
            acc[3] += ca[3] * cb[3];
            acc[4] += ca[4] * cb[4];
            acc[5] += ca[5] * cb[5];
            acc[6] += ca[6] * cb[6];
            acc[7] += ca[7] * cb[7];
        }
        let mut s = combine8(&acc);
        for i in n / 8 * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    #[inline]
    pub(super) fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        for (yv, &xv) in y.iter_mut().zip(x) {
            *yv += a * xv;
        }
    }

    #[inline]
    pub(super) fn sum(x: &[f32]) -> f64 {
        let mut acc = [0.0f64; 4];
        for c in x.chunks_exact(4) {
            acc[0] += c[0] as f64;
            acc[1] += c[1] as f64;
            acc[2] += c[2] as f64;
            acc[3] += c[3] as f64;
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        let tail = x.len() / 4 * 4;
        for &v in &x[tail..] {
            s += v as f64;
        }
        s
    }

    #[inline]
    pub(super) fn sum_sq(x: &[f32]) -> f64 {
        let mut acc = [0.0f64; 4];
        for c in x.chunks_exact(4) {
            let (v0, v1, v2, v3) =
                (c[0] as f64, c[1] as f64, c[2] as f64, c[3] as f64);
            acc[0] += v0 * v0;
            acc[1] += v1 * v1;
            acc[2] += v2 * v2;
            acc[3] += v3 * v3;
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        let tail = x.len() / 4 * 4;
        for &v in &x[tail..] {
            let v = v as f64;
            s += v * v;
        }
        s
    }

    #[inline]
    pub(super) fn sum_sq_centered(x: &[f32], mu: f32) -> f64 {
        let mut acc = [0.0f64; 4];
        for c in x.chunks_exact(4) {
            // the deviation rounds in f32 BEFORE widening — the canonical
            // order matches the original scalar LayerNorm variance loop
            let (v0, v1, v2, v3) = ((c[0] - mu) as f64, (c[1] - mu) as f64,
                                    (c[2] - mu) as f64, (c[3] - mu) as f64);
            acc[0] += v0 * v0;
            acc[1] += v1 * v1;
            acc[2] += v2 * v2;
            acc[3] += v3 * v3;
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        let tail = x.len() / 4 * 4;
        for &v in &x[tail..] {
            let v = (v - mu) as f64;
            s += v * v;
        }
        s
    }

    /// Blocked i-k-j GEMM band (cache blocking only — per output element
    /// the k order stays plainly ascending, so blocks cannot change bits).
    pub(super) fn mm_rows(a_data: &[f32], k: usize, row0: usize, rows: usize,
                          b_data: &[f32], n: usize, c_rows: &mut [f32]) {
        const BK: usize = 64;
        const BJ: usize = 256;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for jb in (0..n).step_by(BJ) {
                let jend = (jb + BJ).min(n);
                for i in 0..rows {
                    let arow = &a_data[(row0 + i) * k..(row0 + i + 1) * k];
                    let crow = &mut c_rows[i * n..(i + 1) * n];
                    for kk in kb..kend {
                        let aik = arow[kk];
                        let brow = &b_data[kk * n..(kk + 1) * n];
                        for j in jb..jend {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }

    pub(super) fn mm_bt_rows(a_data: &[f32], k: usize, row0: usize,
                             rows: usize, b_data: &[f32], n: usize,
                             c_rows: &mut [f32]) {
        for i in 0..rows {
            let arow = &a_data[(row0 + i) * k..(row0 + i + 1) * k];
            let crow = &mut c_rows[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] = dot(arow, &b_data[j * k..(j + 1) * k]);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 backend
// ---------------------------------------------------------------------------

/// AVX2 implementations.  Every `unsafe fn` here requires the caller to
/// have verified AVX2 support at runtime (the dispatchers above do).  The
/// horizontal-reduction sequences are the bit-level definition the portable
/// backend mirrors — change one, change both, and re-baseline the parity
/// gates.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))` — the canonical 8-lane
    /// combine.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum8(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let t = _mm_add_ps(lo, hi); // [l0+l4, l1+l5, l2+l6, l3+l7]
        let u = _mm_add_ps(t, _mm_movehl_ps(t, t)); // [t0+t2, t1+t3, ..]
        _mm_cvtss_f32(_mm_add_ss(u, _mm_movehdup_ps(u))) // (t0+t2)+(t1+t3)
    }

    /// `(l0+l2) + (l1+l3)` — the canonical 4-lane f64 combine.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum4d(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v);
        let hi = _mm256_extractf128_pd::<1>(v);
        let t = _mm_add_pd(lo, hi); // [l0+l2, l1+l3]
        _mm_cvtsd_f64(_mm_add_sd(t, _mm_unpackhi_pd(t, t)))
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let va = _mm256_loadu_ps(ap.add(c * 8));
            let vb = _mm256_loadu_ps(bp.add(c * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut s = hsum8(acc);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        // clamp like the portable zip does, so a length-contract violation
        // degrades identically on both backends instead of reading past x
        let n = y.len().min(x.len());
        let chunks = n / 8;
        let va = _mm256_set1_ps(a);
        for c in 0..chunks {
            let i = c * 8;
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i),
                             _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
        }
        for i in chunks * 8..n {
            y[i] += a * x[i];
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sum(x: &[f32]) -> f64 {
        let chunks = x.len() / 4;
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            let v = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(c * 4)));
            acc = _mm256_add_pd(acc, v);
        }
        let mut s = hsum4d(acc);
        for &v in &x[chunks * 4..] {
            s += v as f64;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sum_sq(x: &[f32]) -> f64 {
        let chunks = x.len() / 4;
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            let v = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(c * 4)));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(v, v));
        }
        let mut s = hsum4d(acc);
        for &v in &x[chunks * 4..] {
            let v = v as f64;
            s += v * v;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sum_sq_centered(x: &[f32], mu: f32) -> f64 {
        let chunks = x.len() / 4;
        let vmu = _mm_set1_ps(mu);
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            // f32 subtraction first, then widen — mirrors the portable lane
            let d = _mm_sub_ps(_mm_loadu_ps(x.as_ptr().add(c * 4)), vmu);
            let v = _mm256_cvtps_pd(d);
            acc = _mm256_add_pd(acc, _mm256_mul_pd(v, v));
        }
        let mut s = hsum4d(acc);
        for &v in &x[chunks * 4..] {
            let v = (v - mu) as f64;
            s += v * v;
        }
        s
    }

    /// Output rows per register tile.
    const MR: usize = 4;
    /// Output columns per packed B panel (two 8-lane registers).
    const NR: usize = 16;

    /// Register-tiled A·B band: B is packed into contiguous `k × NR`
    /// column panels, each reused by every `MR × NR` output tile of the
    /// band.  Accumulators live in registers for the whole k loop — per
    /// output element that is the same "one f32 rounding per ascending k"
    /// the portable blocked kernel performs in memory.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mm_rows(a_data: &[f32], k: usize, row0: usize,
                                 rows: usize, b_data: &[f32], n: usize,
                                 c_rows: &mut [f32]) {
        let j_main = n / NR * NR; // columns covered by full-width panels
        let mut panel = vec![0.0f32; if j_main > 0 { k * NR } else { 0 }];
        let mut j0 = 0usize;
        while j0 + NR <= n {
            for kk in 0..k {
                panel[kk * NR..(kk + 1) * NR]
                    .copy_from_slice(&b_data[kk * n + j0..kk * n + j0 + NR]);
            }
            let pp = panel.as_ptr();
            let mut i = 0usize;
            while i + MR <= rows {
                let mut acc = [[_mm256_setzero_ps(); 2]; MR];
                for kk in 0..k {
                    let b0 = _mm256_loadu_ps(pp.add(kk * NR));
                    let b1 = _mm256_loadu_ps(pp.add(kk * NR + 8));
                    for (r, a2) in acc.iter_mut().enumerate() {
                        let aik =
                            _mm256_set1_ps(a_data[(row0 + i + r) * k + kk]);
                        a2[0] = _mm256_add_ps(a2[0], _mm256_mul_ps(aik, b0));
                        a2[1] = _mm256_add_ps(a2[1], _mm256_mul_ps(aik, b1));
                    }
                }
                for (r, a2) in acc.iter().enumerate() {
                    let dst = c_rows[(i + r) * n + j0..].as_mut_ptr();
                    _mm256_storeu_ps(dst, a2[0]);
                    _mm256_storeu_ps(dst.add(8), a2[1]);
                }
                i += MR;
            }
            while i < rows {
                let mut a0 = _mm256_setzero_ps();
                let mut a1 = _mm256_setzero_ps();
                for kk in 0..k {
                    let aik = _mm256_set1_ps(a_data[(row0 + i) * k + kk]);
                    a0 = _mm256_add_ps(a0,
                        _mm256_mul_ps(aik, _mm256_loadu_ps(pp.add(kk * NR))));
                    a1 = _mm256_add_ps(a1,
                        _mm256_mul_ps(aik,
                                      _mm256_loadu_ps(pp.add(kk * NR + 8))));
                }
                let dst = c_rows[i * n + j0..].as_mut_ptr();
                _mm256_storeu_ps(dst, a0);
                _mm256_storeu_ps(dst.add(8), a1);
                i += 1;
            }
            j0 += NR;
        }
        // column remainder (n % NR): scalar single-accumulator k-ascending
        // per element — same canonical order, at most NR-1 columns of work
        if j0 < n {
            for i in 0..rows {
                let arow = &a_data[(row0 + i) * k..(row0 + i + 1) * k];
                for j in j0..n {
                    let mut s = 0.0f32;
                    for (kk, &aik) in arow.iter().enumerate() {
                        s += aik * b_data[kk * n + j];
                    }
                    c_rows[i * n + j] = s;
                }
            }
        }
    }

    /// A·Bᵀ band: four output columns share each pass over the A row, as
    /// four *independent* canonical dot accumulations — inter-output tiling
    /// buys ILP without touching any per-output order.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mm_bt_rows(a_data: &[f32], k: usize, row0: usize,
                                    rows: usize, b_data: &[f32], n: usize,
                                    c_rows: &mut [f32]) {
        let chunks = k / 8;
        let tail = chunks * 8;
        for i in 0..rows {
            let arow = &a_data[(row0 + i) * k..(row0 + i + 1) * k];
            let ap = arow.as_ptr();
            let crow = &mut c_rows[i * n..(i + 1) * n];
            let mut j = 0usize;
            while j + 4 <= n {
                let b0 = &b_data[j * k..(j + 1) * k];
                let b1 = &b_data[(j + 1) * k..(j + 2) * k];
                let b2 = &b_data[(j + 2) * k..(j + 3) * k];
                let b3 = &b_data[(j + 3) * k..(j + 4) * k];
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                let mut acc2 = _mm256_setzero_ps();
                let mut acc3 = _mm256_setzero_ps();
                for c in 0..chunks {
                    let o = c * 8;
                    let va = _mm256_loadu_ps(ap.add(o));
                    acc0 = _mm256_add_ps(acc0,
                        _mm256_mul_ps(va, _mm256_loadu_ps(b0.as_ptr().add(o))));
                    acc1 = _mm256_add_ps(acc1,
                        _mm256_mul_ps(va, _mm256_loadu_ps(b1.as_ptr().add(o))));
                    acc2 = _mm256_add_ps(acc2,
                        _mm256_mul_ps(va, _mm256_loadu_ps(b2.as_ptr().add(o))));
                    acc3 = _mm256_add_ps(acc3,
                        _mm256_mul_ps(va, _mm256_loadu_ps(b3.as_ptr().add(o))));
                }
                let mut s0 = hsum8(acc0);
                let mut s1 = hsum8(acc1);
                let mut s2 = hsum8(acc2);
                let mut s3 = hsum8(acc3);
                for t in tail..k {
                    let av = arow[t];
                    s0 += av * b0[t];
                    s1 += av * b1[t];
                    s2 += av * b2[t];
                    s3 += av * b3[t];
                }
                crow[j] = s0;
                crow[j + 1] = s1;
                crow[j + 2] = s2;
                crow[j + 3] = s3;
                j += 4;
            }
            while j < n {
                crow[j] = dot(arow, &b_data[j * k..(j + 1) * k]);
                j += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // NOTE: no `force_backend` calls in lib unit tests — dispatch state is
    // process-global and other unit tests compute through it concurrently.
    // Cross-backend checks below call the backend functions DIRECTLY, which
    // touches no shared state; the dispatch-level sweeps live in the
    // dedicated `rust/tests/kernel_equiv.rs` binary.

    /// Adversarial f32 payload: normals across magnitudes, exact and signed
    /// zeros, and denormals — everything the bit-identity contract must
    /// survive.
    fn adversarial(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| match i % 7 {
                0 => 0.0,
                1 => -0.0,
                2 => f32::from_bits(1 + (i as u32 % 9)), // denormals
                3 => -f32::from_bits(3 + (i as u32 % 5)),
                4 => (rng.uniform() as f32 - 0.5) * 1e-20,
                5 => (rng.uniform() as f32 - 0.5) * 1e20,
                _ => rng.uniform() as f32 - 0.5,
            })
            .collect()
    }

    #[test]
    fn portable_dot_matches_f64_reference() {
        let mut rng = Rng::new(1);
        for len in [0usize, 1, 3, 8, 13, 64, 130] {
            let a: Vec<f32> =
                (0..len).map(|_| rng.uniform() as f32 - 0.5).collect();
            let b: Vec<f32> =
                (0..len).map(|_| rng.uniform() as f32 - 0.5).collect();
            let exact: f64 = a.iter().zip(&b)
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            let got = portable::dot(&a, &b) as f64;
            assert!((got - exact).abs() <= 1e-5 * (1.0 + exact.abs()),
                    "len {len}: {got} vs {exact}");
        }
    }

    #[test]
    fn portable_dot_is_the_documented_lane_order() {
        // independent re-derivation of the canonical order straight from
        // the module docs, to pin the spec against refactor drift
        let mut rng = Rng::new(2);
        for len in [5usize, 8, 9, 16, 23, 65] {
            let a = adversarial(&mut rng, len);
            let b = adversarial(&mut rng, len);
            let mut acc = [0.0f32; 8];
            let main = len / 8 * 8;
            for i in 0..main {
                acc[i % 8] += a[i] * b[i];
            }
            let mut want = ((acc[0] + acc[4]) + (acc[2] + acc[6]))
                + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
            for i in main..len {
                want += a[i] * b[i];
            }
            assert_eq!(portable::dot(&a, &b).to_bits(), want.to_bits(),
                       "len {len}");
        }
    }

    #[test]
    fn portable_sums_match_f64_reference() {
        let mut rng = Rng::new(3);
        for len in [0usize, 1, 4, 6, 128, 131] {
            let x: Vec<f32> =
                (0..len).map(|_| rng.uniform() as f32 - 0.5).collect();
            let s: f64 = x.iter().map(|&v| v as f64).sum();
            assert!((portable::sum(&x) - s).abs() <= 1e-9 * (1.0 + s.abs()));
            let sq: f64 = x.iter().map(|&v| v as f64 * v as f64).sum();
            assert!((portable::sum_sq(&x) - sq).abs()
                        <= 1e-9 * (1.0 + sq.abs()));
            let mu = 0.25f32;
            let c: f64 = x.iter()
                .map(|&v| {
                    let d = (v - mu) as f64;
                    d * d
                })
                .sum();
            assert!((portable::sum_sq_centered(&x, mu) - c).abs()
                        <= 1e-9 * (1.0 + c.abs()));
        }
    }

    #[test]
    fn portable_mm_kernels_match_naive() {
        let mut rng = Rng::new(4);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 2), (4, 16, 16),
                            (7, 33, 19), (2, 0, 4)] {
            let a: Vec<f32> =
                (0..m * k).map(|_| rng.uniform() as f32 - 0.5).collect();
            let b: Vec<f32> =
                (0..k * n).map(|_| rng.uniform() as f32 - 0.5).collect();
            let mut c = vec![0.0f32; m * n];
            portable::mm_rows(&a, k, 0, m, &b, n, &mut c);
            let bt: Vec<f32> = {
                // n × k transpose of b for the bt kernel
                let mut t = vec![0.0f32; n * k];
                for kk in 0..k {
                    for j in 0..n {
                        t[j * k + kk] = b[kk * n + j];
                    }
                }
                t
            };
            let mut cbt = vec![0.0f32; m * n];
            portable::mm_bt_rows(&a, k, 0, m, &bt, n, &mut cbt);
            for i in 0..m {
                for j in 0..n {
                    let exact: f64 = (0..k)
                        .map(|kk| a[i * k + kk] as f64 * b[kk * n + j] as f64)
                        .sum();
                    let got = c[i * n + j] as f64;
                    assert!((got - exact).abs() <= 1e-5 * (1.0 + exact.abs()),
                            "mm ({m},{k},{n}) at ({i},{j})");
                    let gbt = cbt[i * n + j] as f64;
                    assert!((gbt - exact).abs() <= 1e-5 * (1.0 + exact.abs()),
                            "mm_bt ({m},{k},{n}) at ({i},{j})");
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_backend_bit_matches_portable_directly() {
        if !detect_avx2() {
            eprintln!("avx2 unavailable on this host; direct backend \
                       comparison skipped");
            return;
        }
        let mut rng = Rng::new(5);
        // every remainder lane + unaligned starts, on adversarial payloads
        for len in 0..=65usize {
            for off in [0usize, 1, 3] {
                let a = adversarial(&mut rng, len + off);
                let b = adversarial(&mut rng, len + off);
                let (sa, sb) = (&a[off..], &b[off..]);
                let p = portable::dot(sa, sb);
                // SAFETY: detect_avx2() checked above.
                let v = unsafe { avx2::dot(sa, sb) };
                assert_eq!(p.to_bits(), v.to_bits(),
                           "dot len {len} off {off}: {p} vs {v}");

                let ps = portable::sum(sa);
                // SAFETY: detect_avx2() checked above.
                let vs = unsafe { avx2::sum(sa) };
                assert_eq!(ps.to_bits(), vs.to_bits(), "sum len {len}");
                let pq = portable::sum_sq(sa);
                // SAFETY: detect_avx2() checked above.
                let vq = unsafe { avx2::sum_sq(sa) };
                assert_eq!(pq.to_bits(), vq.to_bits(), "sum_sq len {len}");
                let pc = portable::sum_sq_centered(sa, 0.125);
                // SAFETY: detect_avx2() checked above.
                let vc = unsafe { avx2::sum_sq_centered(sa, 0.125) };
                assert_eq!(pc.to_bits(), vc.to_bits(), "centered len {len}");

                let mut yp = adversarial(&mut rng, len);
                let mut yv = yp.clone();
                portable::axpy(&mut yp, 0.37, &sa[..len.min(sa.len())]);
                // SAFETY: detect_avx2() checked above.
                unsafe { avx2::axpy(&mut yv, 0.37, &sa[..len.min(sa.len())]) };
                assert_eq!(
                    yp.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    yv.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    "axpy len {len}"
                );
            }
        }
        // GEMM bands across tile remainders (rows % 4, cols % 16, k % 8)
        for &(m, k, n) in &[(1usize, 7usize, 15usize), (4, 8, 16), (5, 9, 17),
                            (8, 64, 48), (3, 65, 33), (6, 0, 5)] {
            let a = adversarial(&mut rng, m * k);
            let b = adversarial(&mut rng, k * n);
            let bt = adversarial(&mut rng, n * k);
            let mut cp = vec![0.0f32; m * n];
            let mut cv = vec![0.0f32; m * n];
            portable::mm_rows(&a, k, 0, m, &b, n, &mut cp);
            // SAFETY: detect_avx2() checked above.
            unsafe { avx2::mm_rows(&a, k, 0, m, &b, n, &mut cv) };
            assert_eq!(cp.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                       cv.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                       "mm_rows ({m},{k},{n})");
            let mut dp = vec![0.0f32; m * n];
            let mut dv = vec![0.0f32; m * n];
            portable::mm_bt_rows(&a, k, 0, m, &bt, n, &mut dp);
            // SAFETY: detect_avx2() checked above.
            unsafe { avx2::mm_bt_rows(&a, k, 0, m, &bt, n, &mut dv) };
            assert_eq!(dp.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                       dv.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                       "mm_bt_rows ({m},{k},{n})");
        }
    }

    #[test]
    fn no_simd_env_parse() {
        assert!(!parse_no_simd(None));
        assert!(!parse_no_simd(Some("")));
        assert!(!parse_no_simd(Some("  ")));
        assert!(!parse_no_simd(Some("0")));
        assert!(parse_no_simd(Some("1")));
        assert!(parse_no_simd(Some("true")));
        assert!(parse_no_simd(Some(" yes ")));
    }

    #[test]
    fn backend_resolution_is_consistent() {
        // read-only: forcing would race other unit tests in this binary
        let b = active_backend();
        assert_eq!(b, active_backend(), "resolution must be stable");
        if b == Backend::Avx2 {
            assert!(simd_available());
        }
    }
}
