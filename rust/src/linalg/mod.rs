//! Pure-Rust dense linear algebra substrate (DESIGN.md §4).
//!
//! Everything the compression pipeline needs: blocked matmul, Cholesky
//! whitening + triangular solves, one-sided Jacobi SVD, Householder QR,
//! effective-rank utilities.  No BLAS, no external crates; f64 accumulation
//! where conditioning demands it.

pub mod cholesky;
pub mod matmul;
pub mod qr;
pub mod svd;

pub use cholesky::{cholesky, cholesky_ridge, right_solve_lower, right_solve_lower_t,
                   solve_lower, solve_lower_t};
pub use matmul::{gram, matmul, matmul_bt, matmul_bt_flat, matmul_flat,
                 matmul_serial};
pub use svd::{effective_rank, factor, reconstruct, svd, tail_energy, Svd};
