//! Pure-Rust dense linear algebra substrate (DESIGN.md §4).
//!
//! Everything the compression pipeline needs: blocked matmul, Cholesky
//! whitening + triangular solves, one-sided Jacobi SVD, Householder QR,
//! effective-rank utilities.  No BLAS, no external crates; f64 accumulation
//! where conditioning demands it.
//!
//! The innermost MAC loops live in [`kernels`] — a SIMD micro-kernel layer
//! with an AVX2 backend behind runtime feature detection and a portable
//! fallback that executes the *same* canonical lane-strided accumulation
//! orders, so results are bit-identical across backends, ISAs, and thread
//! counts (`PALLAS_NO_SIMD` / `ExperimentConfig::no_simd` forces the
//! portable lane; `rust/tests/kernel_equiv.rs` is the gate).

pub mod cholesky;
pub mod kernels;
pub mod matmul;
pub mod qr;
pub mod svd;

pub use cholesky::{cholesky, cholesky_ridge, right_solve_lower, right_solve_lower_t,
                   solve_lower, solve_lower_t};
pub use kernels::{active_backend, force_backend, simd_available, Backend};
pub use matmul::{axpy_f32, dot_f32, gram, matmul, matmul_bt, matmul_bt_flat,
                 matmul_flat, matmul_serial, PAR_MIN_MACS};
pub use svd::{effective_rank, factor, reconstruct, svd, tail_energy, Svd};
