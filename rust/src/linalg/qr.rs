//! Householder QR — used for random orthogonal matrices in tests/benches and
//! as an independent orthogonality oracle for the SVD.

use crate::tensor::Mat;
use crate::util::rng::Rng;

/// Thin QR: A (m×n, m ≥ n) = Q (m×n, orthonormal cols) · R (n×n upper).
pub fn qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "thin qr wants m >= n");
    let mut r = a.clone();
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);

    for k in 0..n {
        // Householder vector for column k below the diagonal
        let mut norm2 = 0.0f64;
        for i in k..m {
            let x = r.at(i, k) as f64;
            norm2 += x * x;
        }
        let norm = norm2.sqrt() as f32;
        let mut v = vec![0.0f32; m - k];
        let x0 = r.at(k, k);
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        v[0] = x0 - alpha;
        for i in k + 1..m {
            v[i - k] = r.at(i, k);
        }
        let vnorm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if vnorm2 > 0.0 {
            // apply H = I - 2 v vᵀ / (vᵀv) to R[k:, k:]
            for j in k..n {
                let mut dot = 0.0f64;
                for i in k..m {
                    dot += v[i - k] as f64 * r.at(i, j) as f64;
                }
                let f = (2.0 * dot / vnorm2) as f32;
                for i in k..m {
                    *r.at_mut(i, j) -= f * v[i - k];
                }
            }
        }
        vs.push(v);
    }

    // accumulate Q = H_0 H_1 ... H_{n-1} · [I; 0]
    let mut q = Mat::zeros(m, n);
    for i in 0..n {
        q.data[i * n + i] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f64;
            for i in k..m {
                dot += v[i - k] as f64 * q.at(i, j) as f64;
            }
            let f = (2.0 * dot / vnorm2) as f32;
            for i in k..m {
                *q.at_mut(i, j) -= f * v[i - k];
            }
        }
    }

    // zero R's strictly-lower part (numerical dust) and return top n×n
    let mut rout = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rout.data[i * n + j] = r.at(i, j);
        }
    }
    (q, rout)
}

/// Haar-ish random orthogonal n×n matrix (QR of a Gaussian matrix).
pub fn random_orthogonal(rng: &mut Rng, n: usize) -> Mat {
    let a = Mat::randn(rng, n, n, 1.0);
    let (q, _) = qr(&a);
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "{x} vs {y}");
        }
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(31);
        for (m, n) in [(5, 5), (12, 7), (40, 40), (3, 1)] {
            let a = Mat::randn(&mut rng, m, n, 1.0);
            let (q, r) = qr(&a);
            assert_close(&matmul(&q, &r), &a, 1e-3);
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(32);
        let a = Mat::randn(&mut rng, 20, 13, 1.0);
        let (q, _) = qr(&a);
        let g = matmul(&q.transpose(), &q);
        assert_close(&g, &Mat::eye(13), 1e-4);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(33);
        let a = Mat::randn(&mut rng, 9, 6, 1.0);
        let (_, r) = qr(&a);
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Rng::new(34);
        let q = random_orthogonal(&mut rng, 16);
        let g = matmul(&q.transpose(), &q);
        assert_close(&g, &Mat::eye(16), 1e-4);
    }
}
