//! One-sided Jacobi SVD — the truncation substrate.
//!
//! Every compression method in this repo funnels through `svd`: it must be
//! robust (whitened matrices can be very ill-conditioned) and exact enough
//! that the zero-sum ΔL estimates mean something.  One-sided Jacobi is the
//! right tool at this scale (matrices up to ~512×512): simple, numerically
//! strong, and singular vectors come out orthogonal to machine precision.
//!
//! Convention: `svd(A)` with A (m×n) returns U (m×r), σ (r), V (n×r) with
//! r = min(m,n), A = U·diag(σ)·Vᵀ and σ₁ ≥ σ₂ ≥ … ≥ 0.

use crate::tensor::Mat;

/// A thin SVD A = U·diag(σ)·Vᵀ.
#[derive(Clone, Debug)]
pub struct Svd {
    /// m × r, orthonormal columns
    pub u: Mat,
    /// r singular values, descending
    pub sigma: Vec<f32>,
    /// n × r, orthonormal columns
    pub v: Mat,
}

const MAX_SWEEPS: usize = 60;
const TOL: f64 = 1e-10; // on gamma² / (alpha·beta)

/// Full (thin) SVD via one-sided Jacobi.
pub fn svd(a: &Mat) -> Svd {
    if a.rows >= a.cols {
        svd_tall(a)
    } else {
        // A = U Σ Vᵀ  ⇔  Aᵀ = V Σ Uᵀ
        let s = svd_tall(&a.transpose());
        Svd { u: s.v, sigma: s.sigma, v: s.u }
    }
}

/// m ≥ n case. Works on B = Aᵀ so the columns being orthogonalized are
/// contiguous rows in memory.
///
/// Perf (§Perf, EXPERIMENTS.md): per-row squared norms are cached and
/// updated analytically after each rotation
///   α′ = c²α − 2csγ + s²β,   β′ = s²α + 2csγ + c²β
/// so a non-rotating pair costs ONE dot product (γ) instead of three —
/// the dominant cost at convergence, when almost no pair rotates.
fn svd_tall(a: &Mat) -> Svd {
    let (m, n) = (a.rows, a.cols);
    debug_assert!(m >= n);
    let mut b = a.transpose(); // n rows of length m: row i = column i of A
    let mut vrows = Mat::eye(n); // row i accumulates v_i

    // cached ||b_i||² (refreshed from scratch periodically to cap drift)
    let mut norms: Vec<f64> = (0..n).map(|i| dot64(b.row(i), b.row(i))).collect();

    for sweep in 0..MAX_SWEEPS {
        if sweep > 0 && sweep % 8 == 0 {
            for i in 0..n {
                norms[i] = dot64(b.row(i), b.row(i));
            }
        }
        let mut rotated = false;
        for i in 0..n {
            for j in i + 1..n {
                let alpha = norms[i];
                let beta = norms[j];
                let (ri, rj) = row_pair(&mut b, i, j, m);
                let gamma = dot64(ri, rj);
                if gamma * gamma <= TOL * alpha * beta || gamma == 0.0 {
                    continue;
                }
                rotated = true;
                // Jacobi rotation zeroing the (i,j) off-diagonal of BᵀB
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate(ri, rj, c as f32, s as f32);
                let (vi, vj) = row_pair(&mut vrows, i, j, n);
                rotate(vi, vj, c as f32, s as f32);
                let (cc, ss) = (c * c, s * s);
                let cross = 2.0 * c * s * gamma;
                norms[i] = cc * alpha - cross + ss * beta;
                norms[j] = ss * alpha + cross + cc * beta;
            }
        }
        if !rotated {
            break;
        }
    }

    // Extract σ and normalize; then sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sig: Vec<f64> = (0..n)
        .map(|i| dot64(b.row(i), b.row(i)).sqrt())
        .collect();
    order.sort_by(|&x, &y| sig[y].total_cmp(&sig[x]));

    let mut u = Mat::zeros(m, n);
    let mut v = Mat::zeros(n, n);
    let mut sigma = Vec::with_capacity(n);
    for (col, &src) in order.iter().enumerate() {
        let s = sig[src];
        sigma.push(s as f32);
        if s > 0.0 {
            let inv = (1.0 / s) as f32;
            for r in 0..m {
                u.data[r * n + col] = b.data[src * m + r] * inv;
            }
        }
        for r in 0..n {
            v.data[r * n + col] = vrows.data[src * n + r];
        }
    }
    // avoid the unused-assignment lint on sig
    sig.clear();
    Svd { u, sigma, v }
}

fn dot64(a: &[f32], b: &[f32]) -> f64 {
    // f64 accumulation (conditioning matters here), 4-lane unrolled so the
    // autovectorizer emits packed converts+FMAs
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] as f64 * b[i] as f64;
        acc[1] += a[i + 1] as f64 * b[i + 1] as f64;
        acc[2] += a[i + 2] as f64 * b[i + 2] as f64;
        acc[3] += a[i + 3] as f64 * b[i + 3] as f64;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] as f64 * b[i] as f64;
    }
    s
}

/// Disjoint mutable rows i<j of a matrix with row length `len`.
fn row_pair<'a>(m: &'a mut Mat, i: usize, j: usize, len: usize) -> (&'a mut [f32], &'a mut [f32]) {
    debug_assert!(i < j);
    let (head, tail) = m.data.split_at_mut(j * len);
    (&mut head[i * len..(i + 1) * len], &mut tail[..len])
}

#[inline]
fn rotate(ri: &mut [f32], rj: &mut [f32], c: f32, s: f32) {
    for (x, y) in ri.iter_mut().zip(rj.iter_mut()) {
        let xi = *x;
        let xj = *y;
        *x = c * xi - s * xj;
        *y = s * xi + c * xj;
    }
}

// ---------------------------------------------------------------------------
// derived quantities
// ---------------------------------------------------------------------------

/// Rank-k reconstruction U_k Σ_k V_kᵀ.
pub fn reconstruct(s: &Svd, k: usize) -> Mat {
    let (m, n) = (s.u.rows, s.v.rows);
    let k = k.min(s.sigma.len());
    let mut out = Mat::zeros(m, n);
    for c in 0..k {
        let sc = s.sigma[c];
        if sc == 0.0 {
            continue;
        }
        for r in 0..m {
            let us = s.u.data[r * s.u.cols + c] * sc;
            if us == 0.0 {
                continue;
            }
            let orow = &mut out.data[r * n..(r + 1) * n];
            for q in 0..n {
                orow[q] += us * s.v.data[q * s.v.cols + c];
            }
        }
    }
    out
}

/// Factored form (Wu, Wv) = (U_k √Σ_k, √Σ_k V_kᵀ) — the paper's Eq. (5)
/// *before* the S⁻¹ unwhitening (the caller applies it to Wv).
pub fn factor(s: &Svd, k: usize) -> (Mat, Mat) {
    let (m, n) = (s.u.rows, s.v.rows);
    let k = k.min(s.sigma.len());
    let mut wu = Mat::zeros(m, k);
    let mut wv = Mat::zeros(k, n);
    for c in 0..k {
        let h = s.sigma[c].max(0.0).sqrt();
        for r in 0..m {
            wu.data[r * k + c] = s.u.data[r * s.u.cols + c] * h;
        }
        for q in 0..n {
            wv.data[c * n + q] = s.v.data[q * s.v.cols + c] * h;
        }
    }
    (wu, wv)
}

/// Effective rank at energy threshold τ (paper Eq. 14):
/// smallest k with Σ_{i≤k} σᵢ² / Σ σᵢ² ≥ τ.
pub fn effective_rank(sigma: &[f32], tau: f64) -> usize {
    let total: f64 = sigma.iter().map(|&s| (s as f64) * (s as f64)).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut acc = 0.0;
    for (i, &s) in sigma.iter().enumerate() {
        acc += (s as f64) * (s as f64);
        if acc / total >= tau {
            return i + 1;
        }
    }
    sigma.len()
}

/// Tail energy Σ_{i>k} σᵢ² (Theorem 3.1's reconstruction error).
pub fn tail_energy(sigma: &[f32], k: usize) -> f64 {
    sigma[k.min(sigma.len())..]
        .iter()
        .map(|&s| (s as f64) * (s as f64))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::rng::Rng;

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "{x} vs {y}");
        }
    }

    fn check_orthonormal_cols(m: &Mat, tol: f32) {
        for i in 0..m.cols {
            for j in i..m.cols {
                let mut d = 0.0f64;
                for r in 0..m.rows {
                    d += m.data[r * m.cols + i] as f64 * m.data[r * m.cols + j] as f64;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < tol as f64, "col {i}·{j} = {d}");
            }
        }
    }

    #[test]
    fn reconstructs_exactly_at_full_rank() {
        let mut rng = Rng::new(21);
        for (m, n) in [(8, 8), (20, 12), (12, 20), (64, 33), (1, 5)] {
            let a = Mat::randn(&mut rng, m, n, 1.0);
            let s = svd(&a);
            let r = m.min(n);
            assert_eq!(s.sigma.len(), r);
            assert_close(&reconstruct(&s, r), &a, 1e-3);
            check_orthonormal_cols(&s.u, 1e-4);
            check_orthonormal_cols(&s.v, 1e-4);
            // descending
            for w in s.sigma.windows(2) {
                assert!(w[0] >= w[1] - 1e-6);
            }
        }
    }

    #[test]
    fn known_diagonal() {
        let a = Mat::from_vec(3, 2, vec![3.0, 0.0, 0.0, 2.0, 0.0, 0.0]);
        let s = svd(&a);
        assert!((s.sigma[0] - 3.0).abs() < 1e-5);
        assert!((s.sigma[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn rank_deficient() {
        let mut rng = Rng::new(22);
        // rank-2 matrix: outer product sum
        let u = Mat::randn(&mut rng, 10, 2, 1.0);
        let v = Mat::randn(&mut rng, 2, 7, 1.0);
        let a = matmul(&u, &v);
        let s = svd(&a);
        assert!(s.sigma[2] < 1e-4 * s.sigma[0].max(1.0));
        assert_close(&reconstruct(&s, 2), &a, 1e-3);
    }

    #[test]
    fn truncation_is_eckart_young() {
        // error of rank-k truncation == tail energy, and no worse than
        // dropping random components
        let mut rng = Rng::new(23);
        let a = Mat::randn(&mut rng, 24, 16, 1.0);
        let s = svd(&a);
        for k in [1, 4, 8, 15] {
            let err = a.sub(&reconstruct(&s, k)).frob_norm().powi(2);
            let tail = tail_energy(&s.sigma, k);
            assert!((err - tail).abs() / tail.max(1e-9) < 1e-2,
                    "k={k}: {err} vs {tail}");
        }
    }

    #[test]
    fn factor_matches_reconstruct() {
        let mut rng = Rng::new(24);
        let a = Mat::randn(&mut rng, 18, 11, 1.0);
        let s = svd(&a);
        let (wu, wv) = factor(&s, 5);
        assert_close(&matmul(&wu, &wv), &reconstruct(&s, 5), 1e-4);
    }

    #[test]
    fn effective_rank_cases() {
        assert_eq!(effective_rank(&[1.0, 0.0, 0.0], 0.95), 1);
        assert_eq!(effective_rank(&[1.0, 1.0, 1.0, 1.0], 0.95), 4);
        assert_eq!(effective_rank(&[], 0.95), 0);
        // 3-4-5 triangle: σ²=[16,9]: 16/25=0.64 < 0.95, need both
        assert_eq!(effective_rank(&[4.0, 3.0], 0.95), 2);
        assert_eq!(effective_rank(&[4.0, 3.0], 0.6), 1);
    }

    #[test]
    fn ill_conditioned_survives() {
        let mut rng = Rng::new(25);
        // singular values spanning 8 orders of magnitude
        let n = 12;
        let q = crate::linalg::qr::random_orthogonal(&mut rng, n);
        let mut d = Mat::zeros(n, n);
        for i in 0..n {
            d.data[i * n + i] = 10f32.powi(-(i as i32) * 2 / 3);
        }
        let a = matmul(&matmul(&q, &d), &q.transpose());
        let s = svd(&a);
        assert!((s.sigma[0] - 1.0).abs() < 1e-4);
        assert!(s.u.is_finite() && s.v.is_finite());
    }
}
