//! Cholesky factorization and triangular solves — the whitening substrate.
//!
//! The paper's whitening factor is S = chol(C + λI) with C = X·Xᵀ (Sec. 3.3).
//! Everything downstream needs only two triangular primitives:
//!   * `solve_lower`   : L·X = B      (forward substitution, multi-RHS)
//!   * `solve_lower_t` : Lᵀ·X = B     (back substitution, multi-RHS)
//! from which the library derives
//!   * W′_v = P·S⁻¹  via  (W′_v)ᵀ = solve_lower_t(S, Pᵀ)
//!   * H    = G·S⁻ᵀ  via  Hᵀ       = solve_lower(S, Gᵀ).

use crate::tensor::Mat;

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
/// Returns Err with the failing pivot index if the matrix is not PD
/// (callers add a ridge and retry).
pub fn cholesky(a: &Mat) -> Result<Mat, usize> {
    assert_eq!(a.rows, a.cols, "cholesky wants square");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // accumulate in f64: whitening matrices are ill-conditioned at
            // high calibration token counts
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if s <= 0.0 {
                    return Err(i);
                }
                *l.at_mut(i, j) = s.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (s / l.at(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Cholesky with automatic ridge escalation: tries λ, 10λ, 100λ, ... until
/// the factorization succeeds.  Returns (L, λ_used).
pub fn cholesky_ridge(c: &Mat, lambda0: f32) -> (Mat, f32) {
    let mut lambda = lambda0;
    loop {
        let mut a = c.clone();
        a.add_diag(lambda);
        match cholesky(&a) {
            Ok(l) => return (l, lambda),
            Err(_) => {
                lambda *= 10.0;
                assert!(
                    lambda.is_finite() && lambda < 1e12,
                    "cholesky_ridge: matrix is hopeless (lambda {lambda})"
                );
            }
        }
    }
}

/// Solve L·X = B for X (L lower-triangular, B is n×k).
pub fn solve_lower(l: &Mat, b: &Mat) -> Mat {
    assert_eq!(l.rows, l.cols);
    assert_eq!(l.rows, b.rows);
    let (n, k) = (b.rows, b.cols);
    let mut x = b.clone();
    for i in 0..n {
        // x[i] -= L[i, :i] · x[:i]
        for c in 0..i {
            let lic = l.at(i, c);
            if lic == 0.0 {
                continue;
            }
            let (head, tail) = x.data.split_at_mut(i * k);
            let xi = &mut tail[..k];
            let xc = &head[c * k..(c + 1) * k];
            for t in 0..k {
                xi[t] -= lic * xc[t];
            }
        }
        let d = l.at(i, i);
        for t in 0..k {
            x.data[i * k + t] /= d;
        }
    }
    x
}

/// Solve Lᵀ·X = B for X (back substitution, B is n×k).
pub fn solve_lower_t(l: &Mat, b: &Mat) -> Mat {
    assert_eq!(l.rows, l.cols);
    assert_eq!(l.rows, b.rows);
    let (n, k) = (b.rows, b.cols);
    let mut x = b.clone();
    for i in (0..n).rev() {
        // x[i] -= (Lᵀ)[i, i+1:] · x[i+1:] = L[i+1:, i] · x[i+1:]
        for c in i + 1..n {
            let lci = l.at(c, i);
            if lci == 0.0 {
                continue;
            }
            let (head, tail) = x.data.split_at_mut(c * k);
            let xi = &mut head[i * k..(i + 1) * k];
            let xc = &tail[..k];
            for t in 0..k {
                xi[t] -= lci * xc[t];
            }
        }
        let d = l.at(i, i);
        for t in 0..k {
            x.data[i * k + t] /= d;
        }
    }
    x
}

/// X = B·L⁻¹ for lower-triangular L (right-solve): Xᵀ solves Lᵀ·Xᵀ = ... —
/// implemented directly as X·L = B ⇔ Lᵀ Xᵀ = Bᵀ.
pub fn right_solve_lower(b: &Mat, l: &Mat) -> Mat {
    solve_lower_t(l, &b.transpose()).transpose()
}

/// X = B·L⁻ᵀ for lower-triangular L: X·Lᵀ = B ⇔ L·Xᵀ = Bᵀ.
pub fn right_solve_lower_t(b: &Mat, l: &Mat) -> Mat {
    solve_lower(l, &b.transpose()).transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{gram, matmul, matmul_bt};
    use crate::util::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::randn(rng, n + 5, n, 1.0);
        let mut g = gram(&a);
        g.add_diag(0.1);
        g
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "{x} vs {y}");
        }
    }

    #[test]
    fn chol_reconstructs() {
        let mut rng = Rng::new(7);
        for n in [1, 2, 7, 33, 64] {
            let c = spd(&mut rng, n);
            let l = cholesky(&c).unwrap();
            assert_close(&matmul_bt(&l, &l), &c, 2e-3);
            // strictly lower-triangular above diagonal is zero
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn chol_rejects_indefinite() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(cholesky(&m).is_err());
    }

    #[test]
    fn ridge_escalates() {
        let m = Mat::from_vec(2, 2, vec![0.0, 0.0, 0.0, 0.0]);
        let (l, lambda) = cholesky_ridge(&m, 1e-6);
        assert!(lambda >= 1e-6);
        assert!(l.at(0, 0) > 0.0);
    }

    #[test]
    fn solve_lower_inverts() {
        let mut rng = Rng::new(8);
        let c = spd(&mut rng, 20);
        let l = cholesky(&c).unwrap();
        let b = Mat::randn(&mut rng, 20, 7, 1.0);
        let x = solve_lower(&l, &b);
        assert_close(&matmul(&l, &x), &b, 1e-3);
    }

    #[test]
    fn solve_lower_t_inverts() {
        let mut rng = Rng::new(9);
        let c = spd(&mut rng, 20);
        let l = cholesky(&c).unwrap();
        let b = Mat::randn(&mut rng, 20, 5, 1.0);
        let x = solve_lower_t(&l, &b);
        assert_close(&matmul(&l.transpose(), &x), &b, 1e-3);
    }

    #[test]
    fn right_solves_invert() {
        let mut rng = Rng::new(10);
        let c = spd(&mut rng, 16);
        let l = cholesky(&c).unwrap();
        let b = Mat::randn(&mut rng, 6, 16, 1.0);
        let x = right_solve_lower(&b, &l);
        assert_close(&matmul(&x, &l), &b, 1e-3);
        let y = right_solve_lower_t(&b, &l);
        assert_close(&matmul(&y, &l.transpose()), &b, 1e-3);
    }

    #[test]
    fn whitening_identity() {
        // (W·S)·S⁻¹ = W — the compress pipeline's round trip.
        let mut rng = Rng::new(11);
        let c = spd(&mut rng, 24);
        let (s, _) = cholesky_ridge(&c, 1e-6);
        let w = Mat::randn(&mut rng, 10, 24, 1.0);
        let a = matmul(&w, &s);
        let back = right_solve_lower(&a, &s);
        assert_close(&back, &w, 5e-3);
    }
}
