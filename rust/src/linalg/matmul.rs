//! Blocked dense matmul + small GEMM helpers, with row-partitioned parallel
//! kernels (see `crate::exec`).
//!
//! This is the workhorse on both sides of the system: compression-time
//! (whitening A = W·S, recomposition W' = Wu·Wv, Jacobi column updates) and
//! request-time (the native runtime's projections run through `matmul_bt`).
//!
//! # Parallel determinism
//!
//! `matmul` and `matmul_bt` split the **output rows** into disjoint bands,
//! one band per worker.  Every output element is accumulated by exactly one
//! worker using exactly the serial kernel's loop structure, so the
//! floating-point addition order per element — and therefore the result,
//! bit for bit — is independent of the thread count.  Small products stay
//! on the serial path (spawn overhead would dominate); the cutover cannot
//! change results for the same reason.

use crate::exec;
use crate::tensor::Mat;

/// Below this many multiply-adds a product is not worth fanning out.
const PAR_MIN_MACS: usize = 1 << 22;

/// C = A · B (blocked i-k-j loop order, row-major friendly).  Parallel over
/// output-row bands; bit-identical to [`matmul_serial`] for any thread
/// count.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    matmul_flat(a, &b.data, b.rows, b.cols)
}

/// `matmul` against a borrowed row-major buffer (`b_rows` × `b_cols`) —
/// lets callers holding weights in `Tensor`s multiply without cloning them
/// into a `Mat` first (the native runtime's per-projection hot path).
pub fn matmul_flat(a: &Mat, b_data: &[f32], b_rows: usize, b_cols: usize) -> Mat {
    assert_eq!(a.cols, b_rows, "matmul_flat: {}x{} · {b_rows}x{b_cols}",
               a.rows, a.cols);
    assert_eq!(b_data.len(), b_rows * b_cols, "matmul_flat: ragged B buffer");
    let (m, k, n) = (a.rows, a.cols, b_cols);
    let mut c = Mat::zeros(m, n);
    if n == 0 {
        return c;
    }
    let nt = exec::threads();
    if nt <= 1 || exec::in_worker() || m * k * n < PAR_MIN_MACS || m < 2 {
        mm_rows(a, b_data, n, &mut c.data, 0, m);
        return c;
    }
    let rows_per = m.div_ceil(nt);
    exec::par_chunks_mut(&mut c.data, rows_per * n, |ci, chunk| {
        mm_rows(a, b_data, n, chunk, ci * rows_per, chunk.len() / n);
    });
    c
}

/// Fully serial reference kernel (the bit-exact baseline for the
/// equivalence tests in `rust/tests/parallel_equiv.rs`).
pub fn matmul_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    if b.cols > 0 {
        mm_rows(a, &b.data, b.cols, &mut c.data, 0, a.rows);
    }
    c
}

/// The blocked kernel over output rows `[row0, row0 + rows)`.  `c_rows` is
/// the destination band (rows·n values), `b_data` the row-major B buffer
/// with row length `n`.  Per output element the k-loop order is fixed (kb
/// ascending, kk ascending within the block), so any row partition of the
/// output accumulates identically to the serial pass.
fn mm_rows(a: &Mat, b_data: &[f32], n: usize, c_rows: &mut [f32], row0: usize,
           rows: usize) {
    let k = a.cols;
    const BK: usize = 64;
    const BJ: usize = 256;
    for kb in (0..k).step_by(BK) {
        let kend = (kb + BK).min(k);
        for jb in (0..n).step_by(BJ) {
            let jend = (jb + BJ).min(n);
            for i in 0..rows {
                let arow = &a.data[(row0 + i) * k..(row0 + i + 1) * k];
                let crow = &mut c_rows[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b_data[kk * n..(kk + 1) * n];
                    for j in jb..jend {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

/// C = A · Bᵀ without materializing the transpose (rows of B are
/// contiguous).  Parallel over output-row bands; each element is one
/// `dot_f32`, so partitioning cannot change results.
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_bt: {}x{} · ({}x{})ᵀ", a.rows, a.cols, b.rows, b.cols);
    matmul_bt_flat(a, &b.data, b.rows, b.cols)
}

/// `matmul_bt` against a borrowed row-major buffer (`b_rows` × `b_cols`,
/// contracted over `b_cols`): y = A · Bᵀ without cloning B into a `Mat`.
pub fn matmul_bt_flat(a: &Mat, b_data: &[f32], b_rows: usize, b_cols: usize)
                      -> Mat {
    assert_eq!(a.cols, b_cols, "matmul_bt_flat: {}x{} · ({b_rows}x{b_cols})ᵀ",
               a.rows, a.cols);
    assert_eq!(b_data.len(), b_rows * b_cols, "matmul_bt_flat: ragged B buffer");
    let (m, k, n) = (a.rows, a.cols, b_rows);
    let mut c = Mat::zeros(m, n);
    if n == 0 {
        return c;
    }
    let nt = exec::threads();
    if nt <= 1 || exec::in_worker() || m * k * n < PAR_MIN_MACS || m < 2 {
        mm_bt_rows(a, b_data, n, &mut c.data, 0, m);
        return c;
    }
    let rows_per = m.div_ceil(nt);
    exec::par_chunks_mut(&mut c.data, rows_per * n, |ci, chunk| {
        mm_bt_rows(a, b_data, n, chunk, ci * rows_per, chunk.len() / n);
    });
    c
}

fn mm_bt_rows(a: &Mat, b_data: &[f32], n: usize, c_rows: &mut [f32],
              row0: usize, rows: usize) {
    let k = a.cols;
    for i in 0..rows {
        let arow = &a.data[(row0 + i) * k..(row0 + i + 1) * k];
        let crow = &mut c_rows[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b_data[j * k..(j + 1) * k];
            crow[j] = dot_f32(arow, brow);
        }
    }
}

/// C = Aᵀ · A (Gram matrix, symmetric — only upper computed then mirrored).
/// Kept serial: it feeds the whitening path where exact symmetry by
/// construction matters more than the last factor of parallelism.
pub fn gram(a: &Mat) -> Mat {
    let (m, n) = (a.rows, a.cols);
    let mut c = Mat::zeros(n, n);
    for r in 0..m {
        let row = &a.data[r * n..(r + 1) * n];
        for i in 0..n {
            let ri = row[i];
            if ri == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in i..n {
                crow[j] += ri * row[j];
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            c.data[i * n + j] = c.data[j * n + i];
        }
    }
    c
}

/// Fixed-order f32 dot product (4-lane unrolled) — the one accumulation
/// the projection kernels build on, hence the unit of bit-reproducibility.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolled accumulation — the autovectorizer picks this up.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64), (70, 130, 50)] {
            let a = Mat::randn(&mut rng, m, k, 1.0);
            let b = Mat::randn(&mut rng, k, n, 1.0);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(&mut rng, 23, 31, 1.0);
        let b = Mat::randn(&mut rng, 11, 31, 1.0);
        assert_close(&matmul_bt(&a, &b), &matmul(&a, &b.transpose()), 1e-4);
    }

    #[test]
    fn gram_matches() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(&mut rng, 40, 17, 1.0);
        let g = gram(&a);
        assert_close(&g, &matmul(&a.transpose(), &a), 1e-3);
        // symmetry exact by construction
        for i in 0..17 {
            for j in 0..17 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }

    #[test]
    fn identity_neutral() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(&mut rng, 9, 9, 1.0);
        assert_close(&matmul(&a, &Mat::eye(9)), &a, 1e-6);
        assert_close(&matmul(&Mat::eye(9), &a), &a, 1e-6);
    }

    #[test]
    fn parallel_bit_identical_to_serial() {
        let mut rng = Rng::new(7);
        // large enough to clear the parallel cutover
        let a = Mat::randn(&mut rng, 200, 160, 1.0);
        let b = Mat::randn(&mut rng, 160, 180, 1.0);
        let serial = matmul_serial(&a, &b);
        let bt = b.transpose();
        let mut bt_ref: Option<Mat> = None;
        for t in [1usize, 2, 3, 4, 7] {
            crate::exec::set_threads(t);
            assert_eq!(matmul(&a, &b), serial, "threads = {t}");
            let got = matmul_bt(&a, &bt);
            match &bt_ref {
                None => bt_ref = Some(got),
                Some(r) => assert_eq!(&got, r, "matmul_bt threads = {t}"),
            }
        }
        crate::exec::set_threads(0);
    }
}
