//! Blocked dense matmul + small GEMM helpers.
//!
//! This is the compression-time workhorse (whitening A = W·S, recomposition
//! W' = Wu·Wv, Jacobi column updates).  Request-path matmuls run inside the
//! AOT HLO on the PJRT client, not here.

use crate::tensor::Mat;

/// C = A · B (blocked i-k-j loop order, row-major friendly).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    const BK: usize = 64;
    const BJ: usize = 256;
    for kb in (0..k).step_by(BK) {
        let kend = (kb + BK).min(k);
        for jb in (0..n).step_by(BJ) {
            let jend = (jb + BJ).min(n);
            for i in 0..m {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for j in jb..jend {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
    c
}

/// C = A · Bᵀ without materializing the transpose (rows of B are contiguous).
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_bt: {}x{} · ({}x{})ᵀ", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            crow[j] = dot_f32(arow, brow);
        }
    }
    c
}

/// C = Aᵀ · A (Gram matrix, symmetric — only upper computed then mirrored).
pub fn gram(a: &Mat) -> Mat {
    let (m, n) = (a.rows, a.cols);
    let mut c = Mat::zeros(n, n);
    for r in 0..m {
        let row = &a.data[r * n..(r + 1) * n];
        for i in 0..n {
            let ri = row[i];
            if ri == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in i..n {
                crow[j] += ri * row[j];
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            c.data[i * n + j] = c.data[j * n + i];
        }
    }
    c
}

#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolled accumulation — the autovectorizer picks this up.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64), (70, 130, 50)] {
            let a = Mat::randn(&mut rng, m, k, 1.0);
            let b = Mat::randn(&mut rng, k, n, 1.0);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(&mut rng, 23, 31, 1.0);
        let b = Mat::randn(&mut rng, 11, 31, 1.0);
        assert_close(&matmul_bt(&a, &b), &matmul(&a, &b.transpose()), 1e-4);
    }

    #[test]
    fn gram_matches() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(&mut rng, 40, 17, 1.0);
        let g = gram(&a);
        assert_close(&g, &matmul(&a.transpose(), &a), 1e-3);
        // symmetry exact by construction
        for i in 0..17 {
            for j in 0..17 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }

    #[test]
    fn identity_neutral() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(&mut rng, 9, 9, 1.0);
        assert_close(&matmul(&a, &Mat::eye(9)), &a, 1e-6);
        assert_close(&matmul(&Mat::eye(9), &a), &a, 1e-6);
    }
}
