//! Dense matmul + small GEMM helpers: row-partitioned parallel dispatch
//! (see `crate::exec`) over the SIMD micro-kernel layer
//! (`crate::linalg::kernels`).
//!
//! This is the workhorse on both sides of the system: compression-time
//! (whitening A = W·S, recomposition W' = Wu·Wv, Jacobi column updates) and
//! request-time (the native runtime's projections run through `matmul_bt`).
//! The innermost MAC loops live in `kernels` — explicit AVX2 where the CPU
//! has it, a bit-identical portable fallback everywhere else — and this
//! module owns shape checks, the output-row banding, and the
//! parallel-dispatch policy.
//!
//! # Parallel determinism
//!
//! `matmul` and `matmul_bt` split the **output rows** into disjoint bands,
//! one band per worker.  Every output element is accumulated by exactly one
//! worker using exactly the serial kernel's canonical order (ascending-k
//! single accumulator for A·B, the 8-lane-strided `dot_f32` for A·Bᵀ — see
//! `kernels`), so the floating-point addition order per element — and
//! therefore the result, bit for bit — is independent of the thread count
//! AND of the kernel backend.  Small products stay on the serial path
//! (dispatch overhead would dominate); the cutover cannot change results
//! for the same reason.
//!
//! `gram` fans out over **fixed-size row bands** whose partial Gram
//! matrices combine through `exec::tree_reduce` — the band size is a
//! constant, so the combination tree depends only on the row count, never
//! the thread count, and the result is bit-stable for any pool
//! configuration.

use crate::exec;
use crate::obs;
use crate::tensor::Mat;

use super::kernels;
pub use super::kernels::{axpy_f32, dot_f32};

/// Observability shim around one GEMM-shaped entry point: when tracing is
/// on, time the call and fold (backend, shape, ns) into the per-kernel
/// aggregates (`obs::kernel_record` — aggregated, never one ring event per
/// call).  When tracing is off this is one relaxed atomic load and a direct
/// call; the clock is read only around the computation, never inside it, so
/// the observe-only contract holds trivially.
#[inline]
fn timed<T>(kernel: &'static str, m: usize, k: usize, n: usize,
            f: impl FnOnce() -> T) -> T {
    if !obs::enabled() {
        return f();
    }
    let t0 = std::time::Instant::now();
    let out = f();
    obs::kernel_record(kernel, kernels::backend_label(), m, k, n,
                       t0.elapsed().as_nanos() as u64);
    out
}

/// Below this many multiply-adds a product is not worth fanning out to the
/// worker pool.
///
/// Calibrated against the kernel-level GFLOP/s sweep in
/// `benches/microbench_linalg.rs` (recorded in `BENCH_5.json`): one
/// `par_chunks_mut` dispatch costs a queue lock + condvar wake — tens of
/// microseconds — while the AVX2 kernels retire a MAC in well under a
/// nanosecond, so `2^21` MACs (~a few hundred microseconds serial) is the
/// smallest product where splitting reliably wins on the 2-core CI box.
/// The pre-SIMD threshold was `2^22`; faster kernels mean *larger* products
/// are needed to amortize the same dispatch cost per unit of saved time,
/// but the old value also left real wins on the table for mid-size
/// compression GEMMs, hence the recalibration rather than a doubling.
/// Changing this constant can never change results — only where the
/// serial/parallel cutover sits.
pub const PAR_MIN_MACS: usize = 1 << 21;

/// One shared dispatch policy for [`matmul_flat`] and [`matmul_bt_flat`]:
/// fan out only when the pool is usable, the product clears
/// [`PAR_MIN_MACS`], and there are at least two output rows.  The row
/// minimum is *structural*, not a tuning knob — the partition unit is an
/// output row, so a single-row product (the steady-state decode shape)
/// cannot be split however many MACs it carries.  Batched-across-slots
/// decode GEMMs exist precisely to lift serving work over this guard; a
/// future column-partitioned kernel could remove it entirely.
#[inline]
fn par_worthwhile(m: usize, k: usize, n: usize) -> bool {
    m >= 2
        && m * k * n >= PAR_MIN_MACS
        && exec::threads() > 1
        && !exec::in_worker()
}

/// C = A · B.  Parallel over output-row bands; bit-identical to
/// [`matmul_serial`] for any thread count and kernel backend.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    matmul_flat(a, &b.data, b.rows, b.cols)
}

/// `matmul` against a borrowed row-major buffer (`b_rows` × `b_cols`) —
/// lets callers holding weights in `Tensor`s multiply without cloning them
/// into a `Mat` first (the native runtime's per-projection hot path).
pub fn matmul_flat(a: &Mat, b_data: &[f32], b_rows: usize, b_cols: usize) -> Mat {
    assert_eq!(a.cols, b_rows, "matmul_flat: {}x{} · {b_rows}x{b_cols}",
               a.rows, a.cols);
    assert_eq!(b_data.len(), b_rows * b_cols, "matmul_flat: ragged B buffer");
    let (m, k, n) = (a.rows, a.cols, b_cols);
    timed("matmul", m, k, n, || {
        let mut c = Mat::zeros(m, n);
        if n == 0 {
            return c;
        }
        if !par_worthwhile(m, k, n) {
            kernels::mm_rows(&a.data, k, 0, m, b_data, n, &mut c.data);
            return c;
        }
        let rows_per = m.div_ceil(exec::threads());
        exec::par_chunks_mut(&mut c.data, rows_per * n, |ci, chunk| {
            kernels::mm_rows(&a.data, k, ci * rows_per, chunk.len() / n,
                             b_data, n, chunk);
        });
        c
    })
}

/// Fully serial reference kernel (the bit-exact baseline for the
/// equivalence tests in `rust/tests/parallel_equiv.rs`).
pub fn matmul_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    if b.cols > 0 {
        kernels::mm_rows(&a.data, a.cols, 0, a.rows, &b.data, b.cols,
                         &mut c.data);
    }
    c
}

/// C = A · Bᵀ without materializing the transpose (rows of B are
/// contiguous).  Parallel over output-row bands; each element is one
/// canonical [`dot_f32`], so partitioning cannot change results.
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_bt: {}x{} · ({}x{})ᵀ", a.rows, a.cols, b.rows, b.cols);
    matmul_bt_flat(a, &b.data, b.rows, b.cols)
}

/// `matmul_bt` against a borrowed row-major buffer (`b_rows` × `b_cols`,
/// contracted over `b_cols`): y = A · Bᵀ without cloning B into a `Mat`.
pub fn matmul_bt_flat(a: &Mat, b_data: &[f32], b_rows: usize, b_cols: usize)
                      -> Mat {
    assert_eq!(a.cols, b_cols, "matmul_bt_flat: {}x{} · ({b_rows}x{b_cols})ᵀ",
               a.rows, a.cols);
    assert_eq!(b_data.len(), b_rows * b_cols, "matmul_bt_flat: ragged B buffer");
    let (m, k, n) = (a.rows, a.cols, b_rows);
    timed("matmul_bt", m, k, n, || {
        let mut c = Mat::zeros(m, n);
        if n == 0 {
            return c;
        }
        if !par_worthwhile(m, k, n) {
            kernels::mm_bt_rows(&a.data, k, 0, m, b_data, n, &mut c.data);
            return c;
        }
        let rows_per = m.div_ceil(exec::threads());
        exec::par_chunks_mut(&mut c.data, rows_per * n, |ci, chunk| {
            kernels::mm_bt_rows(&a.data, k, ci * rows_per, chunk.len() / n,
                                b_data, n, chunk);
        });
        c
    })
}

/// Row count of one `gram` band.  A *constant* on purpose: the band
/// partition — and with it the `tree_reduce` combination tree — must
/// depend only on the input's row count, so the result is bit-identical
/// for every thread count (enforced by `rust/tests/parallel_equiv.rs`).
const GRAM_BAND_ROWS: usize = 128;

/// C = Aᵀ · A (Gram matrix, symmetric — only the upper triangle is
/// computed, then mirrored, so exact symmetry holds by construction).
///
/// Rows are processed in fixed bands of [`GRAM_BAND_ROWS`]: each band
/// accumulates a partial upper-triangular Gram (rows ascending, the
/// canonical element-wise `axpy_f32` per row), the partials fan out across
/// the worker pool, and `exec::tree_reduce` combines them in a fixed
/// pairwise tree.  Small inputs run the same banded algorithm inline —
/// identical bits, no dispatch overhead.
pub fn gram(a: &Mat) -> Mat {
    let (m, n) = (a.rows, a.cols);
    // recorded MACs use the full m·n² product shape; the computed half
    // (upper triangle, then mirrored) makes the reported GFLOP/s read as
    // effective-output throughput, consistent with the other GEMMs
    timed("gram", m, n, n, || {
        let mut c = Mat::zeros(n, n);
        if m == 0 || n == 0 {
            return c;
        }
        let band = |rows: &[f32]| -> Vec<f32> {
            let mut p = vec![0.0f32; n * n];
            for row in rows.chunks_exact(n) {
                for i in 0..n {
                    axpy_f32(&mut p[i * n + i..(i + 1) * n], row[i], &row[i..]);
                }
            }
            p
        };
        let bands: Vec<&[f32]> = a.data.chunks(GRAM_BAND_ROWS * n).collect();
        // upper-triangle MACs ≈ m·n²/2; below the dispatch threshold the
        // same banded pass runs inline on the caller (same bands, same
        // tree, same bits)
        let partials: Vec<Vec<f32>> = if m * n * n / 2 < PAR_MIN_MACS {
            bands.iter().map(|rows| band(rows)).collect()
        } else {
            exec::par_map(&bands, |_, rows| band(rows))
        };
        if let Some(sum) = exec::tree_reduce(partials, |x, y| {
            for (xe, ye) in x.iter_mut().zip(y) {
                *xe += ye;
            }
        }) {
            c.data = sum;
        }
        for i in 0..n {
            for j in 0..i {
                c.data[i * n + j] = c.data[j * n + i];
            }
        }
        c
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64), (70, 130, 50)] {
            let a = Mat::randn(&mut rng, m, k, 1.0);
            let b = Mat::randn(&mut rng, k, n, 1.0);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(&mut rng, 23, 31, 1.0);
        let b = Mat::randn(&mut rng, 11, 31, 1.0);
        assert_close(&matmul_bt(&a, &b), &matmul(&a, &b.transpose()), 1e-4);
    }

    #[test]
    fn gram_matches() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(&mut rng, 40, 17, 1.0);
        let g = gram(&a);
        assert_close(&g, &matmul(&a.transpose(), &a), 1e-3);
        // symmetry exact by construction
        for i in 0..17 {
            for j in 0..17 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }

    #[test]
    fn gram_banding_is_row_count_only() {
        // spanning multiple 128-row bands must agree with the naive
        // product within tolerance AND stay exactly symmetric
        let mut rng = Rng::new(9);
        let a = Mat::randn(&mut rng, 400, 24, 1.0);
        let g = gram(&a);
        assert_close(&g, &matmul(&a.transpose(), &a), 1e-3);
        for i in 0..24 {
            for j in 0..24 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }

    #[test]
    fn identity_neutral() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(&mut rng, 9, 9, 1.0);
        assert_close(&matmul(&a, &Mat::eye(9)), &a, 1e-6);
        assert_close(&matmul(&Mat::eye(9), &a), &a, 1e-6);
    }

    #[test]
    fn parallel_bit_identical_to_serial() {
        let mut rng = Rng::new(7);
        // large enough to clear the parallel cutover
        let a = Mat::randn(&mut rng, 200, 160, 1.0);
        let b = Mat::randn(&mut rng, 160, 180, 1.0);
        let serial = matmul_serial(&a, &b);
        let bt = b.transpose();
        let mut bt_ref: Option<Mat> = None;
        for t in [1usize, 2, 3, 4, 7] {
            crate::exec::set_threads(t);
            assert_eq!(matmul(&a, &b), serial, "threads = {t}");
            let got = matmul_bt(&a, &bt);
            match &bt_ref {
                None => bt_ref = Some(got),
                Some(r) => assert_eq!(&got, r, "matmul_bt threads = {t}"),
            }
        }
        crate::exec::set_threads(0);
    }
}
