//! Per-engine serving metrics: monotonic counters plus bounded latency
//! reservoirs, queryable over the wire protocol (`{"type":"metrics"}`).
//!
//! Counters are u64 totals since server start (admitted / rejected /
//! completed requests, prefill and decode tokens, connections).  Latency
//! series keep the most recent [`RESERVOIR_CAP`] samples in a ring, so a
//! long-lived server summarizes recent behavior in O(cap) memory while the
//! percentile shape stays exactly `util::stats::LatencySummary` — the same
//! p50/p95/p99/mean every offline table reports.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::LatencySummary;

/// Samples each latency series retains (newest-wins ring).
pub const RESERVOIR_CAP: usize = 4096;

struct Ring {
    buf: Vec<f64>,
    next: usize,
}

impl Ring {
    fn push(&mut self, v: f64) {
        if self.buf.len() < RESERVOIR_CAP {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % RESERVOIR_CAP;
        }
    }
}

/// Process-lifetime counter + latency-reservoir registry, wire-queryable
/// through the `metrics` request.
pub struct Metrics {
    started: Instant,
    counters: Mutex<BTreeMap<&'static str, u64>>,
    series: Mutex<BTreeMap<&'static str, Ring>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    /// Empty registry; uptime starts now.
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            counters: Mutex::new(BTreeMap::new()),
            series: Mutex::new(BTreeMap::new()),
        }
    }

    /// Add `by` to the named counter (created at zero on first use).
    pub fn inc(&self, name: &'static str, by: u64) {
        let mut m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        *m.entry(name).or_insert(0) += by;
    }

    /// Current value of the named counter (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Record one latency sample (ms) into the named series.
    pub fn record_ms(&self, name: &'static str, v: f64) {
        let mut m = self.series.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name).or_insert_with(|| Ring { buf: Vec::new(), next: 0 })
            .push(v);
    }

    /// Summary of the named series (zeros when empty/unknown).
    pub fn summary(&self, name: &str) -> LatencySummary {
        let m = self.series.lock().unwrap_or_else(|e| e.into_inner());
        m.get(name)
            .map(|r| LatencySummary::from_samples(&r.buf))
            .unwrap_or_default()
    }

    /// Seconds since the registry was created.
    pub fn uptime_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Wire snapshot (already shaped as a `metrics` event payload).
    /// `queue_depth` is the caller-sampled admission-queue length — a gauge,
    /// so it rides with the snapshot rather than living in a counter.
    pub fn snapshot(&self, queue_depth: usize) -> Json {
        let uptime = self.uptime_secs().max(1e-9);
        let counters = {
            let m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
            Json::Obj(m.iter()
                .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                .collect())
        };
        let latency = {
            let m = self.series.lock().unwrap_or_else(|e| e.into_inner());
            Json::Obj(m.iter()
                .map(|(k, r)| {
                    (k.to_string(),
                     LatencySummary::from_samples(&r.buf).to_json())
                })
                .collect())
        };
        // scheduler occupancy gauges (active slots, KV occupancy, arena /
        // draft pool sizes) live in the always-on obs layer — the engine
        // thread writes them every iteration whether or not tracing is
        // enabled.  The admission-queue depth is sampled by the caller, so
        // it joins the same object here.
        let gauges = {
            let mut g = match crate::obs::gauges_json() {
                Json::Obj(m) => m,
                _ => BTreeMap::new(),
            };
            g.insert("queue_depth".to_string(), Json::num(queue_depth as f64));
            Json::Obj(g)
        };
        Json::obj(vec![
            ("type", Json::str("metrics")),
            ("uptime_secs", Json::num(uptime)),
            ("queue_depth", Json::num(queue_depth as f64)),
            ("gauges", gauges),
            // whole-uptime average (an activity gauge — near zero on a
            // mostly-idle server); deliberately NOT named like the
            // steady-state `decode tok/s` the tables report, which comes
            // from `EngineCounters::decode_tok_per_sec`
            ("uptime_tok_per_sec",
             Json::num(self.counter("decode_tokens") as f64 / uptime)),
            // accepted / proposed drafter tokens; 0.0 when the server runs
            // without speculation (both counters absent)
            ("draft_acceptance_rate", Json::num({
                let proposed = self.counter("draft_proposed_tokens");
                if proposed == 0 {
                    0.0
                } else {
                    self.counter("draft_accepted_tokens") as f64
                        / proposed as f64
                }
            })),
            ("counters", counters),
            ("latency_ms", latency),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        assert_eq!(m.counter("decode_tokens"), 0);
        m.inc("decode_tokens", 3);
        m.inc("decode_tokens", 4);
        m.inc("requests_admitted", 1);
        assert_eq!(m.counter("decode_tokens"), 7);
        assert_eq!(m.counter("requests_admitted"), 1);
        assert_eq!(m.counter("never_touched"), 0);
    }

    #[test]
    fn series_summarizes() {
        let m = Metrics::new();
        assert_eq!(m.summary("e2e_ms"), LatencySummary::default());
        for v in [1.0, 2.0, 3.0, 4.0] {
            m.record_ms("e2e_ms", v);
        }
        let s = m.summary("e2e_ms");
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn reservoir_is_bounded() {
        let m = Metrics::new();
        for i in 0..(RESERVOIR_CAP + 500) {
            m.record_ms("token_gap_ms", i as f64);
        }
        let s = m.summary("token_gap_ms");
        assert_eq!(s.n, RESERVOIR_CAP);
        // the newest samples are retained (oldest were overwritten)
        assert!(s.max >= (RESERVOIR_CAP + 499) as f64 - 0.5);
    }

    #[test]
    fn snapshot_shape() {
        let m = Metrics::new();
        m.inc("decode_tokens", 10);
        m.record_ms("e2e_ms", 12.5);
        let j = m.snapshot(3);
        assert_eq!(j.str_or("type", ""), "metrics");
        assert_eq!(j.usize_or("queue_depth", 99), 3);
        // the gauges object always rides along and echoes the queue depth
        let g = j.get("gauges").expect("gauges object");
        assert_eq!(g.usize_or("queue_depth", 99), 3);
        assert!(j.f64_or("uptime_secs", 0.0) > 0.0);
        assert!(j.f64_or("uptime_tok_per_sec", 0.0) > 0.0);
        // no speculation ran: rate reports 0, not NaN
        assert_eq!(j.f64_or("draft_acceptance_rate", -1.0), 0.0);
        let c = j.get("counters").expect("counters");
        assert_eq!(c.usize_or("decode_tokens", 0), 10);
        let l = j.get("latency_ms").and_then(Json::as_obj).expect("latency");
        assert!((l["e2e_ms"].f64_or("p50", 0.0) - 12.5).abs() < 1e-12);
        // snapshot parses back as a wire event
        let line = j.to_string();
        assert!(super::super::protocol::parse_event(&line).is_ok());
    }

    #[test]
    fn snapshot_derives_draft_acceptance() {
        let m = Metrics::new();
        m.inc("draft_proposed_tokens", 8);
        m.inc("draft_accepted_tokens", 6);
        let j = m.snapshot(0);
        assert!((j.f64_or("draft_acceptance_rate", 0.0) - 0.75).abs()
                < 1e-12);
    }
}
