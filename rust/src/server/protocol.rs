//! Wire format: newline-delimited JSON, one message per line (full spec
//! with a worked client session in the `crate::server` module docs).
//!
//! Client → server messages are [`Request`]s; server → client messages are
//! [`Event`]s.  Both directions serialize through `util::json`, so the
//! protocol shares the repo's single JSON implementation and every message
//! round-trips through `parse_request` / `parse_event` (unit-tested below).
//! Numbers ride as JSON numbers (f64), exact up to 2^53.  Token ids and
//! request ids never approach that; explicit sampler **seeds are required
//! to be < 2^53** — a larger seed would be silently rounded in transit and
//! break the server-vs-offline bit-match, so `parse_request` rejects it
//! with `bad_request` instead.

use crate::util::json::{self, Json};

/// Protocol revision spoken by this build.  A [`Request::Hello`] carrying a
/// different `proto` is answered with a structured `bad_request` instead of
/// failing with a parse error mid-stream, so router↔worker and
/// client↔router version skew surfaces loudly at connect time.
pub const PROTO_VERSION: u64 = 1;

/// Structured error code carried by [`Event::Error`]: admission queue full.
pub const ERR_OVERLOADED: &str = "overloaded";
/// Structured error code: malformed or invalid request.
pub const ERR_BAD_REQUEST: &str = "bad_request";
/// Structured error code: server is draining and admits no new work.
pub const ERR_SHUTTING_DOWN: &str = "shutting_down";
/// Structured error code: a [`Request::Reload`] could not be applied (bad
/// artifact path, verification failure, model mismatch, or the server was
/// started without hot-swap support).  The previous plan keeps serving.
pub const ERR_RELOAD_FAILED: &str = "reload_failed";
/// Structured error code: the fleet worker holding this in-flight request
/// died (crash or heartbeat timeout).  The request was NOT completed; the
/// worker is restarted from its verified artifact and a re-issued identical
/// request bit-matches the original reference.
pub const ERR_WORKER_FAILED: &str = "worker_failed";
/// Structured error code: this connection stopped reading its token stream
/// and its outbox hit the flow-control cap; the router dropped the backlog
/// and closed the connection rather than buffer without bound.
pub const ERR_SLOW_READER: &str = "slow_reader";

/// One generation request.  `id` is client-chosen and echoed verbatim on
/// every event for this request (scope: one connection).
#[derive(Clone, Debug, PartialEq)]
pub struct GenerateReq {
    /// client-chosen request id, echoed on every event
    pub id: u64,
    /// prompt token ids (validated against the model's vocab)
    pub prompt: Vec<i32>,
    /// 0 = use the server's default budget
    pub max_new_tokens: usize,
    /// None = server default (greedy unless configured otherwise)
    pub temperature: Option<f32>,
    /// explicit sampler seed; None derives one from the engine seed and the
    /// server-assigned request id
    pub seed: Option<u64>,
}

impl GenerateReq {
    /// Wire form of the request.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("type", Json::str("generate")),
            ("id", Json::num(self.id as f64)),
            ("prompt", Json::arr(self.prompt.iter()
                                     .map(|&t| Json::num(t as f64)))),
            ("max_new_tokens", Json::num(self.max_new_tokens as f64)),
        ];
        if let Some(t) = self.temperature {
            pairs.push(("temperature", Json::num(t as f64)));
        }
        if let Some(s) = self.seed {
            pairs.push(("seed", Json::num(s as f64)));
        }
        Json::obj(pairs)
    }
}

/// Client → server messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// start one generation
    Generate(GenerateReq),
    /// ask for a metrics snapshot ([`Event::Metrics`] reply)
    Metrics,
    /// ask for an observability snapshot: the recent trace-event ring plus
    /// counters/histograms/kernel stats ([`Event::Trace`] reply).  Always
    /// answered; with tracing disabled the event ring is simply empty
    /// (`enabled: false` in the reply says why)
    Trace,
    /// load + verify the artifact at `artifact` and hot-swap the serving
    /// plan once in-flight requests drain ([`Event::Reloaded`] on success,
    /// [`Event::Error`] with [`ERR_RELOAD_FAILED`] otherwise)
    Reload {
        /// path to the artifact manifest (`.zsar`) on the server host
        artifact: String,
    },
    /// optional version handshake: announce the protocol revision the
    /// client speaks.  A matching server answers [`Event::Hello`] with its
    /// proto/version and engine label; a mismatch is a structured
    /// `bad_request` — version skew fails loudly at connect time instead of
    /// with a parse error mid-stream
    Hello {
        /// protocol revision the sender speaks ([`PROTO_VERSION`]; absent
        /// on the wire means 1)
        proto: u64,
    },
    /// liveness probe ([`Event::Pong`] reply echoing the nonce); the fleet
    /// router heartbeats its workers with this
    Ping {
        /// opaque value echoed in the reply
        nonce: u64,
    },
    /// stop accepting work, drain in-flight requests, exit
    Shutdown,
}

/// One wire line (no trailing newline) for a request.
pub fn request_line(r: &Request) -> String {
    match r {
        Request::Generate(g) => g.to_json().to_string(),
        Request::Metrics => Json::obj(vec![("type", Json::str("metrics"))])
            .to_string(),
        Request::Trace => Json::obj(vec![("type", Json::str("trace"))])
            .to_string(),
        Request::Reload { artifact } => Json::obj(vec![
            ("type", Json::str("reload")),
            ("artifact", Json::str(artifact)),
        ])
        .to_string(),
        Request::Hello { proto } => Json::obj(vec![
            ("type", Json::str("hello")),
            ("proto", Json::num(*proto as f64)),
        ])
        .to_string(),
        Request::Ping { nonce } => Json::obj(vec![
            ("type", Json::str("ping")),
            ("nonce", Json::num(*nonce as f64)),
        ])
        .to_string(),
        Request::Shutdown => Json::obj(vec![("type", Json::str("shutdown"))])
            .to_string(),
    }
}

/// Parse one request line; the error string becomes a `bad_request` reply.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    match j.get("type").and_then(Json::as_str) {
        Some("generate") => {
            let prompt = j
                .get("prompt")
                .and_then(Json::as_arr)
                .ok_or_else(|| "generate: missing `prompt` array".to_string())?
                .iter()
                .map(|t| t.as_f64().map(|v| v as i32))
                .collect::<Option<Vec<i32>>>()
                .ok_or_else(|| "generate: non-numeric prompt token".to_string())?;
            let seed = match j.get("seed").and_then(Json::as_f64) {
                // f64 represents integers exactly only below 2^53; a bigger
                // seed would be silently rounded and the generation would no
                // longer reproduce an offline run with the same seed
                Some(s) if !(0.0..9_007_199_254_740_992.0).contains(&s) => {
                    return Err(format!(
                        "generate: seed {s} outside [0, 2^53)"));
                }
                Some(s) => Some(s as u64),
                None => None,
            };
            Ok(Request::Generate(GenerateReq {
                id: j.f64_or("id", 0.0) as u64,
                prompt,
                max_new_tokens: j.usize_or("max_new_tokens", 0),
                temperature: j.get("temperature").and_then(Json::as_f64)
                    .map(|t| t as f32),
                seed,
            }))
        }
        Some("metrics") => Ok(Request::Metrics),
        Some("trace") => Ok(Request::Trace),
        Some("reload") => match j.get("artifact").and_then(Json::as_str) {
            Some(a) if !a.is_empty() => {
                Ok(Request::Reload { artifact: a.to_string() })
            }
            _ => Err("reload: missing `artifact` path".to_string()),
        },
        // `proto` absent on the wire = revision 1 (the handshake itself is
        // optional, so an early peer that sends a bare hello still works)
        Some("hello") => Ok(Request::Hello {
            proto: j.f64_or("proto", 1.0) as u64,
        }),
        Some("ping") => Ok(Request::Ping {
            nonce: j.f64_or("nonce", 0.0) as u64,
        }),
        Some("shutdown") => Ok(Request::Shutdown),
        Some(other) => Err(format!("unknown request type `{other}`")),
        None => Err("missing `type`".to_string()),
    }
}

/// Server → client messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// one streamed token, emitted as it is sampled
    Token {
        /// client-chosen request id
        id: u64,
        /// 0-based position in this request's generation
        index: usize,
        /// the sampled token id
        token: i32,
    },
    /// final summary for a request, after its last `Token`
    Done {
        /// client-chosen request id
        id: u64,
        /// every generated token, in order
        tokens: Vec<i32>,
        /// prompt length the server accounted
        prompt_len: usize,
        /// admission-queue wait, ms
        queue_ms: f64,
        /// slot admission → prompt fully ingested, ms (0.0 when the peer
        /// is an older server that does not emit the field)
        prefill_ms: f64,
        /// prompt ingested → completion, ms (0.0 from older peers)
        decode_ms: f64,
        /// time to first token, ms
        ttft_ms: f64,
        /// end-to-end latency, ms
        latency_ms: f64,
        /// generation stopped early because the KV arena filled (the
        /// requested budget was not reached)
        truncated: bool,
        /// prompt tokens served from the server's prefix cache (prefill
        /// skipped for them; 0 when caching is off, the prompt missed, or
        /// the peer is an older server that does not emit the field)
        cached_prompt_tokens: usize,
    },
    /// structured rejection or protocol error; `id` present when the error
    /// is attributable to one request
    Error {
        /// client-chosen request id, when attributable
        id: Option<u64>,
        /// structured code (`overloaded`, `bad_request`, `shutting_down`,
        /// `reload_failed`, `worker_failed`, `slow_reader`)
        code: String,
        /// human-readable detail
        message: String,
        /// on `overloaded`: how many requests were queued ahead when this
        /// one was turned away (absent from older peers — lenient parse)
        queue_depth: Option<usize>,
        /// on `overloaded`: suggested client back-off before retrying, ms
        /// (absent from older peers — lenient parse)
        retry_after_ms: Option<u64>,
    },
    /// metrics snapshot (the whole registry object)
    Metrics(Json),
    /// observability snapshot: the recent trace-event ring + counters /
    /// histograms / kernel stats, shaped by `crate::obs::snapshot_json`
    Trace(Json),
    /// a [`Request::Reload`] was verified and installed: new generations on
    /// every connection now run on the swapped-in plan
    Reloaded {
        /// manifest path the server loaded (echoed from the request)
        artifact: String,
        /// label of the engine now serving (e.g. `lowrank-r60`)
        engine: String,
    },
    /// reply to [`Request::Hello`]: the server's protocol revision, build
    /// version, and the label of the engine currently serving
    Hello {
        /// protocol revision the server speaks ([`PROTO_VERSION`])
        proto: u64,
        /// crate version of the serving build (e.g. `0.1.0`)
        version: String,
        /// engine label now serving (e.g. `dense`, `lowrank-r60`, or a
        /// fleet label like `fleet[2 x dense]` from the router)
        engine: String,
    },
    /// reply to [`Request::Ping`], echoing its nonce
    Pong {
        /// the nonce from the `ping`
        nonce: u64,
    },
    /// the server acknowledged shutdown / is closing this connection
    ShuttingDown,
}

impl Event {
    /// An [`Event::Error`] with no back-pressure hints (the common case —
    /// only `overloaded` rejections carry `queue_depth`/`retry_after_ms`).
    pub fn error(id: Option<u64>, code: &str, message: String) -> Event {
        Event::Error { id, code: code.into(), message,
                       queue_depth: None, retry_after_ms: None }
    }
}

/// One wire line (no trailing newline) for an event.
pub fn event_line(e: &Event) -> String {
    match e {
        Event::Token { id, index, token } => Json::obj(vec![
            ("type", Json::str("token")),
            ("id", Json::num(*id as f64)),
            ("index", Json::num(*index as f64)),
            ("token", Json::num(*token as f64)),
        ])
        .to_string(),
        Event::Done { id, tokens, prompt_len, queue_ms, prefill_ms,
                      decode_ms, ttft_ms, latency_ms, truncated,
                      cached_prompt_tokens } => {
            Json::obj(vec![
                ("type", Json::str("done")),
                ("id", Json::num(*id as f64)),
                ("tokens", Json::arr(tokens.iter()
                                         .map(|&t| Json::num(t as f64)))),
                ("prompt_len", Json::num(*prompt_len as f64)),
                ("queue_ms", Json::num(*queue_ms)),
                ("prefill_ms", Json::num(*prefill_ms)),
                ("decode_ms", Json::num(*decode_ms)),
                ("ttft_ms", Json::num(*ttft_ms)),
                ("latency_ms", Json::num(*latency_ms)),
                ("truncated", Json::Bool(*truncated)),
                ("cached_prompt_tokens",
                 Json::num(*cached_prompt_tokens as f64)),
            ])
            .to_string()
        }
        Event::Error { id, code, message, queue_depth, retry_after_ms } => {
            let mut pairs = vec![
                ("type", Json::str("error")),
                ("code", Json::str(code)),
                ("message", Json::str(message)),
            ];
            if let Some(id) = id {
                pairs.push(("id", Json::num(*id as f64)));
            }
            // back-pressure hints ride only when present, so older peers
            // (which parse leniently anyway) see the exact old shape
            if let Some(qd) = queue_depth {
                pairs.push(("queue_depth", Json::num(*qd as f64)));
            }
            if let Some(ra) = retry_after_ms {
                pairs.push(("retry_after_ms", Json::num(*ra as f64)));
            }
            Json::obj(pairs).to_string()
        }
        Event::Metrics(snapshot) => snapshot.to_string(),
        Event::Trace(snapshot) => snapshot.to_string(),
        Event::Reloaded { artifact, engine } => Json::obj(vec![
            ("type", Json::str("reloaded")),
            ("artifact", Json::str(artifact)),
            ("engine", Json::str(engine)),
        ])
        .to_string(),
        Event::Hello { proto, version, engine } => Json::obj(vec![
            ("type", Json::str("hello")),
            ("proto", Json::num(*proto as f64)),
            ("version", Json::str(version)),
            ("engine", Json::str(engine)),
        ])
        .to_string(),
        Event::Pong { nonce } => Json::obj(vec![
            ("type", Json::str("pong")),
            ("nonce", Json::num(*nonce as f64)),
        ])
        .to_string(),
        Event::ShuttingDown => Json::obj(vec![
            ("type", Json::str("shutting_down")),
        ])
        .to_string(),
    }
}

/// Parse one event line (the client side of the wire).
pub fn parse_event(line: &str) -> Result<Event, String> {
    let j = json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    // own the tag: the `metrics` arm moves `j` whole, so the scrutinee must
    // not keep a borrow of it alive across the match
    let tag = j.get("type").and_then(Json::as_str).map(str::to_string);
    match tag.as_deref() {
        Some("token") => Ok(Event::Token {
            id: j.f64_or("id", 0.0) as u64,
            index: j.usize_or("index", 0),
            token: j.f64_or("token", -1.0) as i32,
        }),
        Some("done") => {
            let tokens = j
                .get("tokens")
                .and_then(Json::as_arr)
                .ok_or_else(|| "done: missing `tokens`".to_string())?
                .iter()
                .map(|t| t.as_f64().map(|v| v as i32))
                .collect::<Option<Vec<i32>>>()
                .ok_or_else(|| "done: non-numeric token".to_string())?;
            Ok(Event::Done {
                id: j.f64_or("id", 0.0) as u64,
                tokens,
                prompt_len: j.usize_or("prompt_len", 0),
                queue_ms: j.f64_or("queue_ms", 0.0),
                // phase breakdown: absent from older servers → 0.0
                prefill_ms: j.f64_or("prefill_ms", 0.0),
                decode_ms: j.f64_or("decode_ms", 0.0),
                ttft_ms: j.f64_or("ttft_ms", 0.0),
                latency_ms: j.f64_or("latency_ms", 0.0),
                // older peers never emit the field: absent means complete
                truncated: j.bool_or("truncated", false),
                // absent from older servers → 0 (no cached prefix)
                cached_prompt_tokens: j.usize_or("cached_prompt_tokens", 0),
            })
        }
        Some("error") => Ok(Event::Error {
            id: j.get("id").and_then(Json::as_f64).map(|v| v as u64),
            code: j.str_or("code", "unknown"),
            message: j.str_or("message", ""),
            // hints are newer than the error shape: absent from older
            // peers, parsed leniently as "no hint"
            queue_depth: j.get("queue_depth").and_then(Json::as_f64)
                .map(|v| v as usize),
            retry_after_ms: j.get("retry_after_ms").and_then(Json::as_f64)
                .map(|v| v as u64),
        }),
        Some("metrics") => Ok(Event::Metrics(j)),
        Some("trace") => Ok(Event::Trace(j)),
        Some("reloaded") => Ok(Event::Reloaded {
            artifact: j.str_or("artifact", ""),
            engine: j.str_or("engine", ""),
        }),
        Some("hello") => Ok(Event::Hello {
            proto: j.f64_or("proto", 1.0) as u64,
            version: j.str_or("version", ""),
            engine: j.str_or("engine", ""),
        }),
        Some("pong") => Ok(Event::Pong {
            nonce: j.f64_or("nonce", 0.0) as u64,
        }),
        Some("shutting_down") => Ok(Event::ShuttingDown),
        Some(other) => Err(format!("unknown event type `{other}`")),
        None => Err("missing `type`".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_roundtrips() {
        let g = GenerateReq {
            id: 7,
            prompt: vec![1, 2, 250],
            max_new_tokens: 16,
            temperature: Some(0.75),
            seed: Some(42),
        };
        let line = request_line(&Request::Generate(g.clone()));
        assert!(!line.contains('\n'), "one message per line");
        match parse_request(&line).unwrap() {
            Request::Generate(back) => {
                assert_eq!(back.id, 7);
                assert_eq!(back.prompt, g.prompt);
                assert_eq!(back.max_new_tokens, 16);
                assert_eq!(back.seed, Some(42));
                assert!((back.temperature.unwrap() - 0.75).abs() < 1e-6);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn generate_defaults_omitted_fields() {
        let g = GenerateReq { id: 0, prompt: vec![5], max_new_tokens: 0,
                              temperature: None, seed: None };
        let line = request_line(&Request::Generate(g));
        assert!(!line.contains("temperature"));
        assert!(!line.contains("seed"));
        match parse_request(&line).unwrap() {
            Request::Generate(back) => {
                assert_eq!(back.temperature, None);
                assert_eq!(back.seed, None);
                assert_eq!(back.max_new_tokens, 0);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn control_requests_roundtrip() {
        for r in [Request::Metrics, Request::Trace, Request::Shutdown,
                  Request::Reload { artifact: "store/m.zsar".into() },
                  Request::Hello { proto: PROTO_VERSION },
                  Request::Hello { proto: 99 },
                  Request::Ping { nonce: 0xDEAD }] {
            let line = request_line(&r);
            assert_eq!(parse_request(&line).unwrap(), r);
        }
    }

    #[test]
    fn bare_hello_defaults_to_proto_1() {
        // the handshake is optional AND its field is optional: an early
        // peer sending `{"type":"hello"}` means revision 1
        match parse_request("{\"type\":\"hello\"}").unwrap() {
            Request::Hello { proto } => assert_eq!(proto, 1),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn reload_requires_artifact_path() {
        assert!(parse_request("{\"type\":\"reload\"}").is_err());
        assert!(parse_request("{\"type\":\"reload\",\"artifact\":\"\"}")
                    .is_err());
        assert!(parse_request("{\"type\":\"reload\",\"artifact\":7}")
                    .is_err());
    }

    #[test]
    fn events_roundtrip() {
        let events = vec![
            Event::Token { id: 3, index: 12, token: 199 },
            Event::Done { id: 3, tokens: vec![4, 5, 6], prompt_len: 8,
                          queue_ms: 1.5, prefill_ms: 4.0, decode_ms: 25.0,
                          ttft_ms: 10.25, latency_ms: 30.5,
                          truncated: false, cached_prompt_tokens: 0 },
            Event::Done { id: 4, tokens: vec![7], prompt_len: 2,
                          queue_ms: 0.0, prefill_ms: 0.5, decode_ms: 1.5,
                          ttft_ms: 1.0, latency_ms: 2.0,
                          truncated: true, cached_prompt_tokens: 0 },
            Event::Done { id: 5, tokens: vec![8, 9], prompt_len: 160,
                          queue_ms: 0.0, prefill_ms: 0.25, decode_ms: 3.0,
                          ttft_ms: 0.5, latency_ms: 3.5,
                          truncated: false, cached_prompt_tokens: 128 },
            Event::Error { id: Some(9), code: ERR_OVERLOADED.into(),
                           message: "queue full".into(),
                           queue_depth: Some(16),
                           retry_after_ms: Some(400) },
            Event::error(None, ERR_BAD_REQUEST, "bad json".into()),
            Event::error(None, ERR_RELOAD_FAILED,
                         "chunk `u:layers.0.wq` corrupt".into()),
            Event::error(Some(4), ERR_WORKER_FAILED,
                         "worker 1 died mid-request".into()),
            Event::error(None, ERR_SLOW_READER,
                         "outbox cap reached".into()),
            Event::Reloaded { artifact: "store/m.zsar".into(),
                              engine: "lowrank-r60".into() },
            Event::Hello { proto: PROTO_VERSION, version: "0.1.0".into(),
                           engine: "dense".into() },
            Event::Pong { nonce: 7 },
            Event::ShuttingDown,
        ];
        for e in events {
            let line = event_line(&e);
            assert!(!line.contains('\n'));
            assert_eq!(parse_event(&line).unwrap(), e, "line: {line}");
        }
    }

    #[test]
    fn error_without_hints_parses_leniently() {
        // old-peer error lines carry no queue_depth / retry_after_ms — the
        // parse must produce "no hint", and serializing a hint-free error
        // must not emit the keys at all
        let line = "{\"type\":\"error\",\"code\":\"overloaded\",\
                    \"message\":\"queue full\",\"id\":3}";
        match parse_event(line).unwrap() {
            Event::Error { queue_depth, retry_after_ms, .. } => {
                assert_eq!(queue_depth, None);
                assert_eq!(retry_after_ms, None);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        let out = event_line(&Event::error(Some(3), ERR_OVERLOADED,
                                           "queue full".into()));
        assert!(!out.contains("queue_depth"));
        assert!(!out.contains("retry_after_ms"));
    }

    #[test]
    fn done_without_truncated_field_parses_as_complete() {
        // lines from an older server omit the newer fields entirely:
        // `truncated` parses as false, the phase breakdown as 0.0, and
        // `cached_prompt_tokens` as 0 (no cached prefix)
        let line = "{\"type\":\"done\",\"id\":1,\"tokens\":[2],\
                    \"prompt_len\":1,\"queue_ms\":0,\"ttft_ms\":0,\
                    \"latency_ms\":0}";
        match parse_event(line).unwrap() {
            Event::Done { truncated, prefill_ms, decode_ms,
                          cached_prompt_tokens, .. } => {
                assert!(!truncated);
                assert_eq!(prefill_ms, 0.0);
                assert_eq!(decode_ms, 0.0);
                assert_eq!(cached_prompt_tokens, 0);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn trace_event_carries_snapshot() {
        let snap = Json::obj(vec![
            ("type", Json::str("trace")),
            ("enabled", Json::Bool(false)),
            ("events", Json::Arr(Vec::new())),
        ]);
        let line = event_line(&Event::Trace(snap));
        match parse_event(&line).unwrap() {
            Event::Trace(j) => {
                assert_eq!(j.str_or("type", ""), "trace");
                assert!(j.get("events").is_some());
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn metrics_event_carries_snapshot() {
        let snap = Json::obj(vec![
            ("type", Json::str("metrics")),
            ("uptime_secs", Json::num(1.25)),
        ]);
        let line = event_line(&Event::Metrics(snap.clone()));
        match parse_event(&line).unwrap() {
            Event::Metrics(j) => {
                assert!((j.f64_or("uptime_secs", 0.0) - 1.25).abs() < 1e-12);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request("{\"type\":\"nope\"}").is_err());
        assert!(parse_request("{\"type\":\"generate\"}").is_err());
        assert!(parse_event("{\"no_type\":1}").is_err());
        assert!(parse_event("{\"type\":\"done\"}").is_err());
    }

    #[test]
    fn rejects_unrepresentable_seeds() {
        // 2^53 and above (or negative) would be rounded by the f64 wire and
        // silently break seed-exact reproduction — must be a parse error
        let line = |seed: &str| {
            format!("{{\"type\":\"generate\",\"id\":1,\"prompt\":[1],\
                     \"seed\":{seed}}}")
        };
        assert!(parse_request(&line("9007199254740992")).is_err());
        assert!(parse_request(&line("18446744073709551615")).is_err());
        assert!(parse_request(&line("-1")).is_err());
        // the largest exact integer is fine
        match parse_request(&line("9007199254740991")).unwrap() {
            Request::Generate(g) => {
                assert_eq!(g.seed, Some((1u64 << 53) - 1));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }
}
