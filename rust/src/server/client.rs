//! Minimal blocking client for the wire protocol — the loopback tests, the
//! `server_throughput` bench, and the `zs-svd client` CLI subcommand all
//! drive the server through this, so stream-discipline checks (sequential
//! token indices, streamed == final tokens) live in exactly one place.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::protocol::{self, Event, GenerateReq, Request, ERR_OVERLOADED,
                      ERR_WORKER_FAILED, PROTO_VERSION};
use crate::util::rng::Rng;

/// Deterministic vocab-safe prompt for scripted clients — the CLI `client`
/// subcommand and `benches/server_throughput.rs` share this, so the two
/// drivers can never drift apart on what a "valid" prompt is.
pub fn scripted_prompt(k: usize, len: usize, vocab: usize) -> Vec<i32> {
    let v = vocab.max(2);
    (0..len).map(|j| (1 + (k * 31 + j * 7) % (v - 1)) as i32).collect()
}

/// Blocking wire client with stream-discipline checks — the scripted CLI
/// driver, the loopback tests, and the server bench all speak through it.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Outcome of one blocking generation round-trip.
#[derive(Clone, Debug)]
pub enum GenerateOutcome {
    /// the request completed; summary + streamed tokens
    Done(GenerationResult),
    /// structured rejection (`overloaded`, `bad_request`, `shutting_down`,
    /// `worker_failed`)
    Rejected {
        /// structured error code
        code: String,
        /// human-readable detail
        message: String,
        /// server-suggested back-off before retrying, ms (rides on
        /// `overloaded` from newer servers; `None` from older peers)
        retry_after_ms: Option<u64>,
    },
}

/// Outcome of one blocking `reload` round-trip.
#[derive(Clone, Debug)]
pub enum ReloadOutcome {
    /// the artifact verified and the server swapped to it
    Swapped {
        /// manifest path the server loaded (echoed)
        artifact: String,
        /// label of the engine now serving (e.g. `lowrank-r60`)
        engine: String,
    },
    /// structured rejection (`reload_failed`); the previous plan keeps
    /// serving
    Rejected {
        /// structured error code
        code: String,
        /// human-readable detail (names the corrupt chunk when integrity
        /// verification failed)
        message: String,
    },
}

/// One completed generation as the client observed it.
#[derive(Clone, Debug)]
pub struct GenerationResult {
    /// final tokens from the `done` summary
    pub tokens: Vec<i32>,
    /// tokens as they streamed in (`run_generate` asserts == `tokens`)
    pub streamed: Vec<i32>,
    /// prompt length the server accounted
    pub prompt_len: usize,
    /// admission-queue wait, ms
    pub queue_ms: f64,
    /// slot admission → prompt fully ingested, ms (0.0 when the server
    /// predates the phase breakdown — the parse is lenient)
    pub prefill_ms: f64,
    /// prompt ingested → completion, ms (0.0 from older servers)
    pub decode_ms: f64,
    /// time to first token, ms
    pub ttft_ms: f64,
    /// end-to-end latency, ms
    pub latency_ms: f64,
    /// generation stopped early at the KV-capacity wall (fewer tokens than
    /// the requested budget)
    pub truncated: bool,
    /// prompt tokens served from the server's prefix cache (prefill
    /// skipped for them; 0 with caching off or a cold cache)
    pub cached_prompt_tokens: usize,
}

fn bad_data(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

impl Client {
    /// Connect to a running server.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request line.
    pub fn send(&mut self, r: &Request) -> io::Result<()> {
        let mut line = protocol::request_line(r);
        line.push('\n');
        self.writer.write_all(line.as_bytes())
    }

    /// Next event, or `None` on server-side EOF.
    pub fn next_event(&mut self) -> io::Result<Option<Event>> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Ok(None);
            }
            let t = line.trim();
            if t.is_empty() {
                continue;
            }
            return protocol::parse_event(t).map(Some).map_err(bad_data);
        }
    }

    /// Closed-loop generation: send `g`, then consume this request's event
    /// stream until its `done` (or `error`), checking stream discipline —
    /// token indices strictly sequential, and the streamed tokens equal to
    /// the final summary.  Only events for `g.id` may be in flight on this
    /// connection.
    pub fn run_generate(&mut self, g: &GenerateReq)
                        -> io::Result<GenerateOutcome> {
        self.send(&Request::Generate(g.clone()))?;
        let mut streamed: Vec<i32> = Vec::new();
        loop {
            let ev = self.next_event()?.ok_or_else(|| {
                bad_data("connection closed mid-generation".into())
            })?;
            match ev {
                Event::Token { id, index, token } => {
                    if id != g.id {
                        return Err(bad_data(format!(
                            "token for unexpected id {id} (want {})", g.id)));
                    }
                    if index != streamed.len() {
                        return Err(bad_data(format!(
                            "token index {index} out of order (want {})",
                            streamed.len())));
                    }
                    streamed.push(token);
                }
                Event::Done { id, tokens, prompt_len, queue_ms, prefill_ms,
                              decode_ms, ttft_ms, latency_ms, truncated,
                              cached_prompt_tokens } => {
                    if id != g.id {
                        return Err(bad_data(format!(
                            "done for unexpected id {id} (want {})", g.id)));
                    }
                    if tokens != streamed {
                        return Err(bad_data(format!(
                            "final tokens differ from stream \
                             ({} streamed, {} final)",
                            streamed.len(), tokens.len())));
                    }
                    return Ok(GenerateOutcome::Done(GenerationResult {
                        tokens,
                        streamed,
                        prompt_len,
                        queue_ms,
                        prefill_ms,
                        decode_ms,
                        ttft_ms,
                        latency_ms,
                        truncated,
                        cached_prompt_tokens,
                    }));
                }
                Event::Error { id, code, message, retry_after_ms, .. } => {
                    if id.is_none() || id == Some(g.id) {
                        return Ok(GenerateOutcome::Rejected {
                            code, message, retry_after_ms,
                        });
                    }
                    return Err(bad_data(format!(
                        "error for unexpected id {id:?}: {code}")));
                }
                Event::Metrics(_) => {
                    return Err(bad_data("unexpected metrics event".into()));
                }
                Event::Trace(_) => {
                    return Err(bad_data("unexpected trace event".into()));
                }
                Event::Reloaded { .. } => {
                    return Err(bad_data("unexpected reloaded event".into()));
                }
                Event::ShuttingDown => {
                    return Ok(GenerateOutcome::Rejected {
                        code: protocol::ERR_SHUTTING_DOWN.into(),
                        message: "server shutting down".into(),
                        retry_after_ms: None,
                    });
                }
                Event::Hello { .. } | Event::Pong { .. } => {
                    return Err(bad_data(
                        "unexpected handshake event mid-generation".into()));
                }
            }
        }
    }

    /// Request a metrics snapshot and block for the reply.  Only safe with
    /// no generation in flight on this connection.
    pub fn metrics(&mut self) -> io::Result<crate::util::json::Json> {
        self.send(&Request::Metrics)?;
        loop {
            match self.next_event()? {
                Some(Event::Metrics(j)) => return Ok(j),
                Some(other) => {
                    return Err(bad_data(format!(
                        "unexpected event awaiting metrics: {other:?}")));
                }
                None => return Err(bad_data("eof awaiting metrics".into())),
            }
        }
    }

    /// Request an observability snapshot (recent trace events + counters /
    /// histograms / kernel stats) and block for the reply.  Only safe with
    /// no generation in flight on this connection.  Always answered; when
    /// the server runs without tracing the event ring is empty and the
    /// reply says `"enabled": false`.
    pub fn trace(&mut self) -> io::Result<crate::util::json::Json> {
        self.send(&Request::Trace)?;
        loop {
            match self.next_event()? {
                Some(Event::Trace(j)) => return Ok(j),
                Some(other) => {
                    return Err(bad_data(format!(
                        "unexpected event awaiting trace: {other:?}")));
                }
                None => return Err(bad_data("eof awaiting trace".into())),
            }
        }
    }

    /// Ask the server to hot-swap to the artifact at `artifact` (a path on
    /// the *server* host) and block until the swap is installed or
    /// rejected.  Blocks through the drain of in-flight sequences — only
    /// this connection waits; token streams on other connections continue.
    /// Only safe with no generation in flight on this connection.
    pub fn reload(&mut self, artifact: &str) -> io::Result<ReloadOutcome> {
        self.send(&Request::Reload { artifact: artifact.to_string() })?;
        loop {
            match self.next_event()? {
                Some(Event::Reloaded { artifact, engine }) => {
                    return Ok(ReloadOutcome::Swapped { artifact, engine });
                }
                Some(Event::Error { id: None, code, message, .. }) => {
                    return Ok(ReloadOutcome::Rejected { code, message });
                }
                Some(other) => {
                    return Err(bad_data(format!(
                        "unexpected event awaiting reload: {other:?}")));
                }
                None => return Err(bad_data("eof awaiting reload".into())),
            }
        }
    }

    /// Version handshake: announce [`PROTO_VERSION`] and block for the
    /// server's `hello` reply — `(proto, version, engine label)`.  A
    /// structured rejection (version skew) comes back as an error, so a
    /// mismatched peer fails at connect time instead of mid-stream.  Only
    /// safe with no generation in flight on this connection.
    pub fn hello(&mut self) -> io::Result<(u64, String, String)> {
        self.send(&Request::Hello { proto: PROTO_VERSION })?;
        loop {
            match self.next_event()? {
                Some(Event::Hello { proto, version, engine }) => {
                    return Ok((proto, version, engine));
                }
                Some(Event::Error { code, message, .. }) => {
                    return Err(bad_data(format!(
                        "handshake rejected: {code} ({message})")));
                }
                Some(other) => {
                    return Err(bad_data(format!(
                        "unexpected event awaiting hello: {other:?}")));
                }
                None => return Err(bad_data("eof awaiting hello".into())),
            }
        }
    }

    /// Liveness probe: send `ping` and block for the matching `pong`.
    /// Only safe with no generation in flight on this connection.
    pub fn ping(&mut self, nonce: u64) -> io::Result<()> {
        self.send(&Request::Ping { nonce })?;
        loop {
            match self.next_event()? {
                Some(Event::Pong { nonce: n }) if n == nonce => return Ok(()),
                Some(Event::Pong { nonce: n }) => {
                    return Err(bad_data(format!(
                        "pong nonce {n} does not match ping {nonce}")));
                }
                Some(other) => {
                    return Err(bad_data(format!(
                        "unexpected event awaiting pong: {other:?}")));
                }
                None => return Err(bad_data("eof awaiting pong".into())),
            }
        }
    }

    /// Send `shutdown` and wait for the acknowledgement + EOF.
    pub fn shutdown_server(&mut self) -> io::Result<()> {
        self.send(&Request::Shutdown)?;
        loop {
            match self.next_event()? {
                Some(Event::ShuttingDown) | None => return Ok(()),
                Some(_other) => continue, // stragglers from earlier requests
            }
        }
    }
}

// ---------------------------------------------------------------------------
// retry with jittered exponential back-off
// ---------------------------------------------------------------------------

/// Client-side retry policy: jittered exponential back-off on transient
/// failures (`overloaded`, `worker_failed`, connect refusals, mid-stream
/// EOF).  `retries = 0` (the default) preserves the classic fail-fast
/// behavior exactly.
///
/// The jitter is *deterministic* — attempt `k` draws from
/// `util::rng::Rng::new(seed ^ hash(k))` into `[base·2^(k-1)/2,
/// base·2^(k-1)]` (clamped to `max_ms`) — so a scripted client replays the
/// same schedule run-to-run while concurrent clients with distinct seeds
/// still de-synchronize their retry storms.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// attempts after the first (0 = fail fast, today's behavior)
    pub retries: u32,
    /// first back-off window, ms (doubles per attempt)
    pub base_ms: u64,
    /// upper clamp on any single back-off, ms
    pub max_ms: u64,
    /// jitter seed; distinct per client so retry storms de-synchronize
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { retries: 0, base_ms: 100, max_ms: 5_000, seed: 0x5EED }
    }
}

impl RetryPolicy {
    /// Back-off before retry attempt `attempt` (1-based), ms: a
    /// deterministic jittered draw from `[cap/2, cap]` where
    /// `cap = min(base_ms · 2^(attempt-1), max_ms)`.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        let shift = (attempt.max(1) - 1).min(32);
        // floor ≤ ceiling even for a misconfigured base_ms > max_ms
        let ceiling = self.max_ms.max(1);
        let floor = self.base_ms.max(1).min(ceiling);
        let cap = self.base_ms
            .saturating_mul(1u64 << shift)
            .clamp(floor, ceiling);
        // full-jitter lower half: [cap/2, cap]
        let lo = cap / 2;
        let mut rng = Rng::new(
            self.seed ^ (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        lo + rng.below((cap - lo + 1) as usize) as u64
    }

    /// The whole back-off schedule (one entry per retry attempt), ms.
    pub fn schedule(&self) -> Vec<u64> {
        (1..=self.retries).map(|a| self.backoff_ms(a)).collect()
    }
}

/// Is this outcome worth retrying?  `overloaded` (the server told us to
/// back off) and `worker_failed` (the fleet restarts the worker from its
/// verified artifact; a re-issued request bit-matches) are transient;
/// `bad_request` / `shutting_down` / `reload_failed` are permanent.
fn retryable_rejection(code: &str) -> bool {
    code == ERR_OVERLOADED || code == ERR_WORKER_FAILED
}

/// One generation with retries: connect, run `g` closed-loop, and on a
/// transient failure (retryable rejection, connect refusal, or mid-stream
/// EOF) back off per `policy` and try again on a **fresh connection**.  The
/// wait honors the server's `retry_after_ms` hint when it exceeds the
/// policy's own jittered back-off.  After `policy.retries` retries the last
/// outcome (or transport error) is returned as-is — the give-up path looks
/// exactly like a fail-fast client.
pub fn generate_with_retries<A: ToSocketAddrs + Copy>(
    addr: A, g: &GenerateReq, policy: &RetryPolicy)
    -> io::Result<GenerateOutcome> {
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        let res = Client::connect(addr).and_then(|mut c| c.run_generate(g));
        // decide transience + extract the server's hint without consuming
        // the result we may be about to return
        let hint_ms = match &res {
            Ok(GenerateOutcome::Done(_)) => return res,
            Ok(GenerateOutcome::Rejected { code, retry_after_ms, .. })
                if retryable_rejection(code) => retry_after_ms.unwrap_or(0),
            Ok(GenerateOutcome::Rejected { .. }) => return res, // permanent
            // transport-level: connect refused, reset, EOF mid-generation
            Err(_) => 0,
        };
        if attempt > policy.retries {
            return res; // give up: surface the last outcome verbatim
        }
        let wait = policy.backoff_ms(attempt).max(hint_ms);
        std::thread::sleep(Duration::from_millis(wait));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_prompts_are_vocab_safe_and_deterministic() {
        for vocab in [2usize, 16, 256] {
            for k in 0..5 {
                let p = scripted_prompt(k, 12, vocab);
                assert_eq!(p.len(), 12);
                assert!(p.iter().all(|&t| t >= 1 && (t as usize) < vocab),
                        "vocab {vocab} k {k}: {p:?}");
            }
        }
        assert_eq!(scripted_prompt(3, 8, 256), scripted_prompt(3, 8, 256));
    }

    #[test]
    fn backoff_schedule_is_deterministic_jittered_and_clamped() {
        let p = RetryPolicy { retries: 8, base_ms: 1024, max_ms: 1 << 16,
                              seed: 42 };
        let s1 = p.schedule();
        assert_eq!(s1, p.schedule(), "same policy → same schedule");
        assert_eq!(s1.len(), 8);
        for (i, &w) in s1.iter().enumerate() {
            // attempt i+1 draws from [cap/2, cap], cap doubling then clamped
            let cap = (1024u64 << i).min(1 << 16);
            assert!(w >= cap / 2 && w <= cap,
                    "attempt {}: {w} outside [{}, {cap}]", i + 1, cap / 2);
        }
        // a different seed de-synchronizes the schedule
        let q = RetryPolicy { seed: 43, ..p.clone() };
        assert_ne!(s1, q.schedule());
        // the default policy is fail-fast: no retries, empty schedule
        assert!(RetryPolicy::default().schedule().is_empty());
        // extreme attempts / windows must not overflow
        let h = RetryPolicy { retries: 0, base_ms: u64::MAX / 2,
                              max_ms: u64::MAX, seed: 1 };
        let w = h.backoff_ms(64);
        assert!(w >= u64::MAX / 2 - 1);
        // misconfigured base > max: clamp, don't panic
        let m = RetryPolicy { retries: 0, base_ms: 500, max_ms: 10, seed: 1 };
        assert!(m.backoff_ms(1) <= 10);
    }

    #[test]
    fn retry_classification() {
        assert!(retryable_rejection(ERR_OVERLOADED));
        assert!(retryable_rejection(ERR_WORKER_FAILED));
        assert!(!retryable_rejection(protocol::ERR_BAD_REQUEST));
        assert!(!retryable_rejection(protocol::ERR_SHUTTING_DOWN));
        assert!(!retryable_rejection(protocol::ERR_RELOAD_FAILED));
    }

    #[test]
    fn retry_recovers_after_transient_rejection() {
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpListener;
        let lst = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = lst.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            // connection 1: structured overload (with a tiny hint);
            // connection 2: a clean one-token generation
            for round in 0..2 {
                let (s, _) = lst.accept().unwrap();
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let mut w = s;
                let replies: Vec<String> = if round == 0 {
                    vec![protocol::event_line(&Event::Error {
                        id: Some(1), code: ERR_OVERLOADED.into(),
                        message: "queue full".into(), queue_depth: Some(2),
                        retry_after_ms: Some(1),
                    })]
                } else {
                    vec![
                        protocol::event_line(&Event::Token {
                            id: 1, index: 0, token: 5 }),
                        protocol::event_line(&Event::Done {
                            id: 1, tokens: vec![5], prompt_len: 1,
                            queue_ms: 0.0, prefill_ms: 0.0, decode_ms: 0.0,
                            ttft_ms: 0.0, latency_ms: 0.0, truncated: false,
                            cached_prompt_tokens: 0 }),
                    ]
                };
                for mut l in replies {
                    l.push('\n');
                    w.write_all(l.as_bytes()).unwrap();
                }
            }
        });
        let g = GenerateReq { id: 1, prompt: vec![1], max_new_tokens: 1,
                              temperature: None, seed: None };
        let policy = RetryPolicy { retries: 3, base_ms: 1, max_ms: 4,
                                   seed: 7 };
        match generate_with_retries(addr, &g, &policy).unwrap() {
            GenerateOutcome::Done(r) => assert_eq!(r.tokens, vec![5]),
            other => panic!("expected Done after one retry: {other:?}"),
        }
        server.join().unwrap();
    }

    #[test]
    fn retry_gives_up_after_budget() {
        use std::net::TcpListener;
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let lst = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = lst.local_addr().unwrap();
        let accepts = Arc::new(AtomicUsize::new(0));
        let counter = Arc::clone(&accepts);
        let server = std::thread::spawn(move || {
            // 1 initial attempt + 2 retries = exactly 3 connections, each
            // dropped immediately (EOF mid-generation = transient)
            for _ in 0..3 {
                let (s, _) = lst.accept().unwrap();
                counter.fetch_add(1, Ordering::SeqCst);
                drop(s);
            }
        });
        let g = GenerateReq { id: 1, prompt: vec![1], max_new_tokens: 1,
                              temperature: None, seed: None };
        let policy = RetryPolicy { retries: 2, base_ms: 1, max_ms: 2,
                                   seed: 9 };
        let res = generate_with_retries(addr, &g, &policy);
        assert!(res.is_err(), "give-up must surface the transport error");
        server.join().unwrap();
        assert_eq!(accepts.load(Ordering::SeqCst), 3,
                   "1 attempt + 2 retries, then stop");
    }

    #[test]
    fn permanent_rejection_fails_fast() {
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpListener;
        let lst = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = lst.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            // exactly ONE connection: a bad_request must not be retried
            let (s, _) = lst.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let mut l = protocol::event_line(&Event::error(
                Some(1), protocol::ERR_BAD_REQUEST, "nope".into()));
            l.push('\n');
            let mut w = s;
            w.write_all(l.as_bytes()).unwrap();
        });
        let g = GenerateReq { id: 1, prompt: vec![1], max_new_tokens: 1,
                              temperature: None, seed: None };
        let policy = RetryPolicy { retries: 5, base_ms: 1, max_ms: 2,
                                   seed: 3 };
        match generate_with_retries(addr, &g, &policy).unwrap() {
            GenerateOutcome::Rejected { code, .. } => {
                assert_eq!(code, protocol::ERR_BAD_REQUEST);
            }
            other => panic!("expected fail-fast rejection: {other:?}"),
        }
        server.join().unwrap();
    }
}
