//! Minimal blocking client for the wire protocol — the loopback tests, the
//! `server_throughput` bench, and the `zs-svd client` CLI subcommand all
//! drive the server through this, so stream-discipline checks (sequential
//! token indices, streamed == final tokens) live in exactly one place.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use super::protocol::{self, Event, GenerateReq, Request};

/// Deterministic vocab-safe prompt for scripted clients — the CLI `client`
/// subcommand and `benches/server_throughput.rs` share this, so the two
/// drivers can never drift apart on what a "valid" prompt is.
pub fn scripted_prompt(k: usize, len: usize, vocab: usize) -> Vec<i32> {
    let v = vocab.max(2);
    (0..len).map(|j| (1 + (k * 31 + j * 7) % (v - 1)) as i32).collect()
}

/// Blocking wire client with stream-discipline checks — the scripted CLI
/// driver, the loopback tests, and the server bench all speak through it.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Outcome of one blocking generation round-trip.
#[derive(Clone, Debug)]
pub enum GenerateOutcome {
    /// the request completed; summary + streamed tokens
    Done(GenerationResult),
    /// structured rejection (`overloaded`, `bad_request`, `shutting_down`)
    Rejected {
        /// structured error code
        code: String,
        /// human-readable detail
        message: String,
    },
}

/// Outcome of one blocking `reload` round-trip.
#[derive(Clone, Debug)]
pub enum ReloadOutcome {
    /// the artifact verified and the server swapped to it
    Swapped {
        /// manifest path the server loaded (echoed)
        artifact: String,
        /// label of the engine now serving (e.g. `lowrank-r60`)
        engine: String,
    },
    /// structured rejection (`reload_failed`); the previous plan keeps
    /// serving
    Rejected {
        /// structured error code
        code: String,
        /// human-readable detail (names the corrupt chunk when integrity
        /// verification failed)
        message: String,
    },
}

/// One completed generation as the client observed it.
#[derive(Clone, Debug)]
pub struct GenerationResult {
    /// final tokens from the `done` summary
    pub tokens: Vec<i32>,
    /// tokens as they streamed in (`run_generate` asserts == `tokens`)
    pub streamed: Vec<i32>,
    /// prompt length the server accounted
    pub prompt_len: usize,
    /// admission-queue wait, ms
    pub queue_ms: f64,
    /// slot admission → prompt fully ingested, ms (0.0 when the server
    /// predates the phase breakdown — the parse is lenient)
    pub prefill_ms: f64,
    /// prompt ingested → completion, ms (0.0 from older servers)
    pub decode_ms: f64,
    /// time to first token, ms
    pub ttft_ms: f64,
    /// end-to-end latency, ms
    pub latency_ms: f64,
    /// generation stopped early at the KV-capacity wall (fewer tokens than
    /// the requested budget)
    pub truncated: bool,
    /// prompt tokens served from the server's prefix cache (prefill
    /// skipped for them; 0 with caching off or a cold cache)
    pub cached_prompt_tokens: usize,
}

fn bad_data(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

impl Client {
    /// Connect to a running server.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request line.
    pub fn send(&mut self, r: &Request) -> io::Result<()> {
        let mut line = protocol::request_line(r);
        line.push('\n');
        self.writer.write_all(line.as_bytes())
    }

    /// Next event, or `None` on server-side EOF.
    pub fn next_event(&mut self) -> io::Result<Option<Event>> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Ok(None);
            }
            let t = line.trim();
            if t.is_empty() {
                continue;
            }
            return protocol::parse_event(t).map(Some).map_err(bad_data);
        }
    }

    /// Closed-loop generation: send `g`, then consume this request's event
    /// stream until its `done` (or `error`), checking stream discipline —
    /// token indices strictly sequential, and the streamed tokens equal to
    /// the final summary.  Only events for `g.id` may be in flight on this
    /// connection.
    pub fn run_generate(&mut self, g: &GenerateReq)
                        -> io::Result<GenerateOutcome> {
        self.send(&Request::Generate(g.clone()))?;
        let mut streamed: Vec<i32> = Vec::new();
        loop {
            let ev = self.next_event()?.ok_or_else(|| {
                bad_data("connection closed mid-generation".into())
            })?;
            match ev {
                Event::Token { id, index, token } => {
                    if id != g.id {
                        return Err(bad_data(format!(
                            "token for unexpected id {id} (want {})", g.id)));
                    }
                    if index != streamed.len() {
                        return Err(bad_data(format!(
                            "token index {index} out of order (want {})",
                            streamed.len())));
                    }
                    streamed.push(token);
                }
                Event::Done { id, tokens, prompt_len, queue_ms, prefill_ms,
                              decode_ms, ttft_ms, latency_ms, truncated,
                              cached_prompt_tokens } => {
                    if id != g.id {
                        return Err(bad_data(format!(
                            "done for unexpected id {id} (want {})", g.id)));
                    }
                    if tokens != streamed {
                        return Err(bad_data(format!(
                            "final tokens differ from stream \
                             ({} streamed, {} final)",
                            streamed.len(), tokens.len())));
                    }
                    return Ok(GenerateOutcome::Done(GenerationResult {
                        tokens,
                        streamed,
                        prompt_len,
                        queue_ms,
                        prefill_ms,
                        decode_ms,
                        ttft_ms,
                        latency_ms,
                        truncated,
                        cached_prompt_tokens,
                    }));
                }
                Event::Error { id, code, message } => {
                    if id.is_none() || id == Some(g.id) {
                        return Ok(GenerateOutcome::Rejected { code, message });
                    }
                    return Err(bad_data(format!(
                        "error for unexpected id {id:?}: {code}")));
                }
                Event::Metrics(_) => {
                    return Err(bad_data("unexpected metrics event".into()));
                }
                Event::Trace(_) => {
                    return Err(bad_data("unexpected trace event".into()));
                }
                Event::Reloaded { .. } => {
                    return Err(bad_data("unexpected reloaded event".into()));
                }
                Event::ShuttingDown => {
                    return Ok(GenerateOutcome::Rejected {
                        code: protocol::ERR_SHUTTING_DOWN.into(),
                        message: "server shutting down".into(),
                    });
                }
            }
        }
    }

    /// Request a metrics snapshot and block for the reply.  Only safe with
    /// no generation in flight on this connection.
    pub fn metrics(&mut self) -> io::Result<crate::util::json::Json> {
        self.send(&Request::Metrics)?;
        loop {
            match self.next_event()? {
                Some(Event::Metrics(j)) => return Ok(j),
                Some(other) => {
                    return Err(bad_data(format!(
                        "unexpected event awaiting metrics: {other:?}")));
                }
                None => return Err(bad_data("eof awaiting metrics".into())),
            }
        }
    }

    /// Request an observability snapshot (recent trace events + counters /
    /// histograms / kernel stats) and block for the reply.  Only safe with
    /// no generation in flight on this connection.  Always answered; when
    /// the server runs without tracing the event ring is empty and the
    /// reply says `"enabled": false`.
    pub fn trace(&mut self) -> io::Result<crate::util::json::Json> {
        self.send(&Request::Trace)?;
        loop {
            match self.next_event()? {
                Some(Event::Trace(j)) => return Ok(j),
                Some(other) => {
                    return Err(bad_data(format!(
                        "unexpected event awaiting trace: {other:?}")));
                }
                None => return Err(bad_data("eof awaiting trace".into())),
            }
        }
    }

    /// Ask the server to hot-swap to the artifact at `artifact` (a path on
    /// the *server* host) and block until the swap is installed or
    /// rejected.  Blocks through the drain of in-flight sequences — only
    /// this connection waits; token streams on other connections continue.
    /// Only safe with no generation in flight on this connection.
    pub fn reload(&mut self, artifact: &str) -> io::Result<ReloadOutcome> {
        self.send(&Request::Reload { artifact: artifact.to_string() })?;
        loop {
            match self.next_event()? {
                Some(Event::Reloaded { artifact, engine }) => {
                    return Ok(ReloadOutcome::Swapped { artifact, engine });
                }
                Some(Event::Error { id: None, code, message }) => {
                    return Ok(ReloadOutcome::Rejected { code, message });
                }
                Some(other) => {
                    return Err(bad_data(format!(
                        "unexpected event awaiting reload: {other:?}")));
                }
                None => return Err(bad_data("eof awaiting reload".into())),
            }
        }
    }

    /// Send `shutdown` and wait for the acknowledgement + EOF.
    pub fn shutdown_server(&mut self) -> io::Result<()> {
        self.send(&Request::Shutdown)?;
        loop {
            match self.next_event()? {
                Some(Event::ShuttingDown) | None => return Ok(()),
                Some(_other) => continue, // stragglers from earlier requests
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_prompts_are_vocab_safe_and_deterministic() {
        for vocab in [2usize, 16, 256] {
            for k in 0..5 {
                let p = scripted_prompt(k, 12, vocab);
                assert_eq!(p.len(), 12);
                assert!(p.iter().all(|&t| t >= 1 && (t as usize) < vocab),
                        "vocab {vocab} k {k}: {p:?}");
            }
        }
        assert_eq!(scripted_prompt(3, 8, 256), scripted_prompt(3, 8, 256));
    }
}
