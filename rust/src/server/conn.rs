//! Blocking TCP front-end: accept loop, per-connection reader/writer
//! threads, admission into the long-lived decode scheduler, and streamed
//! token fan-out.
//!
//! # Thread anatomy (all inside one `std::thread::scope`, so [`run`] blocks
//! until the server has fully unwound)
//!
//! * the **calling thread** runs the accept loop;
//! * one **engine thread** runs `decode::run_engine` over a queue-backed
//!   [`RequestSource`]; its emission sink routes every token/completion to
//!   the owning connection's outbox and feeds the metrics registry;
//! * per connection, a **reader** parses newline-delimited requests and
//!   admits them (bounded queue — full ⇒ structured `overloaded` reply),
//!   and a **writer** drains that connection's outbox to the socket, so a
//!   slow client never stalls the engine or other connections.
//!
//! # Shutdown
//!
//! A `shutdown` request (or an engine exit) closes the admission queue and
//! wakes the accept loop via a loopback connect.  The engine drains every
//! admitted request, then outboxes are closed: writers flush and shut their
//! sockets down, which unblocks the readers, and the scope joins.  Clients
//! with in-flight work see it complete; new work is rejected with
//! `shutting_down`.
//!
//! # Live reload
//!
//! A server started through [`run_swappable`] owns its engine state (an
//! [`EngineSlot`]) and accepts `reload` requests: the reader thread loads
//! and fully verifies the named artifact *off* the engine thread, then
//! posts the new slot to the engine's [`SwapMailbox`] and blocks until the
//! scheduler has drained in-flight sequences and installed it (see
//! `decode::run_engine_swappable`).  Verification failures never touch the
//! engine — the old plan keeps serving and the client gets a structured
//! `reload_failed` error.  Only the reload's own connection blocks while
//! the swap drains; token fan-out rides the writer threads.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::admission::{self, BoundedQueue, PopState, PushError};
use super::metrics::Metrics;
use super::protocol::{self, Event, Request, ERR_BAD_REQUEST, ERR_OVERLOADED,
                      ERR_RELOAD_FAILED, ERR_SHUTTING_DOWN, PROTO_VERSION};
use crate::decode::{self, DecodeConfig, DecodeEvent, DecodeRequest,
                    EngineCounters, EngineSlot, RequestSource, SourcePoll,
                    SwapMailbox};
use crate::model::ParamStore;
use crate::runtime::session::Session;
use crate::serve::Engine;
use crate::util::stats::LatencySummary;

/// Network server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// listen address, e.g. `"127.0.0.1:0"` (0 = OS-assigned port)
    pub addr: String,
    /// admission-queue depth; requests beyond it get `overloaded`
    pub queue_depth: usize,
    /// scheduler shape + per-request defaults (slots, default generation
    /// budget, default temperature, engine seed, and the chunked-prefill
    /// budget `prefill_chunk` — large prompts ingest in bounded chunks that
    /// interleave with ongoing decode steps instead of stalling the batch;
    /// `arrival_steps` is unused here — arrivals are real network events)
    pub decode: DecodeConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            queue_depth: 64,
            decode: DecodeConfig::default(),
        }
    }
}

/// Final accounting for one server run (the live view is the metrics
/// snapshot over the wire).
#[derive(Clone, Debug)]
pub struct ServerStats {
    /// engine label (`dense` / `lowrank-r<tag>`)
    pub engine: String,
    /// the decode engine's aggregate counters
    pub counters: EngineCounters,
    /// connections accepted over the run
    pub connections: u64,
    /// requests admitted into the queue
    pub requests_admitted: u64,
    /// requests rejected (overloaded / shutting down)
    pub requests_rejected: u64,
    /// end-to-end request latency (enqueue → completion), ms
    pub e2e: LatencySummary,
    /// time-to-first-token, ms
    pub ttft: LatencySummary,
    /// inter-token gap, ms
    pub token_gap: LatencySummary,
    /// admission-queue wait, ms
    pub queue_wait: LatencySummary,
}

// ---------------------------------------------------------------------------
// per-connection outbox
// ---------------------------------------------------------------------------

/// Hard bound on queued-but-unwritten lines per connection: a client that
/// stops reading cannot grow server memory without limit — at the cap the
/// connection is declared dead (outbox closed, backlog dropped).
const OUTBOX_MAX_LINES: usize = 16_384;

/// How long a single socket write may block before the connection is
/// declared dead.  Bounds shutdown: a stalled client cannot pin its writer
/// thread (and therefore `server::run`'s scope join) forever.
const WRITE_STALL_LIMIT: Duration = Duration::from_secs(30);

struct OutboxInner {
    lines: VecDeque<String>,
    closed: bool,
}

/// FIFO of wire lines from any producer (reader replies, engine emissions)
/// to the connection's writer thread.
struct Outbox {
    inner: Mutex<OutboxInner>,
    cv: Condvar,
}

impl Outbox {
    fn new() -> Outbox {
        Outbox {
            inner: Mutex::new(OutboxInner { lines: VecDeque::new(),
                                            closed: false }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, line: String) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if g.closed {
            return;
        }
        if g.lines.len() >= OUTBOX_MAX_LINES {
            // the client stopped reading long ago: drop the connection
            // rather than buffer without bound
            g.closed = true;
            g.lines.clear();
            self.cv.notify_all();
            return;
        }
        g.lines.push_back(line);
        self.cv.notify_all();
    }

    /// Close for new lines; queued lines still drain through `pop`.
    fn close(&self) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.closed = true;
        self.cv.notify_all();
    }

    /// Blocking pop; `None` once closed *and* drained.
    fn pop(&self) -> Option<String> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(l) = g.lines.pop_front() {
                return Some(l);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

struct ConnState {
    outbox: Outbox,
    /// requests admitted on this connection and not yet completed
    inflight: AtomicUsize,
    /// reader saw EOF — close the outbox once in-flight work finishes
    draining: AtomicBool,
}

impl ConnState {
    fn new() -> ConnState {
        ConnState { outbox: Outbox::new(), inflight: AtomicUsize::new(0),
                    draining: AtomicBool::new(false) }
    }

    fn send(&self, ev: &Event) {
        self.outbox.push(protocol::event_line(ev));
    }

    fn maybe_close(&self) {
        if self.draining.load(Ordering::SeqCst)
            && self.inflight.load(Ordering::SeqCst) == 0
        {
            self.outbox.close();
        }
    }

    /// Fully torn down: nothing will ever be written to this connection
    /// again, so the registry may drop it.
    fn is_closed(&self) -> bool {
        self.outbox.inner.lock().unwrap_or_else(|e| e.into_inner()).closed
    }
}

// ---------------------------------------------------------------------------
// shared server state + the queue-backed request source
// ---------------------------------------------------------------------------

struct Route {
    conn: Arc<ConnState>,
    client_id: u64,
}

struct Admitted {
    req: DecodeRequest,
    client_id: u64,
    conn: Arc<ConnState>,
    enqueued: Instant,
}

struct Shared {
    queue: BoundedQueue<Admitted>,
    /// server-assigned request id → owning connection (sink fan-out)
    routes: Mutex<BTreeMap<usize, Route>>,
    metrics: Metrics,
    shutdown: AtomicBool,
    /// label of the engine this server booted with, echoed on `hello`
    /// replies (the live label after hot-swaps travels on `reloaded`)
    engine: String,
}

/// Start the graceful drain exactly once: close admissions and wake the
/// blocked accept loop with a loopback connect.
fn initiate_shutdown(shared: &Shared, local: SocketAddr) {
    if shared.shutdown.swap(true, Ordering::SeqCst) {
        return;
    }
    shared.queue.close();
    let _ = TcpStream::connect(local);
}

struct NetSource<'a> {
    shared: &'a Shared,
}

impl RequestSource for NetSource<'_> {
    fn poll(&mut self, _iter: usize) -> SourcePoll {
        // pop and drain-state must be one atomic observation: a separate
        // `is_closed` check could see a close that raced in AFTER an
        // admission slipped into the momentarily-empty queue, and silently
        // drop that admitted request at shutdown
        match self.shared.queue.pop_or_state() {
            PopState::Item(a) => {
                self.shared
                    .routes
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .insert(a.req.id, Route { conn: a.conn,
                                              client_id: a.client_id });
                SourcePoll::Ready(a.req, a.enqueued)
            }
            PopState::Drained => SourcePoll::Drained,
            PopState::Empty => SourcePoll::Pending,
        }
    }

    fn idle_wait(&mut self, iter: usize) -> usize {
        self.shared.queue.wait_nonempty(Duration::from_millis(50));
        iter + 1
    }
}

// ---------------------------------------------------------------------------
// connection threads
// ---------------------------------------------------------------------------

fn writer_loop(conn: &ConnState, mut stream: TcpStream) {
    while let Some(mut line) = conn.outbox.pop() {
        line.push('\n');
        if stream.write_all(line.as_bytes()).is_err() {
            // client gone: stop queueing for it and drain the rest cheaply
            conn.outbox.close();
        }
    }
    let _ = stream.flush();
    // closing both halves unblocks this connection's reader
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

#[allow(clippy::too_many_arguments)]
fn reader_loop(shared: &Shared, conn: &Arc<ConnState>, stream: TcpStream,
               next_id: &AtomicUsize, scfg: &ServerConfig, sess: &Session,
               mailbox: Option<&SwapMailbox>, local: SocketAddr) {
    let seq_len = sess.cfg.seq_len;
    let vocab = sess.cfg.vocab;
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    loop {
        buf.clear();
        match reader.read_line(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let line = buf.trim();
        if line.is_empty() {
            continue;
        }
        match protocol::parse_request(line) {
            Err(e) => conn.send(&Event::error(None, ERR_BAD_REQUEST, e)),
            Ok(Request::Hello { proto }) => {
                if proto == PROTO_VERSION {
                    conn.send(&Event::Hello {
                        proto: PROTO_VERSION,
                        version: env!("CARGO_PKG_VERSION").into(),
                        engine: shared.engine.clone(),
                    });
                } else {
                    // version skew fails loudly at handshake time, not
                    // with a parse error mid-stream
                    conn.send(&Event::error(None, ERR_BAD_REQUEST, format!(
                        "unsupported proto {proto} (this server speaks \
                         {PROTO_VERSION})")));
                }
            }
            Ok(Request::Ping { nonce }) => {
                conn.send(&Event::Pong { nonce });
            }
            Ok(Request::Metrics) => {
                conn.send(&Event::Metrics(
                    shared.metrics.snapshot(shared.queue.len())));
            }
            Ok(Request::Trace) => {
                // recent-events tail only: the full ring can hold 64k
                // events, which is more than a wire reply should carry
                conn.send(&Event::Trace(crate::obs::snapshot_json(2048)));
            }
            Ok(Request::Reload { artifact }) => match mailbox {
                None => conn.send(&Event::error(
                    None, ERR_RELOAD_FAILED,
                    "this server was started without hot-swap support \
                     (run_swappable)".into())),
                Some(mb) => match apply_reload(sess, mb, &artifact) {
                    Ok(engine) => {
                        shared.metrics.inc("artifact.swaps", 1);
                        conn.send(&Event::Reloaded { artifact, engine });
                    }
                    Err(e) => {
                        shared.metrics.inc("artifact.reload_failures", 1);
                        conn.send(&Event::error(None, ERR_RELOAD_FAILED,
                                                format!("{e}")));
                    }
                },
            },
            Ok(Request::Shutdown) => {
                conn.send(&Event::ShuttingDown);
                initiate_shutdown(shared, local);
            }
            Ok(Request::Generate(g)) => {
                if let Err(msg) = validate_prompt(&g.prompt, seq_len, vocab) {
                    conn.send(&Event::error(Some(g.id), ERR_BAD_REQUEST,
                                            msg));
                    continue;
                }
                // clamp the budget to the KV capacity: generation stops at a
                // full arena anyway, and an absurd client-supplied budget
                // must never size an allocation.  A wire value of 0 means
                // "use the server's default"; a budget that *resolves* to 0
                // (a server configured with no default) is a caller error —
                // the engine refuses zero-token generations.
                let budget = if g.max_new_tokens == 0 {
                    scfg.decode.max_new_tokens
                } else {
                    g.max_new_tokens
                }
                .min(seq_len);
                if budget == 0 {
                    conn.send(&Event::error(
                        Some(g.id), ERR_BAD_REQUEST,
                        "resolved max_new_tokens is 0 (no client budget \
                         and no server default)".into()));
                    continue;
                }
                let gid = next_id.fetch_add(1, Ordering::SeqCst);
                let req = DecodeRequest {
                    id: gid,
                    prompt: g.prompt,
                    max_new_tokens: budget,
                    temperature: g.temperature,
                    seed: g.seed,
                };
                conn.inflight.fetch_add(1, Ordering::SeqCst);
                let admitted = Admitted {
                    req,
                    client_id: g.id,
                    conn: Arc::clone(conn),
                    enqueued: Instant::now(),
                };
                match shared.queue.try_push(admitted) {
                    Ok(()) => shared.metrics.inc("requests_admitted", 1),
                    Err(PushError::Full(_)) => {
                        conn.inflight.fetch_sub(1, Ordering::SeqCst);
                        shared.metrics.inc("requests_rejected", 1);
                        // sample the backlog once: the depth + hint on the
                        // reply must describe the same instant
                        let queued = shared.queue.len();
                        conn.send(&Event::Error {
                            id: Some(g.id),
                            code: ERR_OVERLOADED.into(),
                            message: format!(
                                "admission queue full (depth {})",
                                shared.queue.depth()),
                            queue_depth: Some(queued),
                            retry_after_ms: Some(
                                admission::retry_after_hint_ms(
                                    queued, shared.queue.depth())),
                        });
                    }
                    Err(PushError::Closed(_)) => {
                        conn.inflight.fetch_sub(1, Ordering::SeqCst);
                        conn.send(&Event::error(Some(g.id),
                                                ERR_SHUTTING_DOWN,
                                                "server is draining".into()));
                    }
                }
            }
        }
    }
    conn.draining.store(true, Ordering::SeqCst);
    conn.maybe_close();
}

/// Load + verify an artifact and post it to the engine's swap mailbox.
/// Runs on the reader thread; returns the new engine label once the
/// scheduler has installed the slot.  Every failure mode (missing file,
/// corrupt chunk, model mismatch, concurrent reload) surfaces here before
/// the engine is touched.
fn apply_reload(sess: &Session, mailbox: &SwapMailbox, artifact: &str)
                -> Result<String> {
    let bundle = crate::artifact::load(Path::new(artifact))
        .with_context(|| format!("loading artifact `{artifact}`"))?;
    bundle.validate_against(&sess.cfg)?;
    mailbox.request(EngineSlot {
        params: bundle.params,
        engine: bundle.engine,
        drafter: bundle.drafter,
    })
}

fn validate_prompt(prompt: &[i32], seq_len: usize, vocab: usize)
                   -> Result<(), String> {
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    if prompt.len() > seq_len {
        return Err(format!("prompt {} exceeds seq_len {seq_len}",
                           prompt.len()));
    }
    for &t in prompt {
        if t < 0 || t as usize >= vocab {
            return Err(format!("token {t} out of range [0, {vocab})"));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// server entry point
// ---------------------------------------------------------------------------

/// How the engine thread holds its weights: borrowed (the classic fixed
/// server) or owned (the hot-swappable server, which can replace them).
enum EngineBinding<'a> {
    Fixed {
        params: &'a ParamStore,
        engine: &'a Engine,
        drafter: Option<&'a Engine>,
    },
    Swappable(EngineSlot),
}

/// Bind `cfg.addr`, report the bound address through `ready`, and serve
/// until a `shutdown` request drains the engine.  Blocking: returns only
/// after every connection and the engine have unwound, with the session's
/// final accounting.
///
/// When `drafter` is `Some` and `cfg.decode.speculate_k > 0`, the engine
/// thread runs speculative self-decode: the drafter proposes up to
/// `speculate_k` tokens per greedy slot per iteration and `engine` (the
/// target) verifies them in one batched call.  Streamed tokens are
/// bit-identical to the non-speculative path.
///
/// This server has no hot-swap support: `reload` requests are answered
/// with a structured `reload_failed` error.  Use [`run_swappable`] for a
/// server that can replace its plan under traffic.
pub fn run(sess: &Session, params: &ParamStore, engine: &Engine,
           drafter: Option<&Engine>, cfg: &ServerConfig,
           ready: impl FnOnce(SocketAddr))
           -> Result<ServerStats> {
    run_inner(sess, EngineBinding::Fixed { params, engine, drafter }, cfg,
              ready)
}

/// [`run`] with an *owned* engine state and live A/B hot-swap: a `reload`
/// wire request loads + verifies a packed artifact (`crate::artifact`) off
/// the engine thread and swaps it in once in-flight sequences drain.
/// Post-swap generations are bit-identical to a fresh server started on
/// the swapped-in artifact; a failed verification leaves the current plan
/// serving untouched.
///
/// `ServerStats::engine` reports the *initial* slot's label even after
/// swaps — the live engine label travels on each `reloaded` event, and
/// `counters.plan_swaps` / the `artifact.swaps` wire counter say how many
/// swaps were installed.
pub fn run_swappable(sess: &Session, slot: EngineSlot, cfg: &ServerConfig,
                     ready: impl FnOnce(SocketAddr))
                     -> Result<ServerStats> {
    run_inner(sess, EngineBinding::Swappable(slot), cfg, ready)
}

fn run_inner(sess: &Session, binding: EngineBinding<'_>, cfg: &ServerConfig,
             ready: impl FnOnce(SocketAddr))
             -> Result<ServerStats> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local = listener.local_addr()?;
    // stats label + drafter presence, captured before the binding moves
    // into the engine thread
    let (engine_label, has_drafter) = match &binding {
        EngineBinding::Fixed { engine, drafter, .. } => {
            (engine.label(), drafter.is_some())
        }
        EngineBinding::Swappable(slot) => {
            (slot.engine.label(), slot.drafter.is_some())
        }
    };
    let shared = Shared {
        queue: BoundedQueue::new(cfg.queue_depth.max(1)),
        routes: Mutex::new(BTreeMap::new()),
        metrics: Metrics::new(),
        shutdown: AtomicBool::new(false),
        engine: engine_label.clone(),
    };
    let next_id = AtomicUsize::new(0);
    let conns: Mutex<Vec<Arc<ConnState>>> = Mutex::new(Vec::new());
    // one mailbox per server run; readers see it only on the swappable path
    let mailbox = SwapMailbox::new();
    let mailbox_ref: Option<&SwapMailbox> = match &binding {
        EngineBinding::Fixed { .. } => None,
        EngineBinding::Swappable(_) => Some(&mailbox),
    };

    ready(local);

    let counters: Result<EngineCounters> = std::thread::scope(|s| {
        let shared = &shared;
        let next_id = &next_id;
        let conns = &conns;
        let mailbox = &mailbox;

        let engine_h = s.spawn(move || {
            // the server cannot serve without its engine: whatever way this
            // thread exits (drain, error, panic), release the accept loop
            struct ShutdownOnExit<'a> {
                shared: &'a Shared,
                local: SocketAddr,
            }
            impl Drop for ShutdownOnExit<'_> {
                fn drop(&mut self) {
                    initiate_shutdown(self.shared, self.local);
                }
            }
            let _guard = ShutdownOnExit { shared, local };

            let mut source = NetSource { shared };
            let mut sink = |ev: DecodeEvent| match ev {
                DecodeEvent::Token { id, index, token, gap_secs } => {
                    shared.metrics.inc("decode_tokens", 1);
                    shared.metrics.record_ms("token_gap_ms", gap_secs * 1e3);
                    let routes =
                        shared.routes.lock().unwrap_or_else(|e| e.into_inner());
                    if let Some(r) = routes.get(&id) {
                        r.conn.send(&Event::Token {
                            id: r.client_id,
                            index,
                            token,
                        });
                    }
                }
                DecodeEvent::Draft { proposed, accepted } => {
                    shared.metrics.inc("draft_proposed_tokens",
                                       proposed as u64);
                    shared.metrics.inc("draft_accepted_tokens",
                                       accepted as u64);
                }
                DecodeEvent::Rejected { id, reason } => {
                    // scheduler-level validation failure: only this request
                    // fails (the engine loop keeps serving).  The wire
                    // reader screens at admission, so this arm fires only
                    // for requests that slipped past it — still route a
                    // structured error and free the connection's in-flight
                    // slot.
                    shared.metrics.inc("requests_rejected", 1);
                    let route = shared
                        .routes
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .remove(&id);
                    if let Some(r) = route {
                        r.conn.send(&Event::error(Some(r.client_id),
                                                  ERR_BAD_REQUEST, reason));
                        r.conn.inflight.fetch_sub(1, Ordering::SeqCst);
                        r.conn.maybe_close();
                    }
                }
                DecodeEvent::Done(c) => {
                    shared.metrics.inc("requests_completed", 1);
                    shared.metrics.inc("prefill_tokens", c.prompt_len as u64);
                    shared.metrics.inc("cached_prompt_tokens",
                                       c.cached_prompt_tokens as u64);
                    shared.metrics.record_ms("e2e_ms", c.latency_ms);
                    shared.metrics.record_ms("ttft_ms", c.ttft_ms);
                    shared.metrics.record_ms("queue_ms", c.queue_ms);
                    shared.metrics.record_ms("prefill_ms", c.prefill_ms);
                    shared.metrics.record_ms("decode_ms", c.decode_ms);
                    let route = shared
                        .routes
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .remove(&c.id);
                    if let Some(r) = route {
                        r.conn.send(&Event::Done {
                            id: r.client_id,
                            tokens: c.tokens,
                            prompt_len: c.prompt_len,
                            queue_ms: c.queue_ms,
                            prefill_ms: c.prefill_ms,
                            decode_ms: c.decode_ms,
                            ttft_ms: c.ttft_ms,
                            latency_ms: c.latency_ms,
                            truncated: c.truncated,
                            cached_prompt_tokens: c.cached_prompt_tokens,
                        });
                        r.conn.inflight.fetch_sub(1, Ordering::SeqCst);
                        r.conn.maybe_close();
                    }
                }
            };
            match binding {
                EngineBinding::Fixed { params, engine, drafter } => {
                    decode::run_engine(sess, params, engine, drafter,
                                       &cfg.decode, &mut source, &mut sink)
                }
                EngineBinding::Swappable(slot) => {
                    decode::run_engine_swappable(sess, slot, &cfg.decode,
                                                 &mut source, &mut sink,
                                                 mailbox)
                }
            }
        });

        // accept loop on the calling thread.  Non-blocking + bounded nap:
        // shutdown must never depend on another connection arriving (the
        // loopback connect in `initiate_shutdown` is only a latency
        // optimization and can fail on exotic bind addresses).
        let nonblocking = listener.set_nonblocking(true).is_ok();
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let read_stream = match listener.accept() {
                Ok((st, _)) => st,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                    continue;
                }
                Err(_) => {
                    // transient accept failure; don't spin hot on it
                    std::thread::sleep(Duration::from_millis(25));
                    continue;
                }
            };
            if nonblocking {
                // accepted sockets must be blocking regardless of what they
                // inherit from the listener on this platform
                let _ = read_stream.set_nonblocking(false);
            }
            let Ok(write_stream) = read_stream.try_clone() else { continue };
            // a stalled client must not block its writer forever (see
            // WRITE_STALL_LIMIT) — shutdown joins every writer thread
            let _ = write_stream.set_write_timeout(Some(WRITE_STALL_LIMIT));
            shared.metrics.inc("connections", 1);
            let conn = Arc::new(ConnState::new());
            {
                // the registry exists only for the final shutdown flush:
                // prune fully-closed connections so a long-lived server
                // doesn't accumulate one dead entry per past connection
                let mut reg = conns.lock().unwrap_or_else(|e| e.into_inner());
                reg.retain(|c| !c.is_closed());
                reg.push(Arc::clone(&conn));
            }
            {
                let conn = Arc::clone(&conn);
                s.spawn(move || {
                    reader_loop(shared, &conn, read_stream, next_id, cfg,
                                sess, mailbox_ref, local);
                });
            }
            s.spawn(move || writer_loop(&conn, write_stream));
        }

        let joined = engine_h.join();

        // engine is done (or died): flush a final notice and release every
        // connection BEFORE propagating any engine panic — writers flush +
        // shut their sockets, unblocking the readers, so the scope can
        // always join its threads instead of hanging on a dead engine
        for conn in conns.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            conn.send(&Event::ShuttingDown);
            conn.outbox.close();
        }
        joined.unwrap_or_else(|e| std::panic::resume_unwind(e))
    });

    let counters = counters?;
    let m = &shared.metrics;
    let label = if has_drafter && cfg.decode.speculate_k > 0 {
        format!("{engine_label}+spec-k{}", cfg.decode.speculate_k)
    } else {
        engine_label
    };
    Ok(ServerStats {
        engine: label,
        counters,
        connections: m.counter("connections"),
        requests_admitted: m.counter("requests_admitted"),
        requests_rejected: m.counter("requests_rejected"),
        e2e: m.summary("e2e_ms"),
        ttft: m.summary("ttft_ms"),
        token_gap: m.summary("token_gap_ms"),
        queue_wait: m.summary("queue_ms"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outbox_drains_then_reports_closed() {
        let o = Outbox::new();
        o.push("a".into());
        o.push("b".into());
        o.close();
        // close is not loss: queued lines still come out, in order
        assert_eq!(o.pop().as_deref(), Some("a"));
        assert_eq!(o.pop().as_deref(), Some("b"));
        assert_eq!(o.pop(), None);
        // pushes after close are dropped
        o.push("c".into());
        assert_eq!(o.pop(), None);
    }

    #[test]
    fn conn_close_waits_for_inflight() {
        let c = ConnState::new();
        c.inflight.fetch_add(1, Ordering::SeqCst);
        c.draining.store(true, Ordering::SeqCst);
        c.maybe_close();
        c.outbox.push("still open".into());
        assert_eq!(c.outbox.pop().as_deref(), Some("still open"));
        // last in-flight request completes → outbox closes
        c.inflight.fetch_sub(1, Ordering::SeqCst);
        c.maybe_close();
        assert_eq!(c.outbox.pop(), None);
    }

    #[test]
    fn prompt_validation() {
        assert!(validate_prompt(&[], 8, 256).is_err());
        assert!(validate_prompt(&[1; 9], 8, 256).is_err());
        assert!(validate_prompt(&[-1], 8, 256).is_err());
        assert!(validate_prompt(&[256], 8, 256).is_err());
        assert!(validate_prompt(&[0, 255], 8, 256).is_ok());
    }
}
