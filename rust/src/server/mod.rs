//! Network serving subsystem: a dependency-free TCP front-end over the
//! KV-cached continuous-batching decode engine, with streaming output,
//! bounded admission, graceful drain, and wire-queryable metrics.
//!
//! # Layout
//!
//! * [`protocol`] — the newline-delimited JSON wire format (spec below),
//!   built on `util::json`.
//! * [`conn`] — the blocking `TcpListener` accept loop, per-connection
//!   reader/writer threads, and the queue-backed `RequestSource` feeding
//!   `decode::run_engine` ([`run`] is the entry point).
//! * [`admission`] — the bounded queue between readers and the scheduler;
//!   a full queue answers with a structured `overloaded` error instead of
//!   growing an unbounded backlog.
//! * [`metrics`] — counters + latency reservoirs (tokens/sec, queue depth,
//!   p50/p95/p99 per-token and end-to-end), queryable over the protocol.
//! * [`client`] — a minimal blocking client (loopback tests, the
//!   throughput bench, the `zs-svd client` CLI).
//!
//! Determinism: generated tokens depend only on (engine weights, prompt,
//! sampling temperature, sampler seed) — never on connection interleaving,
//! slot assignment, or thread count — so a generation served over TCP
//! **bit-matches** the offline `decode::run_decode` path for the same
//! explicit settings (`rust/tests/server_loopback.rs` gates this for the
//! dense and low-rank engines at `PALLAS_THREADS` ∈ {1, 4}).
//!
//! # Wire protocol
//!
//! One JSON object per `\n`-terminated line, both directions.  Client
//! messages:
//!
//! | type       | fields                                                              |
//! |------------|---------------------------------------------------------------------|
//! | `generate` | `id` (echoed on every reply), `prompt` (token array), optional `max_new_tokens` (0/absent = server default), `temperature`, `seed` |
//! | `metrics`  | — (replies with one `metrics` snapshot)                             |
//! | `trace`    | — (replies with one `trace` observability snapshot)                 |
//! | `reload`   | `artifact` (server-host path to a packed `.zsar` manifest; see `crate::artifact`).  The server loads + verifies it off the engine thread and hot-swaps once in-flight work drains.  Replies `reloaded` on success, `error`/`reload_failed` otherwise (including on servers started without [`run_swappable`]).  Against a fleet router the path fans out to every worker (comma-separate N paths for per-worker stores) |
//! | `hello`    | optional version handshake: `proto` (the revision the client speaks, absent = 1).  A matching server replies `hello`; a mismatch is a structured `bad_request`, so version skew fails loudly at connect time |
//! | `ping`     | `nonce` (echoed in the `pong` reply) — liveness probe; the fleet router heartbeats workers with it |
//! | `shutdown` | — (ack `shutting_down`, then drain + close)                         |
//!
//! Server messages:
//!
//! | type            | fields                                                         |
//! |-----------------|----------------------------------------------------------------|
//! | `token`         | `id`, `index` (0-based, strictly sequential), `token` — one per sampled token, streamed as produced |
//! | `done`          | `id`, `tokens` (the full generation), `prompt_len`, latency breakdown `queue_ms` / `prefill_ms` / `decode_ms` / `ttft_ms` / `latency_ms`, `truncated` (true when generation stopped early at the KV-capacity wall).  `truncated`, `prefill_ms` and `decode_ms` are absent from older peers; clients parse them leniently (false / 0.0) |
//! | `error`         | `code` (`overloaded` \| `bad_request` \| `shutting_down` \| `reload_failed` \| `worker_failed` \| `slow_reader`), `message`, `id` when attributable to one request.  `overloaded` additionally carries `queue_depth` (requests queued ahead) and `retry_after_ms` (suggested back-off) — both absent from older peers and parsed leniently |
//! | `metrics`       | `uptime_secs`, `queue_depth`, `uptime_tok_per_sec` (whole-uptime average), `draft_acceptance_rate` (accepted/proposed drafter tokens; 0 without speculation), `gauges{..}` (scheduler occupancy: active slots, KV tokens/capacity, arena/draft pool sizes, queue depth), `counters{..}`, `latency_ms{series → {n,mean,p50,p95,p99,max}}` |
//! | `trace`         | observability snapshot from `crate::obs`: `enabled`, `events` (recent trace-event ring, capped), `events_total` / `events_dropped`, `counters{..}`, `histograms{..}`, `kernels{..}`, `gauges{..}`.  Always answered; with tracing off the ring is empty |
//! | `reloaded`      | `artifact` (echoed path), `engine` (label now serving).  Sent once per successful `reload`; the wire `metrics` counter `artifact.swaps` counts installed swaps |
//! | `hello`         | `proto` (revision the server speaks), `version` (crate version), `engine` (label now serving) — reply to a `hello` request |
//! | `pong`          | `nonce` (echoed) — reply to `ping`                             |
//! | `shutting_down` | — (the connection closes after in-flight work completes)        |
//!
//! Requests from one connection may interleave; every reply carries the
//! client-chosen `id`.  A rejected request produces exactly one `error` and
//! nothing else; an accepted request produces its `token` stream followed
//! by exactly one `done`.
//!
//! # Worked client session
//!
//! ```text
//! C: {"type":"generate","id":1,"prompt":[5,17,200],"max_new_tokens":3,"seed":42}
//! S: {"type":"token","id":1,"index":0,"token":137}
//! S: {"type":"token","id":1,"index":1,"token":9}
//! S: {"type":"token","id":1,"index":2,"token":41}
//! S: {"type":"done","id":1,"tokens":[137,9,41],"prompt_len":3,
//!     "queue_ms":0.2,"ttft_ms":14.8,"latency_ms":31.5,"truncated":false}
//! C: {"type":"metrics"}
//! S: {"type":"metrics","uptime_secs":2.1,"queue_depth":0,"uptime_tok_per_sec":95.1,
//!     "counters":{"connections":1,"decode_tokens":3,...},
//!     "latency_ms":{"e2e_ms":{"n":1,"p50":31.5,...},...}}
//! C: {"type":"shutdown"}
//! S: {"type":"shutting_down"}
//! (connection closes)
//! ```
//!
//! From Rust, the same session via [`client::Client`]:
//!
//! ```text
//! let mut c = Client::connect(addr)?;
//! let out = c.run_generate(&GenerateReq { id: 1, prompt, max_new_tokens: 3,
//!                                         temperature: None, seed: Some(42) })?;
//! let snap = c.metrics()?;
//! c.shutdown_server()?;
//! ```
//!
//! Start a server from the CLI with `zs-svd serve --listen 127.0.0.1:0`
//! (dense) or `--plan --ratio 0.6` (ZS-SVD low-rank engine), and drive it
//! with `zs-svd client --connect <addr>`.  Adding `--speculate-k K` to the
//! server turns on speculative self-decode: a high-compression ZS-SVD
//! drafter proposes up to K tokens per greedy slot per iteration and the
//! serving engine verifies them in one batched call — streamed tokens are
//! bit-identical to the non-speculative server, only latency and the
//! `draft_*` metrics change.
//!
//! A server started on a packed artifact (`zs-svd serve --artifact
//! store/tiny-zs60.zsar`) supports live reload: `zs-svd client --connect
//! <addr> --reload <path>` swaps the serving plan under traffic, and
//! post-swap generations bit-match a fresh server started on that artifact
//! (gated in `rust/tests/server_loopback.rs`).

pub mod admission;
pub mod client;
pub mod conn;
pub mod metrics;
pub mod protocol;

pub use client::{generate_with_retries, scripted_prompt, Client,
                 GenerateOutcome, GenerationResult, ReloadOutcome,
                 RetryPolicy};
pub use conn::{run, run_swappable, ServerConfig, ServerStats};
pub use metrics::Metrics;
pub use protocol::{Event, GenerateReq, Request};
