//! Admission control: a bounded FIFO queue between connection readers and
//! the decode scheduler.
//!
//! `try_push` never blocks — a full queue is a structured [`PushError::Full`]
//! that the connection layer turns into an `overloaded` wire error, so
//! admission pressure surfaces to clients instead of growing an unbounded
//! backlog.  `close` starts the graceful drain: further pushes are rejected
//! with [`PushError::Closed`] while queued items remain poppable, and the
//! scheduler's source reports `Drained` once the queue is closed *and*
//! empty.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Back-off hint attached to `overloaded` rejections: a suggested client
/// wait before retrying, scaled by how many requests were queued ahead
/// (~[`HINT_MS_PER_QUEUED`] ms each) and clamped to a sane window.  Purely
/// advisory — the server promises nothing about capacity after the wait —
/// but it lets fleet clients back off proportionally to the actual backlog
/// instead of guessing.
pub fn retry_after_hint_ms(queued: usize, depth: usize) -> u64 {
    // a deeper configured queue implies a slower-draining server, so the
    // hint never suggests less than one "slot drain" even when the sampled
    // backlog raced down to zero
    let backlog = queued.max(1).min(depth.max(1)) as u64;
    HINT_MS_PER_QUEUED
        .saturating_mul(backlog)
        .clamp(HINT_MS_PER_QUEUED, HINT_MS_MAX)
}

/// Per-queued-request drain estimate behind [`retry_after_hint_ms`].
pub const HINT_MS_PER_QUEUED: u64 = 25;
/// Upper clamp for [`retry_after_hint_ms`] — a hint longer than this stops
/// being a back-off and starts being an outage report.
pub const HINT_MS_MAX: u64 = 2_000;

/// Rejection reasons; the rejected item rides back to the caller.
#[derive(Debug)]
pub enum PushError<T> {
    /// at capacity — back-pressure the client
    Full(T),
    /// draining for shutdown — no new admissions
    Closed(T),
}

/// Atomic pop-or-state: consumers that must distinguish "momentarily empty"
/// from "closed and fully drained" need both facts under ONE lock — separate
/// `try_pop` + `is_closed` calls would race an admission slipping between
/// them and drop it at shutdown.
#[derive(Debug)]
pub enum PopState<T> {
    /// an item was dequeued
    Item(T),
    /// empty but still open: more work may arrive
    Empty,
    /// closed AND empty: nothing can ever arrive again
    Drained,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded FIFO with explicit close semantics — the server's admission
/// queue (full ⇒ structured `overloaded`, closed ⇒ `shutting_down`).
pub struct BoundedQueue<T> {
    depth: usize,
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> BoundedQueue<T> {
    /// Queue admitting at most `depth` items at a time (depth >= 1).
    pub fn new(depth: usize) -> BoundedQueue<T> {
        assert!(depth >= 1, "admission queue needs depth >= 1");
        BoundedQueue {
            depth,
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// The configured admission bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner<T>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Non-blocking admission attempt.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.lock();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.depth {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        self.cv.notify_all();
        Ok(())
    }

    /// Non-blocking pop (FIFO).  Items queued before `close` stay poppable.
    pub fn try_pop(&self) -> Option<T> {
        self.lock().items.pop_front()
    }

    /// Non-blocking pop that atomically reports the drain state on empty —
    /// `Drained` is definitive: the closed flag and the emptiness are
    /// observed under the same lock, so no admitted item can be lost.
    pub fn pop_or_state(&self) -> PopState<T> {
        let mut g = self.lock();
        match g.items.pop_front() {
            Some(t) => PopState::Item(t),
            None if g.closed => PopState::Drained,
            None => PopState::Empty,
        }
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    /// True when nothing is queued right now.
    pub fn is_empty(&self) -> bool {
        self.lock().items.is_empty()
    }

    /// True once `close` has been called.
    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    /// Stop admissions; queued items drain normally.  Wakes every waiter.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    /// Block until the queue is non-empty or closed, bounded by `timeout`
    /// (so callers re-check external state on a heartbeat).
    pub fn wait_nonempty(&self, timeout: Duration) {
        let g = self.lock();
        if g.items.is_empty() && !g.closed {
            let _ = self.cv.wait_timeout(g, timeout);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_hint_is_monotone_and_clamped() {
        // empty backlog still hints one drain interval
        assert_eq!(retry_after_hint_ms(0, 64), HINT_MS_PER_QUEUED);
        // proportional to the sampled backlog...
        assert_eq!(retry_after_hint_ms(4, 64), 4 * HINT_MS_PER_QUEUED);
        let mut prev = 0;
        for q in 0..80 {
            let h = retry_after_hint_ms(q, 64);
            assert!(h >= prev, "hint not monotone at queued={q}");
            prev = h;
        }
        // ...capped by the configured depth and the absolute clamp
        assert_eq!(retry_after_hint_ms(1000, 64),
                   retry_after_hint_ms(64, 64));
        assert!(retry_after_hint_ms(usize::MAX, usize::MAX) <= HINT_MS_MAX);
    }

    #[test]
    fn fifo_and_bounds() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.depth(), 2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1));
        // a pop frees capacity immediately
        q.try_push(4).unwrap();
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(4));
        assert_eq!(q.try_pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn pop_or_state_is_atomic_about_draining() {
        let q = BoundedQueue::new(2);
        assert!(matches!(q.pop_or_state(), PopState::Empty));
        q.try_push(7).unwrap();
        q.close();
        // closed but not drained: the queued item must still come out
        match q.pop_or_state() {
            PopState::Item(7) => {}
            other => panic!("expected Item(7), got {other:?}"),
        }
        assert!(matches!(q.pop_or_state(), PopState::Drained));
    }

    #[test]
    fn close_rejects_new_but_drains_queued() {
        let q = BoundedQueue::new(4);
        q.try_push("a").unwrap();
        q.close();
        assert!(q.is_closed());
        match q.try_push("b") {
            Err(PushError::Closed("b")) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        // queued work survives the close
        assert_eq!(q.try_pop(), Some("a"));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn wait_nonempty_returns_when_closed_or_filled() {
        let q = BoundedQueue::new(1);
        q.close();
        // closed: returns without waiting out the timeout
        q.wait_nonempty(Duration::from_secs(5));

        let q = BoundedQueue::new(1);
        q.try_push(1).unwrap();
        // non-empty: immediate
        q.wait_nonempty(Duration::from_secs(5));
        assert_eq!(q.try_pop(), Some(1));
        // empty + open: bounded nap, then back to the caller
        q.wait_nonempty(Duration::from_millis(5));
    }

    #[test]
    fn cross_thread_handoff() {
        let q = std::sync::Arc::new(BoundedQueue::new(8));
        let qp = std::sync::Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                loop {
                    match qp.try_push(i) {
                        Ok(()) => break,
                        Err(PushError::Full(_)) => std::thread::yield_now(),
                        Err(PushError::Closed(_)) => panic!("closed early"),
                    }
                }
            }
            qp.close();
        });
        let mut got = Vec::new();
        loop {
            match q.try_pop() {
                Some(v) => got.push(v),
                None if q.is_closed() && q.is_empty() => break,
                None => q.wait_nonempty(Duration::from_millis(10)),
            }
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }
}
