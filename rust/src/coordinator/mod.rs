//! The experiment coordinator: one façade that wires runtime, data, trainer,
//! compression methods and evaluation together.  Every bench harness and CLI
//! subcommand drives experiments through this module, so method dispatch and
//! workload setup live in exactly one place.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::compress::baselines::{self, PruneScore};
use crate::compress::{calibrate, compress_zs, Calibration, CompressionPlan,
                      CorrectionKind, Costing, Strategy, ZsOpts};
use crate::config::ExperimentConfig;
use crate::data::{self, Corpus, World};
use crate::eval::{self, EvalReport, EvalSpec};
use crate::model::ParamStore;
use crate::runtime::session::Session;
use crate::runtime::Runtime;
use crate::serve::Engine;
use crate::trainer::{ensure_trained, TrainConfig};

/// A compression method the coordinator can dispatch (paper nomenclature).
#[derive(Clone, Debug)]
pub enum Method {
    /// plain truncated SVD
    Svd,
    /// Fisher-weighted SVD
    Fwsvd,
    /// activation-aware SVD
    Asvd,
    /// SVD-LLM (whitened truncation)
    SvdLlm,
    /// Dobi-SVD cost simulator with N optimization sweeps
    DobiSim {
        /// optimization sweeps
        sweeps: usize,
    },
    /// Dobi with remap accounting (reported as Dobi-SVD* in the paper)
    DobiSimRemap {
        /// optimization sweeps
        sweeps: usize,
    },
    /// ZS-SVD and its variants
    Zs(ZsOpts),
    /// structured pruning at one of the supported scores
    Prune(PruneScore),
    /// SliceGPT-style rotation + slicing
    SliceGpt,
}

impl Method {
    /// Table-row label (paper nomenclature).
    pub fn label(&self) -> String {
        match self {
            Method::Svd => "svd".into(),
            Method::Fwsvd => "fwsvd".into(),
            Method::Asvd => "asvd".into(),
            Method::SvdLlm => "svd-llm".into(),
            Method::DobiSim { .. } => "dobi-sim".into(),
            Method::DobiSimRemap { .. } => "dobi-sim*".into(),
            Method::Zs(o) => o.label(),
            Method::Prune(s) => match s {
                PruneScore::Magnitude => "llm-pruner".into(),
                PruneScore::WandaSp => "wanda-sp".into(),
                PruneScore::Flap => "flap".into(),
            },
            Method::SliceGpt => "slicegpt".into(),
        }
    }

    /// Convenience constructors matching the paper's table rows.
    pub fn zs(ratio: f64) -> Method {
        Method::Zs(ZsOpts::new(ratio))
    }

    /// ZS-SVD with `iters` projected-gradient correction iterations.
    pub fn zs_corrected(ratio: f64, iters: usize) -> Method {
        Method::Zs(ZsOpts { correction_iters: iters, ..ZsOpts::new(ratio) })
    }

    /// ZS-SVD under remap storage accounting (ZS-SVD* rows).
    pub fn zs_remap(ratio: f64) -> Method {
        Method::Zs(ZsOpts { costing: Costing::Remap, ..ZsOpts::new(ratio) })
    }

    /// ZS-SVD with the high-quality (†) search settings.
    pub fn zs_hq(ratio: f64) -> Method {
        Method::Zs(ZsOpts { hq: true, ..ZsOpts::new(ratio) })
    }

    /// ZS-SVD with an explicit selection strategy (ablation rows).
    pub fn zs_strategy(ratio: f64, strategy: Strategy) -> Method {
        Method::Zs(ZsOpts { strategy, ..ZsOpts::new(ratio) })
    }

    /// ZS-SVD with one correction iteration of the given kind.
    pub fn zs_correction_kind(ratio: f64, kind: CorrectionKind) -> Method {
        Method::Zs(ZsOpts { correction_iters: 1, correction_kind: kind,
                            ..ZsOpts::new(ratio) })
    }
}

/// Prepared experiment context for one model: session + pretrained weights +
/// data + calibration.
pub struct Prepared<'rt> {
    /// typed execution facade over the runtime + model config
    pub session: Session<'rt>,
    /// pretrained dense weights (checkpoint-cached)
    pub params: ParamStore,
    /// the synthetic world the corpora/tasks are generated from
    pub world: World,
    /// the family's training corpus
    pub train_corpus: Corpus,
    /// held-out eval corpora (wiki/ptb/c4 styles)
    pub eval_corpora: Vec<Corpus>,
    /// whitening moments + calibration gradients
    pub calib: Calibration,
}

/// Load/pretrain a model per `cfg` and run calibration once.
pub fn prepare<'rt>(rt: &'rt Runtime, cfg: &ExperimentConfig) -> Result<Prepared<'rt>> {
    if cfg.threads > 0 {
        crate::exec::set_threads(cfg.threads);
    }
    if cfg.no_simd {
        // bit-identical to the SIMD backend by contract (kernel_equiv.rs);
        // one-directional like the threads knob — unset leaves the
        // process-level resolution (PALLAS_NO_SIMD env / CPU detection)
        crate::linalg::kernels::force_backend(
            Some(crate::linalg::kernels::Backend::Portable));
    }
    if cfg.trace {
        // one-directional like the other knobs: config can turn tracing on
        // but never off, so a PALLAS_TRACE=1 environment survives a default
        // config.  Observe-only by contract (rust/tests/trace_equiv.rs) —
        // this cannot change any result bits.
        crate::obs::set_enabled(true);
    }
    let session = Session::new(rt, &cfg.model);
    let world = data::default_world();
    let train_corpus = data::training_corpus(&cfg.family, &world);
    let eval_corpora = data::eval_corpora(&world);
    let tc = TrainConfig {
        steps: cfg.train_steps,
        lr: cfg.train_lr as f32,
        warmup: (cfg.train_steps / 10).max(1),
        seed: cfg.seed,
        log_every: 50,
    };
    let params = ensure_trained(&session, &train_corpus, &cfg.family, &tc,
                                &cfg.ckpt_dir)?;
    let calib = calibrate(&session, &params, &train_corpus, cfg.calib_batches,
                          cfg.seed ^ 0xCA11B)?;
    Ok(Prepared { session, params, world, train_corpus, eval_corpora, calib })
}

/// A complete serving state built from a prepared context: the weights the
/// engine reads plus the target engine and optional drafter.  This is
/// exactly what `server::run` / `artifact::pack` consume, so the CLI's
/// `serve --listen` and `pack` subcommands build through one code path.
pub struct ServingBuild {
    /// weights the engine serves from (low-rank-applied when compressed)
    pub params: ParamStore,
    /// the serving (target) engine
    pub engine: Engine,
    /// optional speculative drafter engine
    pub drafter: Option<Engine>,
}

/// Build a serving state from a prepared context: the dense engine
/// (`lowrank_ratio` None) or the ZS-SVD low-rank engine at that ratio, and
/// a high-compression ZS-SVD drafter at `draft_ratio` when given.  The
/// drafter pairs with either target: the low-rank engines read only the
/// embed/norm/untargeted weights out of `params`.
pub fn build_serving(p: &Prepared, lowrank_ratio: Option<f64>,
                     draft_ratio: Option<f64>) -> Result<ServingBuild> {
    let (params, engine) = match lowrank_ratio {
        Some(ratio) => {
            let tag = format!("{}", (ratio * 100.0) as usize);
            anyhow::ensure!(p.session.cfg.lowrank.contains_key(&tag),
                            "no lowrank artifact `{tag}`");
            let plan = run_method(p, &Method::zs(ratio), ratio)?;
            let lm = p.session.cfg.lowrank.get(&tag).expect("checked above");
            let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
            (plan.apply(&p.params), engine)
        }
        None => (p.params.clone(), Engine::Dense),
    };
    let drafter = match draft_ratio {
        Some(dratio) => {
            let dtag = format!("{}", (dratio * 100.0) as usize);
            anyhow::ensure!(p.session.cfg.lowrank.contains_key(&dtag),
                            "no lowrank artifact `{dtag}` for the drafter");
            let dplan = run_method(p, &Method::zs(dratio), dratio)?;
            let dlm = p.session.cfg.lowrank.get(&dtag).expect("checked above");
            Some(Engine::from_plan_capped(&dtag, &dplan, &dlm.ranks))
        }
        None => None,
    };
    Ok(ServingBuild { params, engine, drafter })
}

/// Run one method at one ratio; returns the compression plan.
pub fn run_method(p: &Prepared, method: &Method, ratio: f64)
                  -> Result<CompressionPlan> {
    Ok(match method {
        Method::Svd => baselines::svd_plain(&p.session, &p.params, ratio),
        Method::Fwsvd => baselines::fwsvd(&p.session, &p.params, &p.calib, ratio),
        Method::Asvd => baselines::asvd(&p.session, &p.params, &p.calib, ratio, 0.5),
        Method::SvdLlm => baselines::svdllm(&p.session, &p.params, &p.calib, ratio),
        Method::DobiSim { sweeps } => {
            baselines::dobi_sim(&p.session, &p.params, &p.calib, ratio, *sweeps)?
        }
        Method::DobiSimRemap { sweeps } => {
            // remap accounting: same search, storage counted as k·max(m,n);
            // at matched footprint the retained rank is higher by
            // (m+n)/max(m,n)
            let mut plan = baselines::dobi_sim(&p.session, &p.params, &p.calib,
                                               ratio, *sweeps)?;
            remap_upgrade(&mut plan, &p.session, &p.params, &p.calib, ratio)?;
            plan
        }
        Method::Zs(opts) => {
            let o = ZsOpts { ratio, ..opts.clone() };
            compress_zs(&p.session, &p.params, &p.calib, &o)?
        }
        Method::Prune(score) => {
            baselines::prune_structured(&p.session, &p.params, &p.calib, ratio, *score)
        }
        Method::SliceGpt => {
            baselines::slicegpt_like(&p.session, &p.params, &p.calib, ratio)
        }
    })
}

/// Re-truncate a homogeneous-rank plan at the higher remap-equivalent rank
/// k' = ⌊ρ·min(m,n)⌋ (Sec. 4.4's ρ̃ parameterization).
fn remap_upgrade(plan: &mut CompressionPlan, sess: &Session, params: &ParamStore,
                 calib: &Calibration, ratio: f64) -> Result<()> {
    use crate::compress::whiten::{truncate_with_s, whitening_factor};
    for (tp, t) in plan.targets.iter_mut().zip(&sess.cfg.targets) {
        let w = params.get(&t.name).to_mat();
        let (m, n) = t.shape;
        let k = ((ratio * m.min(n) as f64) as usize).max(1);
        let (s, _) = whitening_factor(&calib.site_xx[&t.site]);
        let (rep, (wu, wv)) = truncate_with_s(&w, &s, k);
        tp.replacement = rep;
        tp.factors = Some((wu, wv));
        tp.rank = k;
        tp.stored_params = crate::compress::plan::remap_params(m, n, k);
    }
    plan.method.push('*');
    Ok(())
}

/// Evaluate a plan (or the dense baseline when `plan` is None).
pub fn evaluate_plan(p: &Prepared, plan: Option<&CompressionPlan>,
                     spec: &EvalSpec) -> Result<EvalReport> {
    let params = match plan {
        Some(pl) => pl.apply(&p.params),
        None => p.params.clone(),
    };
    eval::evaluate(&p.session, &params, &p.eval_corpora, &p.world, spec)
}

/// (method label, per-corpus PPL, per-family acc, avg, drop%) rows for a
/// set of methods at one ratio — the inner loop of Tables 1–5.
pub fn compare_methods(p: &Prepared, methods: &[Method], ratio: f64,
                       spec: &EvalSpec, baseline: &EvalReport)
                       -> Result<Vec<(String, CompressionPlan, EvalReport)>> {
    let mut rows = Vec::new();
    for m in methods {
        let plan = run_method(p, m, ratio)?;
        let report = evaluate_plan(p, Some(&plan), spec)?;
        let _ = baseline;
        rows.push((m.label(), plan, report));
    }
    Ok(rows)
}

/// Heterogeneous-rank summary of a plan, for logging.
pub fn rank_summary(plan: &CompressionPlan) -> String {
    let ranks: BTreeMap<String, usize> = plan.ranks();
    let vals: Vec<usize> = ranks.values().copied().collect();
    let min = vals.iter().min().copied().unwrap_or(0);
    let max = vals.iter().max().copied().unwrap_or(0);
    let mean = vals.iter().sum::<usize>() as f64 / vals.len().max(1) as f64;
    format!("ranks[min {min} / mean {mean:.1} / max {max}]")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels() {
        assert_eq!(Method::Svd.label(), "svd");
        assert_eq!(Method::zs(0.6).label(), "zs-svd");
        assert_eq!(Method::zs_corrected(0.6, 5).label(), "zs-svd 5x");
        assert_eq!(Method::zs_remap(0.6).label(), "zs-svd*");
        assert_eq!(Method::zs_hq(0.4).label(), "zs-svd†");
        assert_eq!(Method::Prune(PruneScore::WandaSp).label(), "wanda-sp");
    }
}
