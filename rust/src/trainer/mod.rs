//! Rust-driven pretraining: the Adam update lives inside the AOT
//! `train_step` HLO; this module owns the loop, LR schedule, logging and
//! checkpointing.  Used to produce the "pretrained" weights every
//! compression experiment starts from (DESIGN.md §2).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::data::Corpus;
use crate::model::{init, ParamStore};
use crate::runtime::session::Session;
use crate::util::rng::Rng;

/// Pretraining hyper-parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Adam steps
    pub steps: usize,
    /// peak learning rate (warmup + cosine decay)
    pub lr: f32,
    /// linear-warmup steps
    pub warmup: usize,
    /// data-order / init seed
    pub seed: u64,
    /// progress-log interval in steps
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 300, lr: 3e-3, warmup: 30, seed: 7, log_every: 25 }
    }
}

/// Warmup + cosine decay to 10% of peak.
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f32 {
    if step < cfg.warmup {
        cfg.lr * (step + 1) as f32 / cfg.warmup as f32
    } else {
        let t = (step - cfg.warmup) as f32 / (cfg.steps - cfg.warmup).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        cfg.lr * (0.1 + 0.9 * cos)
    }
}

/// Outcome of one training run.
pub struct TrainResult {
    /// the trained weights
    pub params: ParamStore,
    /// per-step training losses
    pub losses: Vec<f32>,
}

/// Train from scratch on `corpus`; returns weights + the full loss curve.
pub fn train(session: &Session, corpus: &Corpus, tc: &TrainConfig,
             quiet: bool) -> Result<TrainResult> {
    let cfg = &session.cfg;
    let mut rng = Rng::new(tc.seed);
    let mut params = init::init_params(cfg, &mut rng);
    let mut m = init::zero_state(cfg);
    let mut v = init::zero_state(cfg);
    let mut losses = Vec::with_capacity(tc.steps);
    let t0 = std::time::Instant::now();

    for step in 0..tc.steps {
        let batch = corpus.sample_batch(&mut rng, cfg.batch, cfg.seq_len);
        let lr = lr_at(tc, step);
        let loss = session.train_step(&mut params, &mut m, &mut v,
                                      step as i32, lr, &batch)?;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
        losses.push(loss);
        if !quiet && (step % tc.log_every == 0 || step + 1 == tc.steps) {
            eprintln!(
                "  step {step:4}  loss {loss:7.4}  lr {lr:.2e}  ({:.1}s)",
                t0.elapsed().as_secs_f64()
            );
        }
    }
    Ok(TrainResult { params, losses })
}

/// Checkpoint path for a (config, family, steps) triple.
pub fn ckpt_path(dir: &Path, config: &str, family: &str, steps: usize) -> PathBuf {
    dir.join(format!("ckpt_{config}_{family}_{steps}.zst0"))
}

/// Serializes checkpoint creation: several test threads (or bench sections)
/// asking for the same pretrained weights must train once, not N times.
static TRAIN_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Load a cached pretrained checkpoint or train + save one.
///
/// `family` selects the training-corpus mix ("llama", "vicuna", ...); the
/// weights, not the architecture, are what differs.
pub fn ensure_trained(session: &Session, corpus: &Corpus, family: &str,
                      tc: &TrainConfig, ckpt_dir: &Path) -> Result<ParamStore> {
    let _gate = TRAIN_GATE.lock().unwrap_or_else(|e| e.into_inner());
    std::fs::create_dir_all(ckpt_dir)?;
    let path = ckpt_path(ckpt_dir, &session.cfg.name, family, tc.steps);
    if path.exists() {
        let params = ParamStore::load(&path)?;
        if params.check_matches(&session.cfg).is_ok() {
            return Ok(params);
        }
        eprintln!("checkpoint {} stale, retraining", path.display());
    }
    eprintln!("training {} ({family}, {} steps)...", session.cfg.name, tc.steps);
    let result = train(session, corpus, tc, false)?;
    result.params.save(&path)?;
    // loss curve goes next to the checkpoint for EXPERIMENTS.md
    let curve: Vec<String> = result.losses.iter().map(|l| format!("{l:.5}")).collect();
    std::fs::write(path.with_extension("losses.txt"), curve.join("\n"))?;
    Ok(result.params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let tc = TrainConfig { steps: 100, lr: 1e-3, warmup: 10, ..Default::default() };
        assert!(lr_at(&tc, 0) < lr_at(&tc, 9));
        assert!((lr_at(&tc, 9) - 1e-3).abs() < 2e-4);
        assert!(lr_at(&tc, 99) < 2.0e-4);
        assert!(lr_at(&tc, 99) >= 1.0e-4 * 0.99);
    }

    #[test]
    fn ckpt_path_format() {
        let p = ckpt_path(Path::new("/tmp"), "tiny", "llama", 300);
        assert_eq!(p.to_str().unwrap(), "/tmp/ckpt_tiny_llama_300.zst0");
    }
}
