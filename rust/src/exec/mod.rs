//! Parallel execution substrate: a std::thread scoped worker pool with
//! deterministic fork/join primitives (no external crates, no persistent
//! threads to manage).
//!
//! Everything compute-heavy in the repo funnels through two primitives:
//!
//! * [`par_map`] — map a function over a slice, fanning contiguous index
//!   ranges out to workers and reassembling results **in input order**.
//!   Used for the embarrassingly-parallel per-target work (whitened SVD +
//!   sensitivity in `compress::pipeline::decompose_all`, plan building,
//!   the correction loop).
//! * [`par_chunks_mut`] — hand disjoint `&mut` chunks of one buffer to
//!   workers.  Used by the row-partitioned matmul kernels in
//!   `linalg::matmul`: each worker owns a contiguous band of output rows.
//!
//! # Determinism
//!
//! Parallel results are **bit-identical to the serial path for every thread
//! count**, which is what makes the serial-vs-parallel equivalence tests in
//! `rust/tests/parallel_equiv.rs` meaningful:
//!
//! * `par_map` writes each element's result to its input index — scheduling
//!   cannot reorder outputs, and element computations are independent.
//! * `par_chunks_mut` partitions the output into disjoint slices up front;
//!   workers never share a cacheline of results, and the per-element
//!   floating-point accumulation order inside a chunk is exactly the serial
//!   kernel's order (see `linalg::matmul`).
//!
//! # Thread-count knob
//!
//! Worker count resolves, in priority order:
//! 1. [`set_threads`] (wired from `config::ExperimentConfig::threads` by the
//!    coordinator and the `--threads` CLI flag),
//! 2. the `PALLAS_THREADS` environment variable,
//! 3. `std::thread::available_parallelism()`, capped at [`MAX_THREADS`].
//!
//! Nested parallelism is suppressed: a `par_map`/`par_chunks_mut` call made
//! *from inside a worker* runs serially on that worker, so parallelizing an
//! outer loop (per-target decomposition) never multiplies against the inner
//! parallel matmuls.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Upper bound on the worker count from auto-detection (explicit settings
/// may exceed it; they are clamped to [`HARD_MAX_THREADS`]).
pub const MAX_THREADS: usize = 16;

/// Absolute clamp for explicit settings — a backstop against misconfigured
/// env vars, not a tuning knob.
pub const HARD_MAX_THREADS: usize = 64;

/// 0 = "no override" (fall back to env / auto-detect).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static IN_WORKER: Cell<bool> = Cell::new(false);
}

/// Override the worker count for this process (0 restores auto-detection).
/// Takes effect on the next `par_*` call; also the hook the equivalence
/// tests use to sweep thread counts.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n.min(HARD_MAX_THREADS), Ordering::SeqCst);
}

/// Resolved worker count (>= 1).  The env/auto-detect fallback is resolved
/// once per process and cached — `threads()` sits at the top of every
/// matmul call, so it must stay a couple of atomic loads.
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        if let Ok(v) = std::env::var("PALLAS_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return n.min(HARD_MAX_THREADS);
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_THREADS)
    })
}

/// True when called from inside a pool worker (nested calls degrade to
/// serial execution instead of oversubscribing).
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// Run `f` with the current thread marked as a pool worker, so nested
/// `par_*` calls and the parallel matmul kernels stay serial.  For
/// subsystems that manage their own threads (the multi-worker serving
/// drain) to avoid workers × threads oversubscription.
pub fn with_worker_flag<R>(f: impl FnOnce() -> R) -> R {
    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            IN_WORKER.with(|w| w.set(self.0));
        }
    }
    // restore on unwind too — a caught panic must not leave the thread
    // permanently degraded to serial execution
    let _restore = Restore(IN_WORKER.with(|w| w.replace(true)));
    f()
}

/// Map `f` over `items`, in parallel when worthwhile.  `f` receives the
/// element index and a reference; results come back in input order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let nt = threads();
    if nt <= 1 || in_worker() || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let nt = nt.min(items.len());
    let chunk = items.len().div_ceil(nt);
    let f = &f;
    let mut groups: Vec<Vec<R>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(nt);
        for (ci, slab) in items.chunks(chunk).enumerate() {
            let base = ci * chunk;
            handles.push(s.spawn(move || {
                IN_WORKER.with(|w| w.set(true));
                slab.iter()
                    .enumerate()
                    .map(|(j, t)| f(base + j, t))
                    .collect::<Vec<R>>()
            }));
        }
        groups = handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .collect();
    });
    groups.into_iter().flatten().collect()
}

/// Fold `items` pairwise in fixed rounds: (0,1), (2,3), … then the same
/// over the survivors, until one remains.  The combination tree depends
/// only on `items.len()`, never on the thread count — callers fan the
/// per-item work out with [`par_map`] and reduce here, and the result is
/// identical for any momentary pool configuration (the batch-level
/// calibration fan-out in `runtime::session` relies on this).
pub fn tree_reduce<T>(mut items: Vec<T>, combine: impl Fn(&mut T, T))
                      -> Option<T> {
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                combine(&mut a, b);
            }
            next.push(a);
        }
        items = next;
    }
    items.pop()
}

/// Split `data` into consecutive chunks of `chunk_len` elements (the last
/// may be shorter) and run `f(chunk_index, chunk)` on each, in parallel.
///
/// The caller picks `chunk_len` so the chunk count roughly matches
/// [`threads`] — one worker thread is spawned per chunk.  Chunks are
/// disjoint `&mut` slices, so workers cannot race by construction.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "par_chunks_mut: zero chunk length");
    let nt = threads();
    if nt <= 1 || in_worker() || data.len() <= chunk_len {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let f = &f;
    std::thread::scope(|s| {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            s.spawn(move || {
                IN_WORKER.with(|w| w.set(true));
                f(i, c);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..103).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..103).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_matches_serial_for_all_thread_counts() {
        let items: Vec<u64> = (0..57).collect();
        let serial: Vec<u64> = items.iter().map(|&x| x.wrapping_mul(x) ^ 7).collect();
        for t in [1, 2, 3, 4, 8] {
            set_threads(t);
            let par = par_map(&items, |_, &x| x.wrapping_mul(x) ^ 7);
            assert_eq!(par, serial, "threads = {t}");
        }
        set_threads(0);
    }

    #[test]
    fn tree_reduce_is_a_fixed_pairwise_tree() {
        // strings expose the association order
        let tree = |n: usize| {
            let items: Vec<String> = (0..n).map(|i| i.to_string()).collect();
            tree_reduce(items, |a, b| *a = format!("({a}{b})"))
        };
        assert_eq!(tree(0), None);
        assert_eq!(tree(1).as_deref(), Some("0"));
        assert_eq!(tree(2).as_deref(), Some("(01)"));
        assert_eq!(tree(5).as_deref(), Some("(((01)(23))4)"));
        assert_eq!(tree(8).as_deref(), Some("(((01)(23))((45)(67)))"));
    }

    #[test]
    fn par_chunks_cover_disjointly() {
        let mut data = vec![0u32; 1000];
        set_threads(4);
        par_chunks_mut(&mut data, 250, |ci, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 250 + j) as u32;
            }
        });
        set_threads(0);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn nested_calls_run_serial() {
        // NOTE: no assert on in_worker() inside the closure — a concurrent
        // test may momentarily set_threads(1), which legitimately routes
        // par_map through the serial path on the caller thread.  What must
        // hold for ANY momentary override is the result.
        let touched = AtomicUsize::new(0);
        let items = vec![(); 8];
        set_threads(4);
        par_map(&items, |_, _| {
            let inner = par_map(&[1u8, 2, 3], |_, &x| x as usize);
            touched.fetch_add(inner.iter().sum::<usize>(), Ordering::SeqCst);
        });
        set_threads(0);
        assert_eq!(touched.load(Ordering::SeqCst), 8 * 6);
        assert!(!in_worker());
    }

    #[test]
    fn with_worker_flag_scopes_the_flag() {
        assert!(!in_worker());
        let seen = with_worker_flag(|| in_worker());
        assert!(seen);
        assert!(!in_worker());
    }

    #[test]
    fn threads_always_at_least_one() {
        // NOTE: no strict equality on the override here — unit tests in this
        // binary run concurrently and several sweep `set_threads`; every
        // `par_*` caller is required to be correct for ANY momentary value.
        assert!(threads() >= 1);
        assert!(threads() <= HARD_MAX_THREADS);
    }
}
