//! Parallel execution substrate: a **persistent** std::thread worker pool
//! with deterministic fork/join primitives (no external crates).
//!
//! Everything compute-heavy in the repo funnels through two primitives:
//!
//! * [`par_map`] — map a function over a slice, fanning contiguous index
//!   ranges out to workers and reassembling results **in input order**.
//!   Used for the embarrassingly-parallel per-target work (whitened SVD +
//!   sensitivity in `compress::pipeline::decompose_all`, plan building,
//!   the correction loop, the calibration batch fan-out).
//! * [`par_chunks_mut`] — hand disjoint `&mut` chunks of one buffer to
//!   workers.  Used by the row-partitioned matmul kernels in
//!   `linalg::matmul`: each worker owns a contiguous band of output rows.
//!   The decode scheduler reaches the pool through those kernels — its
//!   per-iteration batched step/prefill GEMMs (`runtime::native::
//!   decode_batch`) stack all live slots' rows into one product, and the
//!   pool splits that product's output rows into bands.
//!
//! # Persistent pool
//!
//! Both primitives execute on one process-lifetime worker pool (lazily
//! spawned on first parallel call) instead of spawning fresh scoped threads
//! per call.  That amortizes thread start-up across the per-token scheduler
//! iterations and the per-matmul fan-outs the ROADMAP flagged: a `par_*`
//! call now costs one queue lock + condvar wake, not N `clone(2)`s.  Work
//! is submitted as boxed jobs with a completion latch; the submitting
//! thread blocks until every job has run, which is what makes the borrowed
//! (non-`'static`) closures sound — see `run_jobs`.  Worker panics are
//! caught, the pool survives, and the panic is re-raised on the submitting
//! thread (same observable behavior as the old scoped join).
//!
//! # Determinism
//!
//! Parallel results are **bit-identical to the serial path for every thread
//! count**, which is what makes the serial-vs-parallel equivalence tests in
//! `rust/tests/parallel_equiv.rs` meaningful:
//!
//! * `par_map` writes each element's result to its input index — scheduling
//!   cannot reorder outputs, and element computations are independent.
//! * `par_chunks_mut` partitions the output into disjoint slices up front;
//!   workers never share results, and the per-element floating-point
//!   accumulation order inside a chunk is exactly the serial kernel's order
//!   (see `linalg::matmul`).
//!
//! # Thread-count knob
//!
//! Worker count resolves, in priority order:
//! 1. [`set_threads`] (wired from `config::ExperimentConfig::threads` by the
//!    coordinator and the `--threads` CLI flag),
//! 2. the `PALLAS_THREADS` environment variable,
//! 3. `std::thread::available_parallelism()`, capped at [`MAX_THREADS`].
//!
//! The pool itself is sized once, at first use, to the larger of the
//! resolved count and the detected parallelism (capped at [`MAX_THREADS`]);
//! later `set_threads` calls change how much work each `par_*` call
//! *submits*, not the pool size — excess chunks simply queue.
//!
//! Nested parallelism is suppressed: a `par_map`/`par_chunks_mut` call made
//! *from inside a worker* runs serially on that worker, so parallelizing an
//! outer loop (per-target decomposition) never multiplies against the inner
//! parallel matmuls — and, as a corollary, pool workers never submit (and
//! never block on) pool jobs, so waiting for a latch cannot deadlock.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Upper bound on the worker count from auto-detection, and on the size of
/// the persistent pool (explicit settings may exceed it for *submission*
/// granularity; they are clamped to [`HARD_MAX_THREADS`]).
pub const MAX_THREADS: usize = 16;

/// Absolute clamp for explicit settings — a backstop against misconfigured
/// env vars, not a tuning knob.
pub const HARD_MAX_THREADS: usize = 64;

/// 0 = "no override" (fall back to env / auto-detect).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static IN_WORKER: Cell<bool> = Cell::new(false);
}

/// Override the worker count for this process (0 restores auto-detection).
/// Takes effect on the next `par_*` call; also the hook the equivalence
/// tests use to sweep thread counts.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n.min(HARD_MAX_THREADS), Ordering::SeqCst);
}

/// Resolved worker count (>= 1).  The env/auto-detect fallback is resolved
/// once per process and cached — `threads()` sits at the top of every
/// matmul call, so it must stay a couple of atomic loads.
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        if let Ok(v) = std::env::var("PALLAS_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return n.min(HARD_MAX_THREADS);
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_THREADS)
    })
}

/// True when called from inside a pool worker (nested calls degrade to
/// serial execution instead of oversubscribing).
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// Run `f` with the current thread marked as a pool worker, so nested
/// `par_*` calls and the parallel matmul kernels stay serial.  For
/// subsystems that manage their own threads (the multi-worker serving
/// drain) to avoid workers × threads oversubscription.
pub fn with_worker_flag<R>(f: impl FnOnce() -> R) -> R {
    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            IN_WORKER.with(|w| w.set(self.0));
        }
    }
    // restore on unwind too — a caught panic must not leave the thread
    // permanently degraded to serial execution
    let _restore = Restore(IN_WORKER.with(|w| w.replace(true)));
    f()
}

// ---------------------------------------------------------------------------
// persistent worker pool
// ---------------------------------------------------------------------------

/// A queued unit of work.  Always the wrapper built in `run_jobs` (which
/// catches panics and counts down a latch), never a raw caller closure.
type Job = Box<dyn FnOnce() + Send>;

struct JobQueue {
    jobs: Mutex<VecDeque<Job>>,
    available: Condvar,
}

struct Pool {
    q: Arc<JobQueue>,
    /// worker threads alive (fixed after spawn; informational)
    size: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let auto = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let size = threads().max(auto).clamp(1, MAX_THREADS);
        let q = Arc::new(JobQueue {
            jobs: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
        });
        for i in 0..size {
            let q = Arc::clone(&q);
            std::thread::Builder::new()
                .name(format!("pallas-pool-{i}"))
                .spawn(move || {
                    IN_WORKER.with(|w| w.set(true));
                    loop {
                        let job = {
                            let mut jobs =
                                q.jobs.lock().unwrap_or_else(|e| e.into_inner());
                            loop {
                                if let Some(j) = jobs.pop_front() {
                                    break j;
                                }
                                jobs = q
                                    .available
                                    .wait(jobs)
                                    .unwrap_or_else(|e| e.into_inner());
                            }
                        };
                        // jobs are panic-catching wrappers; nothing unwinds
                        // through here and the worker lives forever
                        job();
                    }
                })
                .expect("spawn pool worker");
        }
        Pool { q, size }
    })
}

/// Number of threads in the persistent pool (0 if it has not been spawned
/// yet).  Diagnostic only.
pub fn pool_size() -> usize {
    POOL.get().map(|p| p.size).unwrap_or(0)
}

/// Execute `jobs` on the persistent pool and block until every one has
/// finished.  Job panics are caught (workers survive) and the first one is
/// re-raised here after all jobs complete.
///
/// # Safety of the lifetime erasure
///
/// Jobs may borrow caller state (`'a`), yet the queue stores `'static`
/// boxes.  This is sound because this function does not return until the
/// completion latch reports every job done — the borrows outlive every
/// job's execution.  Callers must not be pool workers (all callers guard
/// with [`in_worker`]), so blocking on the latch cannot starve the queue.
fn run_jobs<'a>(jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
    let n = jobs.len();
    if n == 0 {
        return;
    }
    if n == 1 || in_worker() {
        for j in jobs {
            j();
        }
        return;
    }
    // observability: record the fan-out shape (batch count + size
    // distribution) and span the submit→drain window.  Observe-only — the
    // hooks read clocks and counters, never the queue — and one relaxed
    // atomic load each when tracing is off.
    crate::obs::counter_add("exec.job_batches", 1);
    crate::obs::counter_add("exec.jobs", n as u64);
    crate::obs::histo_record("exec.batch_jobs", n as u64);
    let _sp = crate::obs::span("run_jobs", "exec")
        .arg("jobs", crate::util::json::Json::num(n as f64))
        .arg("pool", crate::util::json::Json::num(pool_size() as f64));
    let p = pool();
    let done = Arc::new((Mutex::new(0usize), Condvar::new()));
    type Panic = Box<dyn std::any::Any + Send + 'static>;
    let panic: Arc<Mutex<Option<Panic>>> = Arc::new(Mutex::new(None));
    {
        let mut q = p.q.jobs.lock().unwrap_or_else(|e| e.into_inner());
        // backlog already queued ahead of this batch — nonzero means the
        // pool is saturated and fan-outs are stacking up
        crate::obs::histo_record("exec.queue_backlog", q.len() as u64);
        for job in jobs {
            // SAFETY: see function docs — we block on `done` below until
            // every job has executed, so the 'a borrows stay valid for the
            // whole execution of `job`.
            let job: Box<dyn FnOnce() + Send + 'static> =
                unsafe { std::mem::transmute(job) };
            let done = Arc::clone(&done);
            let panic = Arc::clone(&panic);
            q.push_back(Box::new(move || {
                let r = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(job));
                if let Err(e) = r {
                    let mut slot =
                        panic.lock().unwrap_or_else(|p| p.into_inner());
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                }
                let (count, cv) = &*done;
                let mut c = count.lock().unwrap_or_else(|p| p.into_inner());
                *c += 1;
                cv.notify_all();
            }));
        }
        p.q.available.notify_all();
    }
    let (count, cv) = &*done;
    let mut c = count.lock().unwrap_or_else(|p| p.into_inner());
    while *c < n {
        c = cv.wait(c).unwrap_or_else(|p| p.into_inner());
    }
    drop(c);
    let first = panic.lock().unwrap_or_else(|p| p.into_inner()).take();
    if let Some(e) = first {
        std::panic::resume_unwind(e);
    }
}

// ---------------------------------------------------------------------------
// fork/join primitives
// ---------------------------------------------------------------------------

/// Map `f` over `items`, in parallel when worthwhile.  `f` receives the
/// element index and a reference; results come back in input order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let nt = threads();
    if nt <= 1 || in_worker() || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let nt = nt.min(items.len());
    let chunk = items.len().div_ceil(nt);
    let n_chunks = items.len().div_ceil(chunk);
    // one output slot per chunk, written exactly once by its job
    let slots: Vec<Mutex<Vec<R>>> =
        (0..n_chunks).map(|_| Mutex::new(Vec::new())).collect();
    {
        let f = &f;
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(n_chunks);
        for (ci, slab) in items.chunks(chunk).enumerate() {
            let slot = &slots[ci];
            jobs.push(Box::new(move || {
                let base = ci * chunk;
                let out: Vec<R> = slab
                    .iter()
                    .enumerate()
                    .map(|(j, t)| f(base + j, t))
                    .collect();
                *slot.lock().unwrap_or_else(|e| e.into_inner()) = out;
            }));
        }
        run_jobs(jobs);
    }
    slots
        .into_iter()
        .flat_map(|m| m.into_inner().unwrap_or_else(|e| e.into_inner()))
        .collect()
}

/// Fold `items` pairwise in fixed rounds: (0,1), (2,3), … then the same
/// over the survivors, until one remains.  The combination tree depends
/// only on `items.len()`, never on the thread count — callers fan the
/// per-item work out with [`par_map`] and reduce here, and the result is
/// identical for any momentary pool configuration (the batch-level
/// calibration fan-out in `runtime::session` relies on this).
pub fn tree_reduce<T>(mut items: Vec<T>, combine: impl Fn(&mut T, T))
                      -> Option<T> {
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                combine(&mut a, b);
            }
            next.push(a);
        }
        items = next;
    }
    items.pop()
}

/// Split `data` into consecutive chunks of `chunk_len` elements (the last
/// may be shorter) and run `f(chunk_index, chunk)` on each, in parallel on
/// the persistent pool.
///
/// The caller picks `chunk_len` so the chunk count roughly matches
/// [`threads`].  Chunks are disjoint `&mut` slices, so workers cannot race
/// by construction; chunks beyond the pool size queue and drain.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "par_chunks_mut: zero chunk length");
    let nt = threads();
    if nt <= 1 || in_worker() || data.len() <= chunk_len {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let f = &f;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (i, c) in data.chunks_mut(chunk_len).enumerate() {
        jobs.push(Box::new(move || f(i, c)));
    }
    run_jobs(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..103).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..103).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_matches_serial_for_all_thread_counts() {
        let items: Vec<u64> = (0..57).collect();
        let serial: Vec<u64> = items.iter().map(|&x| x.wrapping_mul(x) ^ 7).collect();
        for t in [1, 2, 3, 4, 8] {
            set_threads(t);
            let par = par_map(&items, |_, &x| x.wrapping_mul(x) ^ 7);
            assert_eq!(par, serial, "threads = {t}");
        }
        set_threads(0);
    }

    #[test]
    fn pool_survives_many_rounds() {
        // repeated fan-outs reuse the same persistent workers; results stay
        // exact across rounds and momentary thread-count changes
        for round in 0..20u64 {
            set_threads(2 + (round as usize % 3));
            let items: Vec<u64> = (0..41).map(|i| i + round).collect();
            let out = par_map(&items, |_, &x| x * 3);
            assert_eq!(out, items.iter().map(|&x| x * 3).collect::<Vec<_>>());
        }
        set_threads(0);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        set_threads(4);
        let items = vec![0usize, 1, 2, 3, 4, 5, 6, 7];
        let r = std::panic::catch_unwind(|| {
            par_map(&items, |_, &x| {
                if x == 5 {
                    panic!("boom");
                }
                x
            })
        });
        assert!(r.is_err(), "panic must reach the caller");
        // the pool still works after a job panicked
        let ok = par_map(&items, |_, &x| x + 1);
        assert_eq!(ok, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        set_threads(0);
    }

    #[test]
    fn tree_reduce_is_a_fixed_pairwise_tree() {
        // strings expose the association order
        let tree = |n: usize| {
            let items: Vec<String> = (0..n).map(|i| i.to_string()).collect();
            tree_reduce(items, |a, b| *a = format!("({a}{b})"))
        };
        assert_eq!(tree(0), None);
        assert_eq!(tree(1).as_deref(), Some("0"));
        assert_eq!(tree(2).as_deref(), Some("(01)"));
        assert_eq!(tree(5).as_deref(), Some("(((01)(23))4)"));
        assert_eq!(tree(8).as_deref(), Some("(((01)(23))((45)(67)))"));
    }

    #[test]
    fn par_chunks_cover_disjointly() {
        let mut data = vec![0u32; 1000];
        set_threads(4);
        par_chunks_mut(&mut data, 250, |ci, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 250 + j) as u32;
            }
        });
        set_threads(0);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn more_chunks_than_workers_all_run() {
        // submission granularity may exceed the pool size; every chunk must
        // still execute exactly once
        let mut data = vec![0u8; 64];
        set_threads(4);
        par_chunks_mut(&mut data, 1, |_, c| c[0] += 1);
        set_threads(0);
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn nested_calls_run_serial() {
        // NOTE: no assert on in_worker() inside the closure — a concurrent
        // test may momentarily set_threads(1), which legitimately routes
        // par_map through the serial path on the caller thread.  What must
        // hold for ANY momentary override is the result.
        let touched = AtomicUsize::new(0);
        let items = vec![(); 8];
        set_threads(4);
        par_map(&items, |_, _| {
            let inner = par_map(&[1u8, 2, 3], |_, &x| x as usize);
            touched.fetch_add(inner.iter().sum::<usize>(), Ordering::SeqCst);
        });
        set_threads(0);
        assert_eq!(touched.load(Ordering::SeqCst), 8 * 6);
        assert!(!in_worker());
    }

    #[test]
    fn with_worker_flag_scopes_the_flag() {
        assert!(!in_worker());
        let seen = with_worker_flag(|| in_worker());
        assert!(seen);
        assert!(!in_worker());
    }

    #[test]
    fn threads_always_at_least_one() {
        // NOTE: no strict equality on the override here — unit tests in this
        // binary run concurrently and several sweep `set_threads`; every
        // `par_*` caller is required to be correct for ANY momentary value.
        assert!(threads() >= 1);
        assert!(threads() <= HARD_MAX_THREADS);
    }
}
