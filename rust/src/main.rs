//! `zs-svd` — the L3 leader binary.
//!
//! Subcommands:
//!   info                     artifact/manifest summary
//!   train                    pretrain a model (checkpoint-cached)
//!   eval                     evaluate dense or compressed weights
//!   compress                 run one method at one ratio, report + save
//!   sweep                    methods × ratios comparison table
//!   serve                    batched serving benchmark (dense vs low-rank);
//!                            `--decode` switches to KV-cached generation
//!                            under continuous batching (`--slots`,
//!                            `--max-new-tokens`, `--temperature`,
//!                            `--prefill-chunk` prompt tokens ingested per
//!                            scheduler iteration, 0 = whole prompt);
//!                            `--listen <addr>` starts the network server
//!                            (streaming TCP front-end; `--plan` serves the
//!                            ZS-SVD low-rank engine, `--queue-depth` bounds
//!                            admission, `--port-file` writes the bound
//!                            address for scripts); `--speculate-k K`
//!                            enables speculative self-decode — a
//!                            high-compression ZS-SVD drafter (ratio
//!                            `--draft-ratio`, default 0.4) proposes up to
//!                            K tokens per slot which the serving engine
//!                            verifies in one batched call; greedy output
//!                            is bit-identical for every K;
//!                            `--prefix-cache BLOCKS` enables the
//!                            prefix-sharing KV cache (repeated prompts
//!                            skip prefill for their cached block-aligned
//!                            prefix, bit-identically) and `--kv-block N`
//!                            sets the paged-block granularity;
//!                            `--artifact PATH` serves straight from a
//!                            packed artifact manifest (no training or
//!                            compression at startup) — such a server
//!                            accepts live `reload` hot-swaps
//!   pack                     compress + pack a complete serving state
//!                            (params, engine, optional drafter) into the
//!                            content-addressed artifact store (`--out DIR`,
//!                            `--name NAME`, `--dense` for the dense
//!                            engine, `--ratio` for ZS-SVD, `--draft-ratio`
//!                            to include a speculative drafter)
//!   install                  copy + verify a packed artifact into another
//!                            store (`--from MANIFEST`, `--to DIR`,
//!                            `--name NAME`); resumable, atomic, and
//!                            every chunk is checksum-verified before the
//!                            manifest commits
//!   router                   supervised multi-worker fleet: spawn
//!                            `--workers N` worker processes (each a full
//!                            `serve --artifact` engine), health-check and
//!                            restart them, and serve the single-server
//!                            wire protocol on `--listen <addr>`
//!                            (`--artifact M` or `M1,M2,..` per-worker
//!                            stores, `--router-depth` / `--worker-depth`
//!                            two-level admission, `--port-file`; worker
//!                            flags `--threads` / `--slots` /
//!                            `--max-new-tokens` / `--temperature` /
//!                            `--prefill-chunk` / `--speculate-k` /
//!                            `--draft-ratio` / `--kv-block` /
//!                            `--prefix-cache` / `--queue-depth` /
//!                            `--model` / `--no-simd` pass through)
//!   client                   drive a running server over TCP
//!                            (`--connect <addr>`, `--requests`,
//!                            `--prompt-len`, `--max-new-tokens`,
//!                            `--retries K` to retry `overloaded` /
//!                            transient-transport rejections with jittered
//!                            exponential back-off,
//!                            `--reload PATH` to hot-swap the server onto
//!                            a packed artifact before generating,
//!                            `--shutdown` to drain the server afterwards)
//!   trace                    validate a trace/report file produced by
//!                            `--trace-out` or `compress --report`
//!                            (positional: the file path)
//!
//! Flags shared by every experiment subcommand: `--threads N` sizes the
//! `exec` worker pool, and `--no-simd` forces the portable kernel backend
//! (bit-identical to the SIMD one — a debugging/CI knob, never a results
//! knob; see `linalg::kernels`).  `--trace` (or the `PALLAS_TRACE` env
//! var) turns on the observability layer (`zs_svd::obs`), and
//! `--trace-out FILE` additionally writes a chrome://tracing JSON on exit
//! — open it in Perfetto.  `compress --report FILE` writes the per-matrix
//! ZS-SVD selection report (rank, predicted ΔL, zero-sum trajectory).
//! Tracing is observe-only: outputs are bit-identical with it on or off.

use std::path::{Path, PathBuf};

use anyhow::Result;

use zs_svd::artifact;
use zs_svd::compress::baselines::PruneScore;
use zs_svd::config::ExperimentConfig;
use zs_svd::coordinator::{self, Method};
use zs_svd::decode::{run_decode, run_decode_speculative, synth_requests,
                     DecodeConfig, EngineSlot};
use zs_svd::eval::EvalSpec;
use zs_svd::report::{acc2, f2, latency_cells, mb, pct, Table,
                     LATENCY_HEADERS};
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::{run_serving, Engine, ServeConfig};
use zs_svd::fleet;
use zs_svd::server::{self, GenerateOutcome, GenerateReq, ReloadOutcome,
                     RetryPolicy};
use zs_svd::util::cli::Args;

fn parse_method(name: &str, ratio: f64) -> Method {
    match name {
        "svd" => Method::Svd,
        "fwsvd" => Method::Fwsvd,
        "asvd" => Method::Asvd,
        "svd-llm" | "svdllm" => Method::SvdLlm,
        "dobi" | "dobi-sim" => Method::DobiSim { sweeps: 2 },
        "dobi*" => Method::DobiSimRemap { sweeps: 2 },
        "zs-svd" | "zs" => Method::zs(ratio),
        "zs-1x" => Method::zs_corrected(ratio, 1),
        "zs-5x" => Method::zs_corrected(ratio, 5),
        "zs-10x" => Method::zs_corrected(ratio, 10),
        "zs*" | "zs-remap" => Method::zs_remap(ratio),
        "zs-hq" => Method::zs_hq(ratio),
        "llm-pruner" | "magnitude" => Method::Prune(PruneScore::Magnitude),
        "wanda-sp" => Method::Prune(PruneScore::WandaSp),
        "flap" => Method::Prune(PruneScore::Flap),
        "slicegpt" => Method::SliceGpt,
        other => panic!("unknown method `{other}`"),
    }
}

fn exp_config(args: &Args) -> ExperimentConfig {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))
            .expect("config file"),
        None => ExperimentConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(f) = args.get("family") {
        cfg.family = f.to_string();
    }
    cfg.train_steps = args.usize_or("steps", cfg.train_steps);
    cfg.calib_batches = args.usize_or("calib-batches", cfg.calib_batches);
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.threads = args.usize_or("threads", cfg.threads);
    cfg.no_simd = cfg.no_simd || args.flag("no-simd");
    // `--trace-out FILE` implies tracing: a chrome-trace with no events
    // would only mislead
    cfg.trace = cfg.trace || args.flag("trace")
        || args.get("trace-out").is_some();
    if args.flag("fast") {
        cfg = cfg.shrunk();
    }
    cfg
}

/// Write the chrome://tracing JSON when `--trace-out FILE` was given.
/// Runs after the subcommand's work, so the event ring holds the run.
fn write_trace_out(args: &Args) -> Result<()> {
    if let Some(out) = args.get("trace-out") {
        zs_svd::obs::write_chrome_trace(std::path::Path::new(out))?;
        println!("wrote chrome trace to {out} (open in Perfetto / \
                  chrome://tracing)");
    }
    Ok(())
}

fn eval_spec(args: &Args, cfg: &ExperimentConfig) -> EvalSpec {
    EvalSpec {
        ppl_batches: args.usize_or("ppl-batches", cfg.ppl_batches),
        instances_per_family: args.usize_or("instances", cfg.instances_per_family),
        task_seed: 0xE1,
    }
}

/// `serve --listen <addr>`: the network server, blocking until a protocol
/// `shutdown` drains it.  The serving state is either built in-process
/// (dense, or `--plan` low-rank) or loaded from a packed artifact
/// (`--artifact PATH` / `cfg.artifact`); either way the server owns it and
/// accepts live `reload` hot-swaps.
fn serve_listen(rt: &Runtime, args: &Args, cfg: &ExperimentConfig,
                listen: &str) -> Result<()> {
    let spec_k = args.usize_or("speculate-k", cfg.speculate_k);
    let artifact_path = args.get("artifact").map(str::to_string)
        .or_else(|| (!cfg.artifact.is_empty()).then(|| cfg.artifact.clone()));

    if let Some(art) = artifact_path {
        // no training / compression at startup — but the execution knobs
        // coordinator::prepare would normally apply still matter
        if cfg.threads > 0 {
            zs_svd::exec::set_threads(cfg.threads);
        }
        if cfg.no_simd {
            zs_svd::linalg::kernels::force_backend(
                Some(zs_svd::linalg::kernels::Backend::Portable));
        }
        if cfg.trace {
            zs_svd::obs::set_enabled(true);
        }
        let bundle = artifact::load(Path::new(&art))?;
        anyhow::ensure!(rt.manifest.configs.contains_key(&bundle.model),
                        "artifact `{art}` is packed for unknown model \
                         config `{}`", bundle.model);
        let session = Session::new(rt, &bundle.model);
        bundle.validate_against(&session.cfg)?;
        println!("loaded artifact {art} (model {})", bundle.model);
        let slot = EngineSlot { params: bundle.params, engine: bundle.engine,
                               drafter: bundle.drafter };
        serve_with_slot(&session, slot, args, cfg, listen, spec_k)
    } else {
        let p = coordinator::prepare(rt, cfg)?;
        let lowrank = if args.flag("plan") {
            Some(args.f64_or("ratio", 0.6))
        } else {
            None
        };
        // the drafter is a high-compression ZS-SVD engine over the SAME
        // param store the target serves from: the low-rank engine reads
        // only the embed/norm/untargeted weights out of `params`, so the
        // pairing is valid for both the dense and the `--plan` target
        let draft = if spec_k > 0 {
            Some(args.f64_or("draft-ratio", 0.4))
        } else {
            None
        };
        let sb = coordinator::build_serving(&p, lowrank, draft)?;
        let slot = EngineSlot { params: sb.params, engine: sb.engine,
                               drafter: sb.drafter };
        serve_with_slot(&p.session, slot, args, cfg, listen, spec_k)
    }
}

/// The common tail of `serve --listen`: configure, run the hot-swappable
/// server on an owned slot, and print the session table.
fn serve_with_slot(session: &Session, slot: EngineSlot, args: &Args,
                   cfg: &ExperimentConfig, listen: &str, spec_k: usize)
                   -> Result<()> {
    let scfg = server::ServerConfig {
        addr: listen.to_string(),
        queue_depth: args.usize_or("queue-depth", cfg.queue_depth),
        decode: DecodeConfig {
            max_slots: args.usize_or("slots", cfg.decode_slots),
            max_new_tokens: args.usize_or("max-new-tokens", cfg.max_new_tokens),
            temperature: args.f64_or("temperature", 0.0) as f32,
            seed: cfg.seed,
            arrival_steps: 0.0,
            prefill_chunk: args.usize_or("prefill-chunk", cfg.prefill_chunk),
            speculate_k: spec_k,
            kv_block: args.usize_or("kv-block", cfg.kv_block),
            prefix_cache_blocks: args.usize_or("prefix-cache",
                                               cfg.prefix_cache_blocks),
        },
    };
    let port_file = args.get("port-file").map(|s| s.to_string());
    println!("serving {} engine on {listen} (slots {}, queue depth {}{})",
             slot.engine.label(), scfg.decode.max_slots, scfg.queue_depth,
             match &slot.drafter {
                 Some(d) => format!(", drafter {} k={spec_k}", d.label()),
                 None => String::new(),
             });

    let stats = server::run_swappable(session, slot, &scfg, |addr| {
        println!("listening on {addr}");
        if let Some(pf) = &port_file {
            if let Err(e) = std::fs::write(pf, addr.to_string()) {
                eprintln!("warn: could not write port file {pf}: {e}");
            }
        }
    })?;

    let mut t = Table::new(
        &format!("server session ({})", stats.engine),
        &["metric", "value"],
    );
    t.row(vec!["connections".into(), format!("{}", stats.connections)]);
    t.row(vec!["admitted".into(), format!("{}", stats.requests_admitted)]);
    t.row(vec!["rejected".into(), format!("{}", stats.requests_rejected)]);
    t.row(vec!["completed".into(),
               format!("{}", stats.counters.requests_completed)]);
    t.row(vec!["decode tokens".into(),
               format!("{}", stats.counters.decode_tokens)]);
    t.row(vec!["prefill tok/s".into(),
               f2(stats.counters.prefill_tok_per_sec())]);
    t.row(vec!["decode tok/s".into(),
               f2(stats.counters.decode_tok_per_sec())]);
    if stats.counters.drafted_tokens > 0 {
        t.row(vec!["drafted tokens".into(),
                   format!("{}", stats.counters.drafted_tokens)]);
        t.row(vec!["draft acceptance".into(),
                   format!("{:.1}%",
                           stats.counters.draft_acceptance_rate() * 100.0)]);
    }
    if stats.counters.plan_swaps > 0 {
        t.row(vec!["plan swaps".into(),
                   format!("{}", stats.counters.plan_swaps)]);
    }
    for (h, v) in LATENCY_HEADERS.iter().zip(latency_cells(&stats.e2e)) {
        t.row(vec![format!("e2e {h}"), v]);
    }
    for (h, v) in LATENCY_HEADERS.iter().zip(latency_cells(&stats.token_gap)) {
        t.row(vec![format!("token {h}"), v]);
    }
    print!("{}", t.to_ascii());
    write_trace_out(args)?;
    Ok(())
}

/// `client --connect <addr>`: scripted session against a running server.
fn client_session(args: &Args, rt: &Runtime) -> Result<()> {
    let addr = args.str_or("connect", "127.0.0.1:8650");
    let n = args.usize_or("requests", 2);
    let plen = args.usize_or("prompt-len", 8).max(1);
    let max_new = args.usize_or("max-new-tokens", 4);
    // prompts must fit the SERVER's vocabulary: derive it from the same
    // manifest config the server loads (`--model` must match its setting)
    let model = args.str_or("model", "tiny");
    let vocab = rt
        .manifest
        .configs
        .get(&model)
        .map(|c| c.vocab)
        .unwrap_or(256)
        .max(2);
    let mut c = server::Client::connect(addr.as_str())?;
    if let Some(art) = args.get("reload") {
        // hot-swap the server BEFORE generating, so this session's token
        // lines reflect the reloaded plan (ci.sh diffs them against a
        // session on the un-swapped server to gate swap invariance)
        match c.reload(art)? {
            ReloadOutcome::Swapped { engine, .. } => {
                println!("reloaded artifact: now serving {engine}");
            }
            ReloadOutcome::Rejected { code, message } => {
                anyhow::bail!("reload rejected: {code} ({message})");
            }
        }
    }
    let retries = args.usize_or("retries", 0) as u32;
    let policy = RetryPolicy { retries, ..RetryPolicy::default() };
    for i in 0..n {
        let prompt = server::scripted_prompt(i, plen, vocab);
        let g = GenerateReq { id: i as u64, prompt, max_new_tokens: max_new,
                              temperature: None, seed: None };
        // with `--retries K`, each request rides its own connection so a
        // retryable rejection (overloaded, worker_failed, transport drop)
        // can reconnect and back off; without it, reuse the session conn
        let outcome = if retries > 0 {
            server::generate_with_retries(addr.as_str(), &g, &policy)?
        } else {
            c.run_generate(&g)?
        };
        match outcome {
            GenerateOutcome::Done(r) => {
                println!(
                    "request {i}: {} tokens streamed, queue {:.1} ms, \
                     prefill {:.1} ms, decode {:.1} ms, ttft {:.1} ms, \
                     e2e {:.1} ms{}{}",
                    r.tokens.len(), r.queue_ms, r.prefill_ms, r.decode_ms,
                    r.ttft_ms, r.latency_ms,
                    if r.cached_prompt_tokens > 0 {
                        format!(" ({} prompt tokens from prefix cache)",
                                r.cached_prompt_tokens)
                    } else {
                        String::new()
                    },
                    if r.truncated { " (truncated at KV capacity)" }
                    else { "" });
                // the generated ids themselves, so scripted sessions (ci.sh)
                // can diff two runs for bit-identity from the outside
                println!("request {i} tokens: {:?}", r.tokens);
            }
            GenerateOutcome::Rejected { code, message, retry_after_ms } => {
                anyhow::bail!(
                    "request {i} rejected: {code} ({message}){}",
                    match retry_after_ms {
                        Some(ms) => format!(" [server hinted retry in \
                                             {ms} ms]"),
                        None => String::new(),
                    });
            }
        }
    }
    let snap = c.metrics()?;
    let cached = snap.get("counters")
        .map(|c| c.usize_or("cached_prompt_tokens", 0))
        .unwrap_or(0);
    println!("server metrics: {} tok/s over uptime, queue depth {}, \
              uptime {:.1}s, {cached} prompt tokens served from prefix \
              cache",
             f2(snap.f64_or("uptime_tok_per_sec", 0.0)),
             snap.usize_or("queue_depth", 0),
             snap.f64_or("uptime_secs", 0.0));
    let swaps = snap.get("counters")
        .map(|c| c.usize_or("artifact.swaps", 0))
        .unwrap_or(0);
    println!("artifact swaps: {swaps}");
    // a fleet router's snapshot carries a `workers` array; print it so
    // scripts (ci.sh) can grep worker pids, health, and restart counts
    if let Some(workers) = snap.get("workers").and_then(|w| w.as_arr()) {
        for w in workers {
            println!(
                "fleet worker {}: pid {} healthy {} restarts {} \
                 inflight {} routed {} engine {}",
                w.usize_or("index", 0), w.usize_or("pid", 0),
                w.bool_or("healthy", false), w.usize_or("restarts", 0),
                w.usize_or("inflight", 0), w.usize_or("routed_total", 0),
                w.str_or("engine", "?"));
        }
        let restarts = snap.get("counters")
            .map(|c| c.usize_or("fleet.worker_restarts", 0))
            .unwrap_or(0);
        println!("fleet worker restarts: {restarts}");
    }
    if args.flag("shutdown") {
        c.shutdown_server()?;
        println!("server acknowledged shutdown");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let cmd = args.subcommand.clone().unwrap_or_else(|| "info".into());
    let rt = Runtime::load_default()?;

    match cmd.as_str() {
        "info" => {
            println!("artifacts: {}", Runtime::default_dir().display());
            for (name, c) in &rt.manifest.configs {
                println!(
                    "  {name:10} arch={:6} d={} L={} ff={} seq={} batch={} \
                     params={} targets={}",
                    c.arch, c.d_model, c.n_layers, c.d_ff, c.seq_len, c.batch,
                    c.param_count(), c.targets.len()
                );
            }
        }

        "train" => {
            let cfg = exp_config(&args);
            let p = coordinator::prepare(&rt, &cfg)?;
            println!("trained {} ({}, {} steps); calib loss {:.4}",
                     cfg.model, cfg.family, cfg.train_steps, p.calib.base_loss);
        }

        "eval" => {
            let cfg = exp_config(&args);
            let p = coordinator::prepare(&rt, &cfg)?;
            let spec = eval_spec(&args, &cfg);
            let report = coordinator::evaluate_plan(&p, None, &spec)?;
            let mut t = Table::new(
                &format!("dense {} ({})", cfg.model, cfg.family),
                &["metric", "value"],
            );
            for (n, v) in &report.ppl {
                t.row(vec![format!("ppl/{n}"), f2(*v)]);
            }
            for (n, v) in &report.acc {
                t.row(vec![format!("acc/{n}"), acc2(*v)]);
            }
            t.row(vec!["acc/avg".into(), acc2(report.avg_acc())]);
            if args.flag("gen") {
                // greedy next-token accuracy through the KV-cached decode
                // path (teacher-forced; also a parity exercise of the cache)
                let acc = zs_svd::eval::greedy_next_token_acc(
                    &p.session, &p.params, None, &p.eval_corpora[0],
                    spec.ppl_batches)?;
                t.row(vec!["gen/greedy-acc".into(), acc2(acc)]);
            }
            print!("{}", t.to_ascii());
        }

        "compress" => {
            let cfg = exp_config(&args);
            let ratio = args.f64_or("ratio", 0.6);
            let method = parse_method(&args.str_or("method", "zs-svd"), ratio);
            let p = coordinator::prepare(&rt, &cfg)?;
            let spec = eval_spec(&args, &cfg);
            let base = coordinator::evaluate_plan(&p, None, &spec)?;
            let plan = coordinator::run_method(&p, &method, ratio)?;
            let report = coordinator::evaluate_plan(&p, Some(&plan), &spec)?;
            println!("{} @ ratio {ratio}: achieved {:.3}, {} ({:.2}s)",
                     plan.method, plan.achieved_ratio(),
                     coordinator::rank_summary(&plan), plan.seconds);
            let mut t = Table::new("compressed vs dense",
                                   &["metric", "dense", &plan.method]);
            for ((n, v), (_, c)) in base.ppl.iter().zip(&report.ppl) {
                t.row(vec![format!("ppl/{n}"), f2(*v), f2(*c)]);
            }
            t.row(vec!["acc/avg".into(), acc2(base.avg_acc()),
                       acc2(report.avg_acc())]);
            t.row(vec!["drop %".into(), "0.0".into(),
                       pct(report.drop_vs(&base))]);
            print!("{}", t.to_ascii());
            if let Some(out) = args.get("save") {
                let compressed = plan.apply(&p.params);
                compressed.save(std::path::Path::new(out))?;
                println!("saved compressed weights to {out}");
            }
            if let Some(out) = args.get("report") {
                // the ZS pipeline stashes the selection report in the
                // always-on obs layer; baselines don't produce one
                match zs_svd::obs::report("compress") {
                    Some(rep) => {
                        let mut body = rep.to_string_pretty();
                        body.push('\n');
                        std::fs::write(out, body)?;
                        println!("wrote compress report to {out}");
                    }
                    None => anyhow::bail!(
                        "no compress report recorded (method `{}` is not a \
                         zero-sum pipeline)", plan.method),
                }
            }
        }

        "sweep" => {
            let cfg = exp_config(&args);
            let ratios = args.f64_list_or("ratios", &cfg.ratios);
            let names = args.str_list_or("methods", &["svd", "svd-llm", "zs-svd"]);
            let p = coordinator::prepare(&rt, &cfg)?;
            let spec = eval_spec(&args, &cfg);
            let base = coordinator::evaluate_plan(&p, None, &spec)?;
            let mut t = Table::new(
                &format!("{} sweep", cfg.model),
                &["ratio", "method", "ppl(wiki)", "ppl(ptb)", "ppl(c4)",
                  "acc", "drop%", "secs"],
            );
            for &ratio in &ratios {
                for name in &names {
                    let m = parse_method(name, ratio);
                    let plan = coordinator::run_method(&p, &m, ratio)?;
                    let r = coordinator::evaluate_plan(&p, Some(&plan), &spec)?;
                    t.row(vec![
                        format!("{ratio}"), plan.method.clone(),
                        f2(r.ppl_of("wiki-syn")), f2(r.ppl_of("ptb-syn")),
                        f2(r.ppl_of("c4-syn")), acc2(r.avg_acc()),
                        pct(r.drop_vs(&base)), format!("{:.2}", plan.seconds),
                    ]);
                }
            }
            print!("{}", t.to_ascii());
        }

        "serve" => {
            let cfg = exp_config(&args);
            if let Some(listen) = args.get("listen") {
                let listen = listen.to_string();
                return serve_listen(&rt, &args, &cfg, &listen);
            }
            let ratio = args.f64_or("ratio", 0.6);
            let requests = args.usize_or("requests", 48);
            let p = coordinator::prepare(&rt, &cfg)?;
            let tag = format!("{}", (ratio * 100.0) as usize);

            if args.flag("decode") {
                // fail fast on an unknown artifact tag, before any
                // benchmarking or compression work
                anyhow::ensure!(p.session.cfg.lowrank.contains_key(&tag),
                                "no lowrank artifact `{tag}`");
                // KV-cached generation under continuous batching; the dense
                // baseline runs BEFORE compression so its peak-RSS column
                // is its own footprint (VmHWM is a monotone high-water mark)
                let dc = DecodeConfig {
                    max_slots: args.usize_or("slots", cfg.decode_slots),
                    max_new_tokens: args.usize_or("max-new-tokens",
                                                  cfg.max_new_tokens),
                    temperature: args.f64_or("temperature", 0.0) as f32,
                    seed: cfg.seed,
                    arrival_steps: args.f64_or("arrival-steps", 0.0),
                    prefill_chunk: args.usize_or("prefill-chunk",
                                                 cfg.prefill_chunk),
                    speculate_k: args.usize_or("speculate-k",
                                               cfg.speculate_k),
                    kv_block: args.usize_or("kv-block", cfg.kv_block),
                    prefix_cache_blocks: args.usize_or(
                        "prefix-cache", cfg.prefix_cache_blocks),
                };
                let prompt_len = args.usize_or("prompt-len",
                                               p.session.cfg.seq_len / 4);
                let reqs = synth_requests(&p.session.cfg, requests, prompt_len,
                                          dc.max_new_tokens, cfg.seed ^ 0xDEC0);
                let (d, _) = run_decode(&p.session, &p.params, &Engine::Dense,
                                        &reqs, &dc)?;
                let plan = coordinator::run_method(&p, &Method::zs(ratio),
                                                   ratio)?;
                let lm = p.session.cfg.lowrank.get(&tag).expect("checked above");
                let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
                let (l, _) = run_decode(&p.session, &plan.apply(&p.params),
                                        &engine, &reqs, &dc)?;
                // optional third row: the dense target re-run with a
                // high-compression drafter proposing `--speculate-k` tokens
                // per slot (greedy output bit-matches the dense row)
                let spec = if dc.speculate_k > 0 {
                    let dratio = args.f64_or("draft-ratio", 0.4);
                    let dtag = format!("{}", (dratio * 100.0) as usize);
                    anyhow::ensure!(
                        p.session.cfg.lowrank.contains_key(&dtag),
                        "no lowrank artifact `{dtag}` for the drafter");
                    let dplan = coordinator::run_method(
                        &p, &Method::zs(dratio), dratio)?;
                    let dlm = p.session.cfg.lowrank.get(&dtag)
                        .expect("checked above");
                    let drafter = Engine::from_plan_capped(&dtag, &dplan,
                                                           &dlm.ranks);
                    let (s, _) = run_decode_speculative(
                        &p.session, &p.params, &Engine::Dense, &drafter,
                        &reqs, &dc)?;
                    Some(s)
                } else {
                    None
                };
                let mut headers = vec!["engine", "prefill tok/s",
                                       "decode tok/s", "total tok/s"];
                headers.extend(LATENCY_HEADERS);
                headers.extend(["ttft p50 ms", "KV MB/slot", "peak RSS MB"]);
                let mut t = Table::new(
                    "decode serving (continuous batching)", &headers);
                let mut rows = vec![&d, &l];
                if let Some(s) = &spec {
                    rows.push(s);
                }
                for s in rows {
                    let mut row = vec![s.engine.clone(),
                                       f2(s.prefill_tok_per_sec),
                                       f2(s.decode_tok_per_sec),
                                       f2(s.total_tok_per_sec)];
                    row.extend(latency_cells(&s.latency));
                    row.extend([f2(s.ttft.p50),
                                mb(s.kv_bytes_per_slot as f64),
                                mb(s.peak_mem_bytes as f64)]);
                    t.row(row);
                }
                print!("{}", t.to_ascii());
                if let Some(s) = &spec {
                    println!("speculation: {} drafted, {} accepted \
                              ({:.1}% acceptance)",
                             s.drafted_tokens, s.accepted_draft_tokens,
                             s.draft_acceptance * 100.0);
                }
            } else {
                let sc = ServeConfig {
                    n_requests: requests,
                    workers: args.usize_or("workers", 1),
                    ..Default::default()
                };
                // dense measured before compression, as above
                let dense_bytes = p.session.cfg.param_count() as f64 * 2.0;
                let d = run_serving(&p.session, &p.params, &Engine::Dense, &sc,
                                    dense_bytes)?;
                let plan = coordinator::run_method(&p, &Method::zs(ratio),
                                                   ratio)?;
                let engine = Engine::from_plan(&tag, &plan);
                let l = run_serving(&p.session, &plan.apply(&p.params), &engine,
                                    &sc, plan.model_bytes(&p.session.cfg))?;

                let mut headers = vec!["engine", "tok/s"];
                headers.extend(LATENCY_HEADERS);
                headers.extend(["weights MB", "act MB", "peak RSS MB"]);
                let mut t = Table::new("serving", &headers);
                for s in [&d, &l] {
                    let mut row = vec![s.engine.clone(),
                                       f2(s.tokens_per_sec)];
                    row.extend(latency_cells(&s.latency));
                    row.extend([mb(s.weight_mem_bytes),
                                mb(s.act_mem_bytes as f64),
                                mb(s.peak_mem_bytes as f64)]);
                    t.row(row);
                }
                print!("{}", t.to_ascii());
            }
        }

        "pack" => {
            let cfg = exp_config(&args);
            let p = coordinator::prepare(&rt, &cfg)?;
            let lowrank = if args.flag("dense") {
                None
            } else {
                Some(args.f64_or("ratio", 0.6))
            };
            // include a speculative drafter when asked for explicitly or
            // when the config's serving default speculates
            let draft = if args.get("draft-ratio").is_some()
                || args.usize_or("speculate-k", cfg.speculate_k) > 0
            {
                Some(args.f64_or("draft-ratio", 0.4))
            } else {
                None
            };
            let sb = coordinator::build_serving(&p, lowrank, draft)?;
            let store_root =
                PathBuf::from(args.str_or("out", &cfg.artifact_store));
            let name = args.get("name").map(str::to_string)
                .unwrap_or_else(|| match lowrank {
                    Some(r) => format!("{}-zs{}", cfg.model,
                                       (r * 100.0) as usize),
                    None => format!("{}-dense", cfg.model),
                });
            let path = artifact::pack(&p.session.cfg, &sb.params, &sb.engine,
                                      sb.drafter.as_ref(), &store_root,
                                      &name)?;
            println!("packed {} engine{} into {}",
                     sb.engine.label(),
                     match &sb.drafter {
                         Some(d) => format!(" (drafter {})", d.label()),
                         None => String::new(),
                     },
                     path.display());
        }

        "install" => {
            let cfg = exp_config(&args);
            let from = args.get("from").ok_or_else(|| anyhow::anyhow!(
                "usage: zs-svd install --from <manifest.zsar> [--to DIR] \
                 [--name NAME]"))?;
            let from = Path::new(from);
            let to = PathBuf::from(args.str_or("to", &cfg.artifact_store));
            let name = args.get("name").map(str::to_string)
                .unwrap_or_else(|| from.file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or("artifact")
                    .to_string());
            let path = artifact::install(from, &to, &name)?;
            println!("installed artifact {}", path.display());
        }

        "router" => {
            let listen = args.str_or("listen", "127.0.0.1:0");
            let workers = args.usize_or("workers", 2);
            let artifact = args.get("artifact").ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: zs-svd router --workers N --artifact \
                     M[,M2,..] [--listen ADDR] [--port-file FILE]")
            })?;
            let artifacts: Vec<String> = artifact
                .split(',')
                .map(|s| s.trim().to_string())
                .collect();
            let mut rcfg = fleet::RouterConfig::new(&listen, workers,
                                                    artifacts);
            rcfg.router_depth = args.usize_or("router-depth",
                                              rcfg.router_depth);
            rcfg.worker_depth = args.usize_or("worker-depth",
                                              rcfg.worker_depth);
            rcfg.heartbeat_ms = args.u64_or("heartbeat-ms",
                                            rcfg.heartbeat_ms);
            rcfg.health_timeout_ms = args.u64_or("health-timeout-ms",
                                                 rcfg.health_timeout_ms);
            // pass-through serving knobs: every worker gets them verbatim
            let mut wargs: Vec<String> = Vec::new();
            for flag in ["threads", "slots", "max-new-tokens", "temperature",
                         "prefill-chunk", "speculate-k", "draft-ratio",
                         "kv-block", "prefix-cache", "queue-depth", "model"] {
                if let Some(v) = args.get(flag) {
                    wargs.push(format!("--{flag}"));
                    wargs.push(v.to_string());
                }
            }
            if args.flag("no-simd") {
                wargs.push("--no-simd".into());
            }
            rcfg.worker_args = wargs;
            let port_file = args.get("port-file").map(str::to_string);
            println!("router: supervising {workers} worker(s) from \
                      {artifact} behind {listen}");
            let stats = fleet::run_fleet(rcfg, |addr| {
                println!("listening on {addr}");
                if let Some(pf) = &port_file {
                    if let Err(e) = std::fs::write(pf, addr.to_string()) {
                        eprintln!("warn: could not write port file \
                                   {pf}: {e}");
                    }
                }
            })?;
            let mut t = Table::new("fleet session", &["metric", "value"]);
            t.row(vec!["connections".into(),
                       format!("{}", stats.connections)]);
            t.row(vec!["requests routed".into(),
                       format!("{}", stats.requests_routed)]);
            t.row(vec!["worker restarts".into(),
                       format!("{}", stats.worker_restarts)]);
            t.row(vec!["worker failures".into(),
                       format!("{}", stats.worker_failures)]);
            t.row(vec!["slow readers shed".into(),
                       format!("{}", stats.slow_reader_closes)]);
            print!("{}", t.to_ascii());
        }

        "client" => {
            return client_session(&args, &rt);
        }

        "trace" => {
            // validate a file produced by `--trace-out` (chrome trace) or
            // `compress --report` (selection report): parse it with the
            // repo's own `util::json`, auto-detect which of the two it is,
            // and check the keys a consumer relies on — CI runs this
            // against the serve-smoke trace so a malformed export fails
            // loudly instead of silently confusing Perfetto
            let path = args.positional.first().cloned().ok_or_else(|| {
                anyhow::anyhow!("usage: zs-svd trace <file>  (a chrome \
                                 trace or a compress report)")
            })?;
            let j = zs_svd::util::json::parse_file(std::path::Path::new(&path))
                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            if let Some(events) = j.get("traceEvents") {
                let evs = events.as_arr().ok_or_else(|| anyhow::anyhow!(
                    "{path}: `traceEvents` is not an array"))?;
                for (i, e) in evs.iter().enumerate() {
                    for key in ["name", "ph", "pid", "tid"] {
                        anyhow::ensure!(
                            e.get(key).is_some(),
                            "{path}: traceEvents[{i}] missing `{key}`");
                    }
                    // metadata events (`ph:"M"`, e.g. process names) carry
                    // no timestamp; every span event must
                    if e.str_or("ph", "") != "M" {
                        anyhow::ensure!(
                            e.get("ts").is_some() && e.get("dur").is_some(),
                            "{path}: traceEvents[{i}] span missing ts/dur");
                    }
                }
                println!("{path}: valid chrome trace ({} events)", evs.len());
            } else if j.str_or("type", "") == "compress_report" {
                let targets = j.get("targets")
                    .and_then(|t| t.as_arr())
                    .ok_or_else(|| anyhow::anyhow!(
                        "{path}: compress report missing `targets` array"))?;
                for (i, t) in targets.iter().enumerate() {
                    for key in ["name", "m", "n", "rank", "removed",
                                "dl_removed", "keep_dense"] {
                        anyhow::ensure!(
                            t.get(key).is_some(),
                            "{path}: targets[{i}] missing `{key}`");
                    }
                }
                for key in ["method", "ratio", "selection", "timing_s",
                            "trajectory"] {
                    anyhow::ensure!(j.get(key).is_some(),
                                    "{path}: compress report missing `{key}`");
                }
                println!("{path}: valid compress report ({} targets, \
                          {} trajectory points)",
                         targets.len(),
                         j.get("trajectory")
                             .and_then(|t| t.as_arr())
                             .map(|a| a.len())
                             .unwrap_or(0));
            } else {
                anyhow::bail!("{path}: neither a chrome trace \
                               (no `traceEvents`) nor a compress report \
                               (no `\"type\":\"compress_report\"`)");
            }
            return Ok(());
        }

        other => {
            anyhow::bail!("unknown subcommand `{other}` \
                           (info|train|eval|compress|sweep|serve|router|\
                            pack|install|client|trace)");
        }
    }
    write_trace_out(&args)?;
    Ok(())
}
