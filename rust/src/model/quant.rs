//! Symmetric int8 quantize–dequantize — the HQ (Half-prune + Quantize)
//! mechanism of the paper (Sec. 5, Table 1 footnote †) and the fp8-remap
//! quality proxy.  Per-row scales, round-to-nearest.

use crate::tensor::Mat;

/// Quantize a matrix to int8 per-row and immediately dequantize (the network
/// consumes f32; what matters for the experiments is the quantization error
/// + the byte accounting).
pub fn quant_dequant_int8(w: &Mat) -> Mat {
    let mut out = Mat::zeros(w.rows, w.cols);
    for r in 0..w.rows {
        let row = w.row(r);
        let maxabs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
        let orow = out.row_mut(r);
        for (o, &v) in orow.iter_mut().zip(row) {
            let q = (v / scale).round().clamp(-127.0, 127.0);
            *o = q * scale;
        }
    }
    out
}

/// Max elementwise quantization error bound for a row with max-abs `m`:
/// half a quantization step.
pub fn int8_error_bound(maxabs: f32) -> f32 {
    maxabs / 127.0 / 2.0 + f32::EPSILON
}

/// Storage bytes for an int8 matrix with per-row f32 scales.
pub fn int8_bytes(rows: usize, cols: usize) -> usize {
    rows * cols + rows * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn error_within_bound() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(&mut rng, 16, 64, 0.5);
        let q = quant_dequant_int8(&w);
        for r in 0..w.rows {
            let maxabs = w.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bound = int8_error_bound(maxabs);
            for (a, b) in w.row(r).iter().zip(q.row(r)) {
                assert!((a - b).abs() <= bound * 1.01, "{a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(&mut rng, 8, 8, 1.0);
        let q1 = quant_dequant_int8(&w);
        let q2 = quant_dequant_int8(&q1);
        for (a, b) in q1.data.iter().zip(&q2.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_row_survives() {
        let w = Mat::zeros(2, 4);
        let q = quant_dequant_int8(&w);
        assert!(q.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn byte_accounting() {
        // int8 + per-row scale ≈ half of fp16 for wide rows
        assert_eq!(int8_bytes(4, 100), 416);
        assert!(int8_bytes(128, 128) < 128 * 128 * 2);
    }
}
