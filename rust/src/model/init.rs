//! Parameter initialization — mirrors `python/compile/model.py::init_params`
//! (normal(0, 0.02), residual-branch outputs scaled by 1/sqrt(2L), norms at
//! one) so rust-trained and python-tested models share dynamics.

use super::manifest::ConfigMeta;
use super::store::ParamStore;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Random initialization matching `model.py::init_params` (scaled normal
/// projections, ones for norm scales).
pub fn init_params(cfg: &ConfigMeta, rng: &mut Rng) -> ParamStore {
    let mut store = ParamStore::zeros_like(cfg);
    let resid_scale = 0.02 / (2.0 * cfg.n_layers as f32).sqrt();
    for p in &cfg.params {
        let mut t = Tensor::zeros(&p.shape);
        if p.name.ends_with("ln1") || p.name.ends_with("ln2")
            || p.name.ends_with("final_ln")
        {
            t.data.fill(1.0);
        } else {
            let std = if p.name.ends_with("wo") || p.name.ends_with("wdown")
                || p.name.ends_with("wout")
            {
                resid_scale
            } else {
                0.02
            };
            rng.fill_normal(&mut t.data, 0.0, std);
        }
        store.set(&p.name, t);
    }
    store
}

/// Zero-filled Adam state (m or v) for a config.
pub fn zero_state(cfg: &ConfigMeta) -> ParamStore {
    ParamStore::zeros_like(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use std::path::PathBuf;

    fn tiny() -> ConfigMeta {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).unwrap().config("tiny").clone()
    }

    #[test]
    fn norms_are_ones_weights_are_small() {
        let cfg = tiny();
        let mut rng = Rng::new(1);
        let s = init_params(&cfg, &mut rng);
        s.check_matches(&cfg).unwrap();
        assert!(s.get("layers.0.ln1").data.iter().all(|&v| v == 1.0));
        let w = s.get("layers.0.wq");
        let std = (w.data.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / w.len() as f64)
            .sqrt();
        assert!((std - 0.02).abs() < 0.002, "std {std}");
        // residual outputs scaled down
        let wo = s.get("layers.0.wo");
        let std_o = (wo.data.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / wo.len() as f64)
            .sqrt();
        assert!(std_o < std * 0.6, "wo std {std_o} vs {std}");
    }

    #[test]
    fn deterministic() {
        let cfg = tiny();
        let a = init_params(&cfg, &mut Rng::new(5));
        let b = init_params(&cfg, &mut Rng::new(5));
        assert_eq!(a.get("embed"), b.get("embed"));
    }
}
