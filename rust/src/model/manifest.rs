//! Typed view of `artifacts/manifest.json` — the ABI between the python
//! build path and this runtime.  Produced once by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::{parse_file, Json};

/// One declared input or output of an artifact graph.
#[derive(Clone, Debug)]
pub struct IoMeta {
    /// logical name ("tokens", "loss", a parameter name, ...)
    pub name: String,
    /// declared shape, outermost dimension first
    pub shape: Vec<usize>,
    /// element type: "f32" | "i32"
    pub dtype: String,
}

/// One AOT-lowered graph artifact: its file plus the ordered, shaped
/// signature the runtime validates before dispatch.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// artifact file name inside the artifacts directory
    pub file: String,
    /// ordered input signature
    pub inputs: Vec<IoMeta>,
    /// ordered output signature
    pub outputs: Vec<IoMeta>,
}

/// A low-rank (fused-factor) forward artifact at one compression ratio.
#[derive(Clone, Debug)]
pub struct LowrankMeta {
    /// the fused-kernel forward graph
    pub art: ArtifactMeta,
    /// target name -> uniform rank baked into this artifact's shapes
    pub ranks: BTreeMap<String, usize>,
}

/// Name + shape of one model parameter tensor.
#[derive(Clone, Debug)]
pub struct ParamMeta {
    /// parameter name ("embed", "layers.0.wq", ...)
    pub name: String,
    /// tensor shape, outermost dimension first
    pub shape: Vec<usize>,
}

/// One compression target: a weight matrix the engine may factorize.
#[derive(Clone, Debug)]
pub struct TargetMeta {
    /// parameter name of the targeted matrix
    pub name: String,
    /// (m, n) — rows (output dim), cols (input dim)
    pub shape: (usize, usize),
    /// whitening-site name whose activations feed this matrix
    pub site: String,
}

/// One whitening site: a named activation tap with its feature dimension.
#[derive(Clone, Debug)]
pub struct SiteMeta {
    /// site name ("layers.0.attn_in", ...)
    pub name: String,
    /// feature dimension of the tapped activations
    pub dim: usize,
}

/// Full description of one model configuration: architecture hyper-
/// parameters, the parameter/target/site tables, and every graph artifact
/// the build side lowered for it.
#[derive(Clone, Debug)]
pub struct ConfigMeta {
    /// config name ("tiny", "small", "opt_tiny", ...)
    pub name: String,
    /// architecture family: "llama" | "opt"
    pub arch: String,
    /// vocabulary size
    pub vocab: usize,
    /// residual-stream width
    pub d_model: usize,
    /// transformer layer count
    pub n_layers: usize,
    /// attention head count
    pub n_heads: usize,
    /// MLP hidden width
    pub d_ff: usize,
    /// maximum sequence length (also the KV-arena capacity)
    pub seq_len: usize,
    /// batch size the main forward artifact was lowered at
    pub batch: usize,
    /// RoPE base (llama arch only)
    pub rope_theta: f64,
    /// normalization epsilon (rmsnorm / layernorm)
    pub norm_eps: f32,
    /// every parameter tensor, in canonical order
    pub params: Vec<ParamMeta>,
    /// compression targets (the factorizable weight matrices)
    pub targets: Vec<TargetMeta>,
    /// whitening sites, in the order the moments pass emits them
    pub sites: Vec<SiteMeta>,
    /// batched forward graph
    pub fwd: ArtifactMeta,
    /// optional single-sequence forward graph (serving / decode)
    pub fwd_b1: Option<ArtifactMeta>,
    /// calibration-gradients graph
    pub grads: ArtifactMeta,
    /// whitening-moments graph
    pub moments: ArtifactMeta,
    /// Adam train-step graph
    pub train: ArtifactMeta,
    /// keyed by ratio tag: "80", "60", "40", "20", "60_b1", ...
    pub lowrank: BTreeMap<String, LowrankMeta>,
}

/// The artifact manifest: every model config the build side produced.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// configs keyed by name
    pub configs: BTreeMap<String, ConfigMeta>,
}

fn io_meta(j: &Json) -> IoMeta {
    IoMeta {
        name: j.str_or("name", ""),
        shape: j.req("shape").as_shape().expect("io shape"),
        dtype: j.str_or("dtype", "f32"),
    }
}

fn artifact(j: &Json) -> ArtifactMeta {
    ArtifactMeta {
        file: j.str_or("file", ""),
        inputs: j.req("inputs").as_arr().unwrap().iter().map(io_meta).collect(),
        outputs: j.req("outputs").as_arr().unwrap().iter().map(io_meta).collect(),
    }
}

fn config(name: &str, j: &Json) -> ConfigMeta {
    let arts = j.req("artifacts");
    let lowrank = arts
        .get("lowrank")
        .and_then(Json::as_obj)
        .map(|m| {
            m.iter()
                .map(|(tag, rec)| {
                    let ranks = rec
                        .req("ranks")
                        .as_obj()
                        .unwrap()
                        .iter()
                        .map(|(k, v)| (k.clone(), v.as_usize().unwrap()))
                        .collect();
                    (tag.clone(), LowrankMeta { art: artifact(rec), ranks })
                })
                .collect()
        })
        .unwrap_or_default();

    ConfigMeta {
        name: name.to_string(),
        arch: j.str_or("arch", "llama"),
        vocab: j.usize_or("vocab", 256),
        d_model: j.usize_or("d_model", 0),
        n_layers: j.usize_or("n_layers", 0),
        n_heads: j.usize_or("n_heads", 0),
        d_ff: j.usize_or("d_ff", 0),
        seq_len: j.usize_or("seq_len", 0),
        batch: j.usize_or("batch", 0),
        rope_theta: j.f64_or("rope_theta", 10000.0),
        norm_eps: j.f64_or("norm_eps", 1e-5) as f32,
        params: j
            .req("params")
            .as_arr()
            .unwrap()
            .iter()
            .map(|p| ParamMeta {
                name: p.str_or("name", ""),
                shape: p.req("shape").as_shape().unwrap(),
            })
            .collect(),
        targets: j
            .req("targets")
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| {
                let s = t.req("shape").as_shape().unwrap();
                TargetMeta {
                    name: t.str_or("name", ""),
                    shape: (s[0], s[1]),
                    site: t.str_or("site", ""),
                }
            })
            .collect(),
        sites: j
            .req("sites")
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| SiteMeta {
                name: s.str_or("name", ""),
                dim: s.usize_or("dim", 0),
            })
            .collect(),
        fwd: artifact(arts.req("fwd")),
        fwd_b1: arts.get("fwd_b1").map(artifact),
        grads: artifact(arts.req("grads")),
        moments: artifact(arts.req("moments")),
        train: artifact(arts.req("train")),
        lowrank,
    }
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.  When the file is
    /// absent (no python build step has run) the built-in manifest is used:
    /// the native runtime executes every graph directly, so the manifest
    /// only has to pin the ABI (shapes, orders, signatures), not point at
    /// real HLO files.
    pub fn load(artifacts_dir: &Path) -> Result<Manifest, String> {
        let path = artifacts_dir.join("manifest.json");
        if !path.exists() {
            return Ok(Manifest::builtin());
        }
        let j = parse_file(&path)?;
        let configs = j
            .req("configs")
            .as_obj()
            .ok_or("configs must be an object")?
            .iter()
            .map(|(name, cj)| (name.clone(), config(name, cj)))
            .collect();
        Ok(Manifest { configs })
    }

    /// The shipped model configurations, mirroring
    /// `python/compile/configs.py::CONFIGS` + `aot.py`'s artifact set.
    pub fn builtin() -> Manifest {
        let mut configs = BTreeMap::new();
        for c in [
            builtin_config("tiny", "llama", 128, 4, 4, 352,
                           &[0.8, 0.6, 0.4, 0.2]),
            builtin_config("small", "llama", 192, 6, 6, 512, &[]),
            builtin_config("opt_tiny", "opt", 128, 4, 4, 512, &[]),
        ] {
            configs.insert(c.name.clone(), c);
        }
        Manifest { configs }
    }

    /// Look a config up by name; panics with the known names on a miss
    /// (configs are compile-time constants of the experiment, not user
    /// input).
    pub fn config(&self, name: &str) -> &ConfigMeta {
        self.configs
            .get(name)
            .unwrap_or_else(|| panic!("unknown config `{name}` (have: {:?})",
                                      self.configs.keys().collect::<Vec<_>>()))
    }
}

// ---------------------------------------------------------------------------
// built-in manifest (mirrors python/compile/configs.py + aot.py)
// ---------------------------------------------------------------------------

fn pm(name: &str, shape: Vec<usize>) -> ParamMeta {
    ParamMeta { name: name.to_string(), shape }
}

fn io(name: &str, shape: Vec<usize>, dtype: &str) -> IoMeta {
    IoMeta { name: name.to_string(), shape, dtype: dtype.to_string() }
}

/// Canonical ordered parameter spec (`configs.py::param_spec`).
fn builtin_params(arch: &str, d: usize, ff: usize, vocab: usize,
                  n_layers: usize, seq: usize) -> Vec<ParamMeta> {
    let mut out = vec![pm("embed", vec![vocab, d])];
    if arch == "opt" {
        out.push(pm("pos_embed", vec![seq, d]));
    }
    for i in 0..n_layers {
        let p = format!("layers.{i}.");
        out.push(pm(&format!("{p}ln1"), vec![d]));
        out.push(pm(&format!("{p}wq"), vec![d, d]));
        out.push(pm(&format!("{p}wk"), vec![d, d]));
        out.push(pm(&format!("{p}wv"), vec![d, d]));
        out.push(pm(&format!("{p}wo"), vec![d, d]));
        out.push(pm(&format!("{p}ln2"), vec![d]));
        if arch == "llama" {
            out.push(pm(&format!("{p}wgate"), vec![ff, d]));
            out.push(pm(&format!("{p}wup"), vec![ff, d]));
            out.push(pm(&format!("{p}wdown"), vec![d, ff]));
        } else {
            out.push(pm(&format!("{p}win"), vec![ff, d]));
            out.push(pm(&format!("{p}wout"), vec![d, ff]));
        }
    }
    out.push(pm("final_ln", vec![d]));
    out
}

/// Compression targets (`configs.py::target_spec`).
fn builtin_targets(arch: &str, d: usize, ff: usize, n_layers: usize)
                   -> Vec<TargetMeta> {
    let mut out = Vec::new();
    for i in 0..n_layers {
        let p = format!("layers.{i}.");
        let t = |name: &str, m: usize, n: usize, site: &str| TargetMeta {
            name: format!("{p}{name}"),
            shape: (m, n),
            site: format!("{p}{site}"),
        };
        out.push(t("wq", d, d, "attn_in"));
        out.push(t("wk", d, d, "attn_in"));
        out.push(t("wv", d, d, "attn_in"));
        out.push(t("wo", d, d, "attn_out_in"));
        if arch == "llama" {
            out.push(t("wgate", ff, d, "mlp_in"));
            out.push(t("wup", ff, d, "mlp_in"));
            out.push(t("wdown", d, ff, "mlp_down_in"));
        } else {
            out.push(t("win", ff, d, "mlp_in"));
            out.push(t("wout", d, ff, "mlp_down_in"));
        }
    }
    out
}

/// Whitening sites (`configs.py::site_spec`).
fn builtin_sites(d: usize, ff: usize, n_layers: usize) -> Vec<SiteMeta> {
    let mut out = Vec::new();
    for i in 0..n_layers {
        let p = format!("layers.{i}.");
        out.push(SiteMeta { name: format!("{p}attn_in"), dim: d });
        out.push(SiteMeta { name: format!("{p}attn_out_in"), dim: d });
        out.push(SiteMeta { name: format!("{p}mlp_in"), dim: d });
        out.push(SiteMeta { name: format!("{p}mlp_down_in"), dim: ff });
    }
    out
}

/// Closed-form uniform rank (`configs.py::lowrank_rank`).
fn uniform_rank(ratio: f64, m: usize, n: usize) -> usize {
    ((ratio * (m * n) as f64 / (m + n) as f64) as usize).max(1)
}

fn builtin_config(name: &str, arch: &str, d: usize, n_layers: usize,
                  n_heads: usize, ff: usize, lowrank_ratios: &[f64])
                  -> ConfigMeta {
    let (vocab, seq, batch) = (256usize, 128usize, 8usize);
    let params = builtin_params(arch, d, ff, vocab, n_layers, seq);
    let targets = builtin_targets(arch, d, ff, n_layers);
    let sites = builtin_sites(d, ff, n_layers);

    let param_ios = |prefix: &str| -> Vec<IoMeta> {
        params
            .iter()
            .map(|p| io(&format!("{prefix}{}", p.name), p.shape.clone(), "f32"))
            .collect()
    };
    let tokens_io = |b: usize| io("tokens", vec![b, seq + 1], "i32");

    let fwd_artifact = |b: usize, file: &str| -> ArtifactMeta {
        let mut inputs = param_ios("");
        inputs.push(tokens_io(b));
        ArtifactMeta {
            file: file.to_string(),
            inputs,
            outputs: vec![io("loss", vec![], "f32"),
                          io("logits", vec![b, seq, vocab], "f32")],
        }
    };

    let grads = {
        let mut inputs = param_ios("");
        inputs.push(tokens_io(batch));
        let mut outputs = vec![io("loss", vec![], "f32")];
        for t in &targets {
            outputs.push(io(&format!("d_{}", t.name),
                            vec![t.shape.0, t.shape.1], "f32"));
        }
        ArtifactMeta { file: format!("{name}_grads.hlo"), inputs, outputs }
    };

    let moments = {
        let mut inputs = param_ios("");
        inputs.push(tokens_io(batch));
        let mut outputs = vec![io("loss", vec![], "f32")];
        for s in &sites {
            outputs.push(io(&format!("{}_xx", s.name), vec![s.dim, s.dim], "f32"));
            outputs.push(io(&format!("{}_sum", s.name), vec![s.dim], "f32"));
            outputs.push(io(&format!("{}_abssum", s.name), vec![s.dim], "f32"));
        }
        ArtifactMeta { file: format!("{name}_moments.hlo"), inputs, outputs }
    };

    let train = {
        let mut inputs = param_ios("");
        inputs.extend(param_ios("m_"));
        inputs.extend(param_ios("v_"));
        inputs.push(io("step", vec![], "i32"));
        inputs.push(io("lr", vec![], "f32"));
        inputs.push(tokens_io(batch));
        let mut outputs = param_ios("");
        outputs.extend(param_ios("m_"));
        outputs.extend(param_ios("v_"));
        outputs.push(io("loss", vec![], "f32"));
        ArtifactMeta { file: format!("{name}_train.hlo"), inputs, outputs }
    };

    let tnames: std::collections::BTreeSet<&str> =
        targets.iter().map(|t| t.name.as_str()).collect();
    let base_ios: Vec<IoMeta> = params
        .iter()
        .filter(|p| !tnames.contains(p.name.as_str()))
        .map(|p| io(&p.name, p.shape.clone(), "f32"))
        .collect();

    let mut lowrank = BTreeMap::new();
    for &ratio in lowrank_ratios {
        let pct = (ratio * 100.0).round() as usize;
        for (suffix, b) in [("", batch), ("_b1", 1usize)] {
            let tag = format!("{pct}{suffix}");
            let mut inputs = base_ios.clone();
            let mut ranks = BTreeMap::new();
            for t in &targets {
                let k = uniform_rank(ratio, t.shape.0, t.shape.1);
                inputs.push(io(&format!("{}.wu", t.name), vec![t.shape.0, k], "f32"));
                inputs.push(io(&format!("{}.wv", t.name), vec![k, t.shape.1], "f32"));
                ranks.insert(t.name.clone(), k);
            }
            inputs.push(tokens_io(b));
            let art = ArtifactMeta {
                file: format!("{name}_lowrank_{tag}.hlo"),
                inputs,
                outputs: vec![io("loss", vec![], "f32"),
                              io("logits", vec![b, seq, vocab], "f32")],
            };
            lowrank.insert(tag, LowrankMeta { art, ranks });
        }
    }

    let fwd = fwd_artifact(batch, &format!("{name}_fwd.hlo"));
    let fwd_b1 = Some(fwd_artifact(1, &format!("{name}_fwd_b1.hlo")));

    ConfigMeta {
        name: name.to_string(),
        arch: arch.to_string(),
        vocab,
        d_model: d,
        n_layers,
        n_heads,
        d_ff: ff,
        seq_len: seq,
        batch,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        params,
        targets,
        sites,
        fwd,
        fwd_b1,
        grads,
        moments,
        train,
        lowrank,
    }
}

impl ConfigMeta {
    /// Total parameter count across every tensor of the model.
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }

    /// Look a compression target up by name; panics on a miss.
    pub fn target(&self, name: &str) -> &TargetMeta {
        self.targets
            .iter()
            .find(|t| t.name == name)
            .unwrap_or_else(|| panic!("unknown target `{name}`"))
    }

    /// Feature dimension of a whitening site; panics on a miss.
    pub fn site_dim(&self, name: &str) -> usize {
        self.sites
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("unknown site `{name}`"))
            .dim
    }

    /// Total parameters in the compression-target matrices.
    pub fn target_param_count(&self) -> usize {
        self.targets.iter().map(|t| t.shape.0 * t.shape.1).sum()
    }

    /// Names of non-target params, in canonical (manifest) order.
    pub fn base_param_names(&self) -> Vec<String> {
        let tnames: std::collections::BTreeSet<&str> =
            self.targets.iter().map(|t| t.name.as_str()).collect();
        self.params
            .iter()
            .filter(|p| !tnames.contains(p.name.as_str()))
            .map(|p| p.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
        let tiny = m.config("tiny");
        assert_eq!(tiny.arch, "llama");
        assert_eq!(tiny.d_model, 128);
        assert_eq!(tiny.n_layers, 4);
        // 7 targets per llama layer
        assert_eq!(tiny.targets.len(), 7 * tiny.n_layers);
        // 4 whitening sites per layer
        assert_eq!(tiny.sites.len(), 4 * tiny.n_layers);
        assert!(tiny.fwd_b1.is_some());
        assert!(tiny.lowrank.contains_key("60"));
        assert!(tiny.lowrank.contains_key("60_b1"));
    }

    #[test]
    fn signature_alignment() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        for cfg in m.configs.values() {
            let p = cfg.params.len();
            // fwd inputs = params + tokens
            assert_eq!(cfg.fwd.inputs.len(), p + 1, "{}", cfg.name);
            // grads outputs = loss + per-target grad
            assert_eq!(cfg.grads.outputs.len(), 1 + cfg.targets.len());
            for (out, t) in cfg.grads.outputs[1..].iter().zip(&cfg.targets) {
                assert_eq!(out.shape, vec![t.shape.0, t.shape.1]);
            }
            // moments outputs = anchoring loss + 3 per site
            assert_eq!(cfg.moments.outputs.len(), 1 + 3 * cfg.sites.len());
            // train: params+m+v+step+lr+tokens -> params+m+v+loss
            assert_eq!(cfg.train.inputs.len(), 3 * p + 3);
            assert_eq!(cfg.train.outputs.len(), 3 * p + 1);
            // lowrank inputs = base + 2*targets + tokens
            for lm in cfg.lowrank.values() {
                assert_eq!(
                    lm.art.inputs.len(),
                    cfg.base_param_names().len() + 2 * cfg.targets.len() + 1
                );
            }
        }
    }

    #[test]
    fn target_site_dims_match_cols() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        for cfg in m.configs.values() {
            for t in &cfg.targets {
                assert_eq!(cfg.site_dim(&t.site), t.shape.1,
                           "{}: {}", cfg.name, t.name);
            }
        }
    }

    #[test]
    fn param_counts_sane() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let tiny = m.config("tiny");
        let total = tiny.param_count();
        assert!((500_000..2_000_000).contains(&total), "{total}");
        assert!(tiny.target_param_count() < total);
    }
}
