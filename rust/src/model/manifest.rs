//! Typed view of `artifacts/manifest.json` — the ABI between the python
//! build path and this runtime.  Produced once by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::{parse_file, Json};

#[derive(Clone, Debug)]
pub struct IoMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<IoMeta>,
    pub outputs: Vec<IoMeta>,
}

#[derive(Clone, Debug)]
pub struct LowrankMeta {
    pub art: ArtifactMeta,
    /// target name -> uniform rank baked into this artifact's shapes
    pub ranks: BTreeMap<String, usize>,
}

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct TargetMeta {
    pub name: String,
    /// (m, n) — rows (output dim), cols (input dim)
    pub shape: (usize, usize),
    pub site: String,
}

#[derive(Clone, Debug)]
pub struct SiteMeta {
    pub name: String,
    pub dim: usize,
}

#[derive(Clone, Debug)]
pub struct ConfigMeta {
    pub name: String,
    pub arch: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub params: Vec<ParamMeta>,
    pub targets: Vec<TargetMeta>,
    pub sites: Vec<SiteMeta>,
    pub fwd: ArtifactMeta,
    pub fwd_b1: Option<ArtifactMeta>,
    pub grads: ArtifactMeta,
    pub moments: ArtifactMeta,
    pub train: ArtifactMeta,
    /// keyed by ratio tag: "80", "60", "40", "20", "60_b1", ...
    pub lowrank: BTreeMap<String, LowrankMeta>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub configs: BTreeMap<String, ConfigMeta>,
}

fn io_meta(j: &Json) -> IoMeta {
    IoMeta {
        name: j.str_or("name", ""),
        shape: j.req("shape").as_shape().expect("io shape"),
        dtype: j.str_or("dtype", "f32"),
    }
}

fn artifact(j: &Json) -> ArtifactMeta {
    ArtifactMeta {
        file: j.str_or("file", ""),
        inputs: j.req("inputs").as_arr().unwrap().iter().map(io_meta).collect(),
        outputs: j.req("outputs").as_arr().unwrap().iter().map(io_meta).collect(),
    }
}

fn config(name: &str, j: &Json) -> ConfigMeta {
    let arts = j.req("artifacts");
    let lowrank = arts
        .get("lowrank")
        .and_then(Json::as_obj)
        .map(|m| {
            m.iter()
                .map(|(tag, rec)| {
                    let ranks = rec
                        .req("ranks")
                        .as_obj()
                        .unwrap()
                        .iter()
                        .map(|(k, v)| (k.clone(), v.as_usize().unwrap()))
                        .collect();
                    (tag.clone(), LowrankMeta { art: artifact(rec), ranks })
                })
                .collect()
        })
        .unwrap_or_default();

    ConfigMeta {
        name: name.to_string(),
        arch: j.str_or("arch", "llama"),
        vocab: j.usize_or("vocab", 256),
        d_model: j.usize_or("d_model", 0),
        n_layers: j.usize_or("n_layers", 0),
        n_heads: j.usize_or("n_heads", 0),
        d_ff: j.usize_or("d_ff", 0),
        seq_len: j.usize_or("seq_len", 0),
        batch: j.usize_or("batch", 0),
        params: j
            .req("params")
            .as_arr()
            .unwrap()
            .iter()
            .map(|p| ParamMeta {
                name: p.str_or("name", ""),
                shape: p.req("shape").as_shape().unwrap(),
            })
            .collect(),
        targets: j
            .req("targets")
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| {
                let s = t.req("shape").as_shape().unwrap();
                TargetMeta {
                    name: t.str_or("name", ""),
                    shape: (s[0], s[1]),
                    site: t.str_or("site", ""),
                }
            })
            .collect(),
        sites: j
            .req("sites")
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| SiteMeta {
                name: s.str_or("name", ""),
                dim: s.usize_or("dim", 0),
            })
            .collect(),
        fwd: artifact(arts.req("fwd")),
        fwd_b1: arts.get("fwd_b1").map(artifact),
        grads: artifact(arts.req("grads")),
        moments: artifact(arts.req("moments")),
        train: artifact(arts.req("train")),
        lowrank,
    }
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest, String> {
        let j = parse_file(&artifacts_dir.join("manifest.json"))?;
        let configs = j
            .req("configs")
            .as_obj()
            .ok_or("configs must be an object")?
            .iter()
            .map(|(name, cj)| (name.clone(), config(name, cj)))
            .collect();
        Ok(Manifest { configs })
    }

    pub fn config(&self, name: &str) -> &ConfigMeta {
        self.configs
            .get(name)
            .unwrap_or_else(|| panic!("unknown config `{name}` (have: {:?})",
                                      self.configs.keys().collect::<Vec<_>>()))
    }
}

impl ConfigMeta {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }

    pub fn target(&self, name: &str) -> &TargetMeta {
        self.targets
            .iter()
            .find(|t| t.name == name)
            .unwrap_or_else(|| panic!("unknown target `{name}`"))
    }

    pub fn site_dim(&self, name: &str) -> usize {
        self.sites
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("unknown site `{name}`"))
            .dim
    }

    /// Total parameters in the compression-target matrices.
    pub fn target_param_count(&self) -> usize {
        self.targets.iter().map(|t| t.shape.0 * t.shape.1).sum()
    }

    /// Names of non-target params, in canonical (manifest) order.
    pub fn base_param_names(&self) -> Vec<String> {
        let tnames: std::collections::BTreeSet<&str> =
            self.targets.iter().map(|t| t.name.as_str()).collect();
        self.params
            .iter()
            .filter(|p| !tnames.contains(p.name.as_str()))
            .map(|p| p.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
        let tiny = m.config("tiny");
        assert_eq!(tiny.arch, "llama");
        assert_eq!(tiny.d_model, 128);
        assert_eq!(tiny.n_layers, 4);
        // 7 targets per llama layer
        assert_eq!(tiny.targets.len(), 7 * tiny.n_layers);
        // 4 whitening sites per layer
        assert_eq!(tiny.sites.len(), 4 * tiny.n_layers);
        assert!(tiny.fwd_b1.is_some());
        assert!(tiny.lowrank.contains_key("60"));
        assert!(tiny.lowrank.contains_key("60_b1"));
    }

    #[test]
    fn signature_alignment() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        for cfg in m.configs.values() {
            let p = cfg.params.len();
            // fwd inputs = params + tokens
            assert_eq!(cfg.fwd.inputs.len(), p + 1, "{}", cfg.name);
            // grads outputs = loss + per-target grad
            assert_eq!(cfg.grads.outputs.len(), 1 + cfg.targets.len());
            for (out, t) in cfg.grads.outputs[1..].iter().zip(&cfg.targets) {
                assert_eq!(out.shape, vec![t.shape.0, t.shape.1]);
            }
            // moments outputs = anchoring loss + 3 per site
            assert_eq!(cfg.moments.outputs.len(), 1 + 3 * cfg.sites.len());
            // train: params+m+v+step+lr+tokens -> params+m+v+loss
            assert_eq!(cfg.train.inputs.len(), 3 * p + 3);
            assert_eq!(cfg.train.outputs.len(), 3 * p + 1);
            // lowrank inputs = base + 2*targets + tokens
            for lm in cfg.lowrank.values() {
                assert_eq!(
                    lm.art.inputs.len(),
                    cfg.base_param_names().len() + 2 * cfg.targets.len() + 1
                );
            }
        }
    }

    #[test]
    fn target_site_dims_match_cols() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        for cfg in m.configs.values() {
            for t in &cfg.targets {
                assert_eq!(cfg.site_dim(&t.site), t.shape.1,
                           "{}: {}", cfg.name, t.name);
            }
        }
    }

    #[test]
    fn param_counts_sane() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let tiny = m.config("tiny");
        let total = tiny.param_count();
        assert!((500_000..2_000_000).contains(&total), "{total}");
        assert!(tiny.target_param_count() < total);
    }
}
