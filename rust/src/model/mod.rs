//! Model metadata + weights: manifest ABI, parameter store with the ZST0
//! checkpoint format, initialization, and int8 quantization (DESIGN.md §4).

pub mod init;
pub mod manifest;
pub mod quant;
pub mod store;

pub use manifest::{ArtifactMeta, ConfigMeta, Manifest, SiteMeta, TargetMeta};
pub use store::ParamStore;
