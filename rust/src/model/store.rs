//! Parameter store + the `ZST0` checkpoint format.
//!
//! `ParamStore` holds named tensors in the manifest's canonical order (the
//! PJRT input order).  Checkpoints are a small self-describing binary
//! format — magic `ZST0`, a JSON header (names/shapes/offsets), then raw
//! little-endian f32 data — implemented in-repo since serde/safetensors are
//! unavailable offline (the layout mirrors safetensors).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use super::manifest::ConfigMeta;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};

/// Ordered, named parameter tensors — the in-memory weight format shared
/// by training, compression, serving, and the ZST0 checkpoint format.
#[derive(Clone, Debug)]
pub struct ParamStore {
    names: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Store knowing its parameter names but holding no tensors yet.
    pub fn new_empty(names: Vec<String>) -> ParamStore {
        ParamStore { names, map: BTreeMap::new() }
    }

    /// Zero-initialized store matching a config's parameter spec.
    pub fn zeros_like(cfg: &ConfigMeta) -> ParamStore {
        let mut s = ParamStore::new_empty(
            cfg.params.iter().map(|p| p.name.clone()).collect());
        for p in &cfg.params {
            s.map.insert(p.name.clone(), Tensor::zeros(&p.shape));
        }
        s
    }

    /// Parameter names in canonical (manifest) order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Number of parameters.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when the store names no parameters.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Tensor lookup by name; panics on a miss.
    pub fn get(&self, name: &str) -> &Tensor {
        self.map
            .get(name)
            .unwrap_or_else(|| panic!("param `{name}` missing"))
    }

    /// Mutable access for in-place updates (the optimizer hot path — no
    /// clone/re-insert round trip).
    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        self.map
            .get_mut(name)
            .unwrap_or_else(|| panic!("param `{name}` missing"))
    }

    /// Replace a tensor (name must be declared).
    pub fn set(&mut self, name: &str, t: Tensor) {
        assert!(self.names.iter().any(|n| n == name), "unknown param `{name}`");
        self.map.insert(name.to_string(), t);
    }

    /// Ordered tensors (the PJRT call order).
    pub fn ordered(&self) -> Vec<&Tensor> {
        self.names.iter().map(|n| self.get(n)).collect()
    }

    /// Total scalar count across every tensor.
    pub fn total_values(&self) -> usize {
        self.names.iter().map(|n| self.get(n).len()).sum()
    }

    /// Bytes at fp16-equivalent accounting (the paper reports fp16 storage).
    pub fn fp16_bytes(&self) -> usize {
        self.total_values() * 2
    }

    // ------------------------------------------------------------------
    // ZST0 checkpoint format
    // ------------------------------------------------------------------

    /// Write the ZST0 checkpoint format (JSON header + raw f32 payload).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut header_entries = Vec::new();
        let mut offset = 0usize;
        for n in &self.names {
            let t = self.get(n);
            header_entries.push(Json::obj(vec![
                ("name", Json::str(n)),
                ("shape", Json::arr(t.shape.iter().map(|&d| Json::num(d as f64)))),
                ("offset", Json::num(offset as f64)),
            ]));
            offset += t.len();
        }
        let header = Json::obj(vec![
            ("version", Json::num(1.0)),
            ("tensors", Json::Arr(header_entries)),
        ])
        .to_string();

        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"ZST0")?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for n in &self.names {
            for v in &self.get(n).data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Read a ZST0 checkpoint written by [`ParamStore::save`].
    pub fn load(path: &Path) -> anyhow::Result<ParamStore> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"ZST0", "bad checkpoint magic {magic:?}");
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;

        let mut rest = Vec::new();
        f.read_to_end(&mut rest)?;
        anyhow::ensure!(rest.len() % 4 == 0, "truncated checkpoint data");
        let floats: Vec<f32> = rest
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut names = Vec::new();
        let mut map = BTreeMap::new();
        for e in header.req("tensors").as_arr().unwrap() {
            let name = e.str_or("name", "");
            let shape = e.req("shape").as_shape().unwrap();
            let offset = e.usize_or("offset", 0);
            let n: usize = shape.iter().product();
            anyhow::ensure!(offset + n <= floats.len(),
                            "tensor `{name}` out of bounds");
            map.insert(name.clone(),
                       Tensor::from_vec(&shape, floats[offset..offset + n].to_vec()));
            names.push(name);
        }
        Ok(ParamStore { names, map })
    }

    /// Validate against a config spec (names + shapes, in order).
    pub fn check_matches(&self, cfg: &ConfigMeta) -> anyhow::Result<()> {
        anyhow::ensure!(self.names.len() == cfg.params.len(),
                        "param count {} != {}", self.names.len(), cfg.params.len());
        for (n, p) in self.names.iter().zip(&cfg.params) {
            anyhow::ensure!(n == &p.name, "order mismatch: {n} vs {}", p.name);
            anyhow::ensure!(self.get(n).shape == p.shape,
                            "shape mismatch for {n}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_store() -> ParamStore {
        let mut rng = Rng::new(1);
        let mut s = ParamStore::new_empty(vec!["a".into(), "b".into(), "c".into()]);
        let mut t = Tensor::zeros(&[3, 4]);
        rng.fill_normal(&mut t.data, 0.0, 1.0);
        s.set("a", t);
        s.set("b", Tensor::scalar(7.5));
        let mut t2 = Tensor::zeros(&[2, 2, 2]);
        rng.fill_normal(&mut t2.data, 0.0, 1.0);
        s.set("c", t2);
        s
    }

    #[test]
    fn ordered_follows_names() {
        let s = sample_store();
        let o = s.ordered();
        assert_eq!(o[0].shape, vec![3, 4]);
        assert_eq!(o[1].shape, Vec::<usize>::new());
        assert_eq!(s.total_values(), 12 + 1 + 8);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let s = sample_store();
        let dir = std::env::temp_dir().join("zs_svd_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.zst0");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.names(), s.names());
        for n in s.names() {
            assert_eq!(loaded.get(n), s.get(n));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("zs_svd_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.zst0");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic(expected = "unknown param")]
    fn set_unknown_panics() {
        let mut s = sample_store();
        s.set("zzz", Tensor::scalar(0.0));
    }
}
