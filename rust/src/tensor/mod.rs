//! Dense tensors: `Mat` (2-D f32, row-major — the linalg workhorse) and
//! `Tensor` (n-D f32) + `IntTensor` (i32 token buffers) shared across the
//! native runtime, the compression engine, and the checkpoint format.

use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Mat
// ---------------------------------------------------------------------------

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// row count
    pub rows: usize,
    /// column count
    pub cols: usize,
    /// row-major element storage, `rows * cols` long
    pub data: Vec<f32>,
}

impl Mat {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer; panics on a length mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    /// The n-by-n identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// I.i.d. normal entries with mean 0 and the given std.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, 0.0, std);
        m
    }

    /// Element (r, c).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Mutable element (r, c).
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Row `r` as a contiguous slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r` as a mutable contiguous slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy `src` over row `r` (KV-cache appends, factor re-shaping).
    #[inline]
    pub fn set_row(&mut self, r: usize, src: &[f32]) {
        self.row_mut(r).copy_from_slice(src);
    }

    /// Materialized transpose.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness on larger matrices
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Multiply every element by `s` in place.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Elementwise `self += other`; shapes must match.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise difference `self - other`; shapes must match.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise sum `self + other`; shapes must match.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Copy of `self` with every element multiplied by `s`.
    pub fn scaled(&self, s: f32) -> Mat {
        let mut out = self.clone();
        out.scale(s);
        out
    }

    /// Frobenius inner product <A, B> = tr(A^T B).
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum()
    }

    /// Frobenius norm, accumulated in f64.
    pub fn frob_norm(&self) -> f64 {
        self.dot(self).sqrt()
    }

    /// Add `lambda` to the diagonal (ridge for whitening stability).
    pub fn add_diag(&mut self, lambda: f32) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += lambda;
        }
    }

    /// The main diagonal (length `min(rows, cols)`).
    pub fn diag(&self) -> Vec<f32> {
        (0..self.rows.min(self.cols)).map(|i| self.at(i, i)).collect()
    }

    /// True when every element is finite (no NaN / infinity).
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

// ---------------------------------------------------------------------------
// Tensor (n-D f32) and IntTensor (n-D i32)
// ---------------------------------------------------------------------------

/// N-dimensional f32 tensor (row-major), the parameter/activation type of
/// the native runtime and the checkpoint format.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// dimensions, outermost first; empty = scalar
    pub shape: Vec<usize>,
    /// row-major element storage
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// 0-dimensional tensor holding one value.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Wrap an existing buffer; panics on a length mismatch.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor { shape: shape.to_vec(), data }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for a zero-element tensor (some dimension is 0).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// View a 2-D tensor as a Mat (copy).
    pub fn to_mat(&self) -> Mat {
        assert_eq!(self.shape.len(), 2, "to_mat wants 2-D, got {:?}", self.shape);
        Mat::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }

    /// Copy a `Mat` into a 2-D tensor.
    pub fn from_mat(m: &Mat) -> Tensor {
        Tensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }
}

/// N-dimensional i32 tensor — token id buffers for the model graphs.
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    /// dimensions, outermost first; empty = scalar
    pub shape: Vec<usize>,
    /// row-major element storage
    pub data: Vec<i32>,
}

impl IntTensor {
    /// Wrap an existing buffer; panics on a length mismatch.
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> IntTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        IntTensor { shape: shape.to_vec(), data }
    }

    /// 0-dimensional tensor holding one value.
    pub fn scalar(v: i32) -> IntTensor {
        IntTensor { shape: vec![], data: vec![v] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_indexing_roundtrip() {
        let mut m = Mat::zeros(3, 4);
        *m.at_mut(1, 2) = 5.0;
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1)[2], 5.0);
    }

    #[test]
    fn set_row_copies_whole_row() {
        let mut m = Mat::zeros(2, 3);
        m.set_row(1, &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(0), &[0.0; 3]);
        assert_eq!(m.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(&mut rng, 37, 53, 1.0);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_entries() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.at(2, 1), m.at(1, 2));
        assert_eq!((t.rows, t.cols), (3, 2));
    }

    #[test]
    fn frob_and_dot() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        assert!((a.frob_norm() - (30.0f64).sqrt()).abs() < 1e-9);
        let b = Mat::eye(2);
        assert!((a.dot(&b) - 5.0).abs() < 1e-9); // trace
    }

    #[test]
    fn add_diag_ridge() {
        let mut m = Mat::zeros(3, 3);
        m.add_diag(0.5);
        assert_eq!(m.diag(), vec![0.5, 0.5, 0.5]);
    }

    #[test]
    fn tensor_mat_roundtrip() {
        let mut rng = Rng::new(2);
        let m = Mat::randn(&mut rng, 4, 5, 1.0);
        let t = Tensor::from_mat(&m);
        assert_eq!(t.to_mat(), m);
    }

    #[test]
    fn tensor_shapes() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        let s = Tensor::scalar(7.0);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.data, vec![7.0]);
    }
}
